"""Content-addressed, refcounted chunk store.

The durable byte layer under :class:`repro.fs.branchfs.BranchFS`.  Chunks
are immutable blobs addressed by BLAKE2b digest; identical content across
branches/checkpoints is stored once (structural sharing on disk, the same
CoW economics the paper gets from delta directories).  Refcounts are kept
in a sidecar JSON so the store needs nothing beyond ordinary files —
portable across ext4/XFS/NFS/tmpfs and fully unprivileged (R5).
"""

from __future__ import annotations

import hashlib
import json
import os
import tempfile
import threading
from pathlib import Path
from typing import Dict, Iterable, Optional


def _digest(data: bytes) -> str:
    return hashlib.blake2b(data, digest_size=20).hexdigest()


class ChunkStore:
    def __init__(self, root: str | Path):
        self.root = Path(root)
        (self.root / "chunks").mkdir(parents=True, exist_ok=True)
        self._lock = threading.RLock()
        self._refs_path = self.root / "refcounts.json"
        self._refs: Dict[str, int] = {}
        if self._refs_path.exists():
            self._refs = json.loads(self._refs_path.read_text())
        # chunk files actually written (dedup hits don't count); BranchFS
        # mirrors this into its obs gauge `fs.chunks_materialized`
        self.materialized = 0

    def _chunk_path(self, cid: str) -> Path:
        # two-level fanout like .git/objects, keeps directories small
        return self.root / "chunks" / cid[:2] / cid[2:]

    def _persist_refs(self) -> None:
        tmp = self._refs_path.with_suffix(".tmp")
        tmp.write_text(json.dumps(self._refs))
        os.replace(tmp, self._refs_path)

    # ------------------------------------------------------------------
    def put(self, data: bytes) -> str:
        """Store ``data``; returns its chunk id.  Incref on every call."""
        cid = _digest(data)
        with self._lock:
            path = self._chunk_path(cid)
            if not path.exists():
                path.parent.mkdir(parents=True, exist_ok=True)
                # atomic create: write to a temp file then rename
                fd, tmp = tempfile.mkstemp(dir=path.parent)
                try:
                    with os.fdopen(fd, "wb") as f:
                        f.write(data)
                    os.replace(tmp, path)
                except BaseException:
                    os.unlink(tmp)
                    raise
                self.materialized += 1
            self._refs[cid] = self._refs.get(cid, 0) + 1
            self._persist_refs()
            return cid

    def get(self, cid: str) -> bytes:
        path = self._chunk_path(cid)
        if not path.exists():
            raise KeyError(f"chunk {cid} not found")
        return path.read_bytes()

    def exists(self, cid: str) -> bool:
        return self._chunk_path(cid).exists()

    def size(self, cid: str) -> int:
        return self._chunk_path(cid).stat().st_size

    def incref(self, cids: Iterable[str]) -> None:
        with self._lock:
            for cid in cids:
                self._refs[cid] = self._refs.get(cid, 0) + 1
            self._persist_refs()

    def decref(self, cids: Iterable[str]) -> None:
        """Drop references; chunks hitting zero are deleted (GC inline)."""
        with self._lock:
            for cid in cids:
                n = self._refs.get(cid, 0) - 1
                if n <= 0:
                    self._refs.pop(cid, None)
                    try:
                        self._chunk_path(cid).unlink()
                    except FileNotFoundError:
                        pass
                else:
                    self._refs[cid] = n
            self._persist_refs()

    def refcount(self, cid: str) -> int:
        return self._refs.get(cid, 0)

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {
                "chunks": len(self._refs),
                "bytes": sum(
                    self._chunk_path(c).stat().st_size
                    for c in self._refs
                    if self._chunk_path(c).exists()
                ),
            }
