"""BranchFS analogue on disk — branching delta checkpoints.

``chunkstore`` is the content-addressed, refcounted byte store;
``branchfs`` layers branch manifests (delta layers + tombstones + epochs)
with commit-to-parent and sibling invalidation on top, all unprivileged
and portable across underlying filesystems (R5).
"""

from repro.fs.branchfs import BranchFS
from repro.fs.chunkstore import ChunkStore

__all__ = ["BranchFS", "ChunkStore"]
