"""``branchfs`` CLI — standalone branch management (paper §4.4).

Usage (mirrors ``branchfs create/commit/abort``)::

    python -m repro.fs.cli --root /tmp/ws init
    python -m repro.fs.cli --root /tmp/ws create --parent base --name fix-a
    python -m repro.fs.cli --root /tmp/ws write  --branch fix-a --path main.py --data 'print(1)'
    python -m repro.fs.cli --root /tmp/ws read   --branch fix-a --path main.py
    python -m repro.fs.cli --root /tmp/ws commit --branch fix-a
    python -m repro.fs.cli --root /tmp/ws abort  --branch fix-b
    python -m repro.fs.cli --root /tmp/ws ls     --branch base
    python -m repro.fs.cli --root /tmp/ws status --branch fix-a
"""

from __future__ import annotations

import argparse
import sys

from repro.fs.branchfs import BranchFS


def main(argv=None) -> int:
    p = argparse.ArgumentParser(prog="branchfs")
    p.add_argument("--root", required=True, help="store root directory")
    sub = p.add_subparsers(dest="cmd", required=True)

    sub.add_parser("init")
    c = sub.add_parser("create")
    c.add_argument("--parent", default="base")
    c.add_argument("--name", default=None)
    c.add_argument("-n", type=int, default=1)
    for name in ("commit", "abort", "ls", "status"):
        s = sub.add_parser(name)
        s.add_argument("--branch", required=True)
    w = sub.add_parser("write")
    w.add_argument("--branch", required=True)
    w.add_argument("--path", required=True)
    w.add_argument("--data", required=True)
    r = sub.add_parser("read")
    r.add_argument("--branch", required=True)
    r.add_argument("--path", required=True)
    d = sub.add_parser("rm")
    d.add_argument("--branch", required=True)
    d.add_argument("--path", required=True)

    args = p.parse_args(argv)
    fs = BranchFS(args.root)

    if args.cmd == "init":
        print(f"initialized BranchFS at {args.root}")
    elif args.cmd == "create":
        names = fs.create(parent=args.parent, name=args.name, n=args.n)
        print("\n".join(names))
    elif args.cmd == "commit":
        print(fs.commit(args.branch))
    elif args.cmd == "abort":
        fs.abort(args.branch)
        print("aborted")
    elif args.cmd == "write":
        fs.write(args.branch, args.path, args.data.encode())
        print("ok")
    elif args.cmd == "read":
        sys.stdout.buffer.write(fs.read(args.branch, args.path))
    elif args.cmd == "rm":
        fs.delete(args.branch, args.path)
        print("ok")
    elif args.cmd == "ls":
        print("\n".join(fs.listdir(args.branch)))
    elif args.cmd == "status":
        print(fs.status(args.branch))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
