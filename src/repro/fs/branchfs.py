"""BranchFS — durable branching delta store (the paper's filesystem, on disk).

Reproduces the BranchFS design (paper §4) at checkpoint granularity:

* **Branches as delta layers**: each branch is a manifest mapping
  ``path -> chunk id`` (or tombstone).  Unmodified paths resolve through
  the ancestor chain to the base (§4.2).
* **O(1) creation**: creating a branch writes one empty per-branch
  manifest plus the (small) branch-graph file — cost independent of base
  size (paper Table 4; ``benchmarks/branch_create.py`` asserts the
  scaling).  Deltas are NOT stored in the graph file, so a 10k-file base
  never rewrites on fork.
* **Commit ∝ modification size**: commit merges the delta manifest into
  the parent (tombstones first, §4.3); only delta entries move.  The
  parent's epoch is bumped, invalidating all sibling branches.  Chunk
  payloads are content-addressed and already on disk at write() time, so
  commit itself is O(#modified files) — stronger than the paper's
  O(bytes) file copy (recorded as a beyond-paper delta in EXPERIMENTS).
* **Abort is trivial**: drop the manifest, decref chunks.
* **fsync elision**: branch writes are buffered (no fsync) — durability
  is enforced at commit time, exactly the paper's rationale for beating
  native write throughput on ephemeral branches (§6, Table 6).
* **Unprivileged & portable**: plain files + atomic renames, no mounts,
  no root (R5).
* **@branch paths**: ``read("@feature-a/src/main.py")`` addresses a
  branch's view, mirroring the virtual-directory interface (§4.4).

The in-memory :class:`repro.core.store.BranchStore` and this class
deliberately share semantics; property tests cross-check them against a
single model.
"""

from __future__ import annotations

import json
import os
import threading
import time
from pathlib import Path
from typing import Any, Dict, Iterator, List, Optional, Tuple

from repro.core.errors import (
    BranchStateError,
    FrozenOriginError,
    NoSuchLeafError,
    StaleBranchError,
)
from repro.fs.chunkstore import ChunkStore
from repro.obs import Observability

_TOMB = "__tombstone__"
BASE = "base"


class BranchFS:
    def __init__(self, root: str | Path, *,
                 obs: Optional[Observability] = None):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.chunks = ChunkStore(self.root / "objects")
        self.obs = Observability() if obs is None else obs
        m = self.obs.metrics
        # a CoW fault = first write to a path this branch only inherited
        # (the delta-layer analogue of the KV pool's shared-tail copy)
        self._c_cow_faults = m.counter("fs.cow_faults")
        self._c_writes = m.counter("fs.writes")
        self._c_commits = m.counter("fs.commits")
        self._h_commit_us = m.histogram("fs.commit_us")
        self._g_materialized = m.gauge("fs.chunks_materialized")
        self._lock = threading.RLock()
        self._tree_path = self.root / "tree.json"
        self._log_path = self.root / "tree.log"
        self._log_fd: Optional[int] = None
        self._delta_dir = self.root / "manifests"
        self._delta_dir.mkdir(exist_ok=True)
        self._deltas: Dict[str, Dict[str, str]] = {}
        self._tree = self._load_tree()
        if self._tree is None:
            self._tree = {
                "branches": {
                    BASE: {
                        "parent": None,
                        "status": "active",
                        "epoch": 0,
                        "fork_epoch": 0,
                        "children": [],
                        "delta_id": 0,
                    }
                },
                "next_id": 1,
                "seq": 0,
            }
            self._persist_tree()
            self._persist_delta(BASE)

    # ------------------------------------------------------------------
    # persistence: graph file is O(#branches); manifests are per-branch
    # ------------------------------------------------------------------
    @staticmethod
    def _atomic_write(path: str, data: bytes, durable: bool) -> None:
        """tmp + rename, os-level: this sits on the branch-create hot
        path where pathlib/TextIOWrapper overhead alone is ~40µs."""
        tmp = path + ".tmp"
        fd = os.open(tmp, os.O_WRONLY | os.O_CREAT | os.O_TRUNC, 0o644)
        try:
            os.write(fd, data)
            if durable:
                # durability point: only commits fsync (fsync elision)
                os.fsync(fd)
        finally:
            os.close(fd)
        os.replace(tmp, path)

    def _log(self) -> int:
        if self._log_fd is None:
            self._log_fd = os.open(str(self._log_path),
                                   os.O_WRONLY | os.O_CREAT | os.O_APPEND,
                                   0o644)
        return self._log_fd

    def _load_tree(self) -> Optional[Dict[str, Any]]:
        """Recover the branch graph: compacted ``tree.json`` plus any
        newer full-tree lines journaled since (highest ``seq`` wins; a
        torn final line — crash mid-append — parses as garbage and is
        skipped, falling back to the previous line)."""
        tree: Optional[Dict[str, Any]] = None
        if self._tree_path.exists():
            tree = json.loads(self._tree_path.read_text())
        if self._log_path.exists():
            for line in self._log_path.read_bytes().splitlines():
                try:
                    cand = json.loads(line)
                except ValueError:
                    continue
                if tree is None or cand.get("seq", 0) >= tree.get("seq", 0):
                    tree = cand
        return tree

    def _persist_tree(self, durable: bool = False) -> None:
        """Journal-append (cheap, one ``write(2)`` on an open fd) for
        ephemeral mutations; compact + fsync + truncate the journal at
        durability points.  Branch *creation* therefore costs one log
        append, not a rewrite of the whole graph file — the paper's
        <350µs creation bar with room to spare."""
        self._tree["seq"] = self._tree.get("seq", 0) + 1
        data = json.dumps(self._tree, separators=(",", ":")).encode()
        if not durable:
            os.write(self._log(), data + b"\n")
            return
        # durability point (commit): compacted tree is fsynced first,
        # then the journal is emptied — a crash in between leaves stale
        # log lines whose lower seq loses to the compacted file
        self._atomic_write(str(self._tree_path), data, True)
        os.ftruncate(self._log(), 0)
        os.fsync(self._log_fd)

    def close(self) -> None:
        if self._log_fd is not None:
            try:
                os.close(self._log_fd)
            except OSError:
                pass
            self._log_fd = None

    def __del__(self):   # pragma: no cover - interpreter teardown order
        try:
            self.close()
        # interpreter teardown: module globals (os, json) may already be
        # gone, so even the narrowed close() can fail arbitrarily here
        except Exception:   # branchlint: ignore[BL001]
            pass

    def _delta_path(self, name: str) -> Path:
        return self._delta_dir / f"{self._branch(name)['delta_id']}.json"

    def _delta(self, name: str) -> Dict[str, str]:
        if name not in self._deltas:
            p = self._delta_path(name)
            self._deltas[name] = (json.loads(p.read_text())
                                  if p.exists() else {})
        return self._deltas[name]

    def _persist_delta(self, name: str, durable: bool = False) -> None:
        b = self._branch(name)
        path = self._delta_dir / f"{b['delta_id']}.json"
        if not self._deltas.get(name) and not path.exists():
            # an empty manifest with no file on disk is already its own
            # persisted form (_delta() reads a missing file as {}), so
            # create() costs one tree write, not one file per branch
            return
        self._atomic_write(str(path),
                           json.dumps(self._deltas.get(name, {})).encode(),
                           durable)

    # ------------------------------------------------------------------
    def _branch(self, name: str) -> Dict[str, Any]:
        try:
            return self._tree["branches"][name]
        except KeyError:
            raise BranchStateError(f"unknown branch {name!r}") from None

    def _check_live(self, name: str) -> Dict[str, Any]:
        b = self._branch(name)
        if b["status"] == "stale":
            raise StaleBranchError(f"branch {name} is stale (-ESTALE)")
        if b["status"] != "active":
            raise BranchStateError(f"branch {name} is {b['status']}")
        parent = b["parent"]
        if parent is not None:
            p = self._branch(parent)
            if p["epoch"] != b["fork_epoch"]:
                b["status"] = "stale"
                self._persist_tree()
                raise StaleBranchError(f"branch {name} is stale (-ESTALE)")
        return b

    def _chain(self, name: str) -> Iterator[str]:
        cur: Optional[str] = name
        while cur is not None:
            yield cur
            cur = self._branch(cur)["parent"]

    def _live_children(self, b: Dict[str, Any]) -> List[str]:
        return [
            c
            for c in b["children"]
            if self._tree["branches"][c]["status"] == "active"
        ]

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------
    def create(self, parent: str = BASE, name: Optional[str] = None,
               n: int = 1) -> List[str]:
        """Create ``n`` sibling branches from ``parent``.  O(1) each."""
        with self._lock:
            p = self._branch(parent)
            if p["status"] not in ("active", "committed"):
                raise BranchStateError(f"cannot fork {parent}: {p['status']}")
            names: List[str] = []
            for i in range(n):
                if name is not None and n == 1:
                    bname = name
                else:
                    bname = f"{name or 'b'}{self._tree['next_id']}"
                if bname in self._tree["branches"]:
                    raise BranchStateError(f"branch {bname!r} exists")
                did = self._tree["next_id"]
                self._tree["next_id"] += 1
                self._tree["branches"][bname] = {
                    "parent": parent,
                    "status": "active",
                    "epoch": 0,
                    "fork_epoch": p["epoch"],
                    "children": [],
                    "delta_id": did,
                }
                self._deltas[bname] = {}
                p["children"].append(bname)
                names.append(bname)
                self._persist_delta(bname)
            self._persist_tree()
            return names

    def commit(self, name: str) -> str:
        """Atomic commit-to-parent with first-commit-wins (§4.3)."""
        with self._lock:
            t0 = time.perf_counter_ns()
            b = self._check_live(name)
            if self._live_children(b):
                raise BranchStateError(
                    f"branch {name} has live children; resolve them first"
                )
            parent_name = b["parent"]
            if parent_name is None:
                raise BranchStateError("base branch cannot commit")
            p = self._branch(parent_name)
            delta = self._delta(name)
            pdelta = self._delta(parent_name)
            # tombstones first (deletions), then modifications (§4.3)
            drop: List[str] = []
            for path, cid in delta.items():
                if cid == _TOMB:
                    if p["parent"] is None:
                        old = pdelta.pop(path, None)
                        if old and old != _TOMB:
                            drop.append(old)
                    else:
                        old = pdelta.get(path)
                        if old and old != _TOMB:
                            drop.append(old)
                        pdelta[path] = _TOMB
            for path, cid in delta.items():
                if cid != _TOMB:
                    old = pdelta.get(path)
                    if old and old != _TOMB:
                        drop.append(old)
                    pdelta[path] = cid  # ref transfers child -> parent
            self._deltas[name] = {}
            b["status"] = "committed"
            p["epoch"] += 1  # invalidate siblings
            for sib_name in p["children"]:
                sib = self._tree["branches"][sib_name]
                if sib_name != name and sib["status"] == "active":
                    self._invalidate(sib_name)
            self._persist_delta(name)
            self._persist_delta(parent_name, durable=True)
            self._persist_tree(durable=True)  # the durability point
            if drop:
                self.chunks.decref(drop)
            self._c_commits.inc()
            self._h_commit_us.observe(
                (time.perf_counter_ns() - t0) / 1000.0)
            return parent_name

    def abort(self, name: str) -> None:
        with self._lock:
            b = self._branch(name)
            if b["status"] == "stale":
                return
            if b["status"] != "active":
                raise BranchStateError(f"branch {name} is {b['status']}")
            self._invalidate(name, status="aborted")
            self._persist_tree()

    def _invalidate(self, name: str, status: str = "stale") -> None:
        b = self._tree["branches"][name]
        for child in b["children"]:
            if self._tree["branches"][child]["status"] == "active":
                self._invalidate(child)
        delta = self._delta(name)
        dead = [cid for cid in delta.values() if cid != _TOMB]
        self._deltas[name] = {}
        b["status"] = status
        self._persist_delta(name)
        if dead:
            self.chunks.decref(dead)

    # ------------------------------------------------------------------
    # namespace ops (supports @branch paths, §4.4)
    # ------------------------------------------------------------------
    @staticmethod
    def _split(path: str, default_branch: str) -> Tuple[str, str]:
        if path.startswith("@"):
            branch, _, rest = path[1:].partition("/")
            return branch, rest
        return default_branch, path

    def _inherited(self, branch: str, path: str) -> bool:
        """Whether ``path`` resolves through an ancestor's delta layer."""
        first = True
        for level in self._chain(branch):
            if first:
                first = False
                continue
            delta = self._delta(level)
            if path in delta:
                return delta[path] != _TOMB
        return False

    def write(self, branch: str, path: str, data: bytes) -> None:
        branch, path = self._split(path, branch)
        with self._lock:
            b = self._check_live(branch)
            if self._live_children(b):
                raise FrozenOriginError(f"branch {branch} is frozen")
            delta = self._delta(branch)
            self._c_writes.inc()
            if (path not in delta and b["parent"] is not None
                    and self._inherited(branch, path)):
                # first write to an inherited path: this branch breaks
                # sharing with its ancestors — the FS-layer CoW fault
                self._c_cow_faults.inc()
            cid = self.chunks.put(data)
            self._g_materialized.set(self.chunks.materialized)
            old = delta.get(path)
            delta[path] = cid
            self._persist_delta(branch)  # no fsync: ephemeral until commit
            if old and old != _TOMB:
                self.chunks.decref([old])

    def read(self, branch: str, path: str = "") -> bytes:
        branch, path = self._split(path, branch)
        with self._lock:
            b = self._branch(branch)
            if b["status"] == "active":
                self._check_live(branch)
            elif b["status"] == "stale":
                raise StaleBranchError(f"branch {branch} is stale")
            for level in self._chain(branch):
                delta = self._delta(level)
                if path in delta:
                    cid = delta[path]
                    if cid == _TOMB:
                        raise NoSuchLeafError(path)
                    return self.chunks.get(cid)
            raise NoSuchLeafError(path)

    def delete(self, branch: str, path: str) -> None:
        branch, path = self._split(path, branch)
        with self._lock:
            b = self._check_live(branch)
            if self._live_children(b):
                raise FrozenOriginError(f"branch {branch} is frozen")
            if not self.exists(branch, path):
                raise NoSuchLeafError(path)
            delta = self._delta(branch)
            old = delta.get(path)
            delta[path] = _TOMB
            self._persist_delta(branch)
            if old and old != _TOMB:
                self.chunks.decref([old])

    def exists(self, branch: str, path: str) -> bool:
        try:
            self.read(branch, path)
            return True
        except NoSuchLeafError:
            return False

    def listdir(self, branch: str) -> List[str]:
        with self._lock:
            self._branch(branch)
            seen: Dict[str, bool] = {}
            for level in self._chain(branch):
                for path, cid in self._delta(level).items():
                    if path not in seen:
                        seen[path] = cid != _TOMB
            return sorted(p for p, alive in seen.items() if alive)

    # ------------------------------------------------------------------
    def status(self, branch: str) -> str:
        with self._lock:
            b = self._branch(branch)
            if b["status"] == "active" and b["parent"] is not None:
                p = self._branch(b["parent"])
                if p["epoch"] != b["fork_epoch"]:
                    b["status"] = "stale"
                    self._persist_tree()
            return b["status"]

    def epoch(self, branch: str) -> int:
        return self._branch(branch)["epoch"]

    def delta_paths(self, branch: str) -> List[str]:
        return sorted(self._delta(branch))

    def branches(self) -> List[str]:
        return sorted(self._tree["branches"])
