"""ServeEngine — branchable paged-KV engine (device step + state domains).

The paper's serving workload as a first-class engine feature:

* KV lives in fixed-size **pages** ([L, n_pages, page, kv, hd] pools);
  sequences hold block tables managed by :class:`KVBranchManager`.
* ``fork(seq, n)`` creates N generation branches sharing every page
  (CoW); the first append to a shared tail page triggers a single-page
  device copy (the CoW fault).  All pending CoW faults of a decode step
  are serviced by **one** fused ``_copy_pages`` dispatch, not one jit
  call per page.
* ``commit(branch)`` promotes the branch into its parent and invalidates
  siblings, whose pages are recycled — first-commit-wins.
* nesting: branches fork sub-branches (Tree-of-Thoughts style).
* decode runs the **paged-attention** path per layer (Pallas kernel on
  TPU; the jnp gather oracle on CPU — same math).

The engine does not implement a branch lifecycle of its own: its host
token tails are a :class:`TokenDomain` attached to the KV manager's
:class:`~repro.core.lifecycle.BranchTree`, so one kernel-level
``commit``/``abort``/invalidation resolves pages *and* tokens atomically
— a raced commit can no longer strand token tails (DESIGN §2).

Admission, continuous batching and fork admission live in
:mod:`repro.runtime.scheduler`; this module is only the device step plus
the per-sequence state domains.

Only attention-family archs use paged KV; SSM archs branch their
recurrent state through the BranchStore instead (DESIGN §6).
"""

from __future__ import annotations

from functools import partial
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import KVBranchManager
from repro.kernels.paged_attention.ops import paged_attention
from repro.models import layers as L
from repro.models.model import Model
from repro.models.transformer import embed_tokens, lm_head


# ---------------------------------------------------------------------------
# jitted paged decode step (dense/moe families)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("cfg", "impl"))
def paged_decode_step(
    cfg: ArchConfig,
    params: Any,
    k_pages: jax.Array,       # [L, n_pages, page, kv, hd]
    v_pages: jax.Array,
    block_tables: jax.Array,  # [b, max_pages]
    lengths: jax.Array,       # [b] length BEFORE this token
    slot_pages: jax.Array,    # [b] page for this token's KV
    slot_offsets: jax.Array,  # [b] offset within that page
    tokens: jax.Array,        # [b, 1]
    impl: str = "ref",
):
    """One decode step over paged KV.  Returns (logits, k_pages, v_pages)."""
    b = tokens.shape[0]
    kvh, g = cfg.num_kv_heads, cfg.num_heads // cfg.num_kv_heads
    h = embed_tokens(cfg, params, tokens)

    def body(h, xs):
        lp, kp, vp = xs
        x = L.rms_norm(h, lp["ln1"], cfg.norm_eps)
        q, k, v = L.qkv_project(cfg, lp["attn"], x, lengths[:, None])
        # write this token's K/V into its (possibly CoW'd) page slot
        kp = kp.at[slot_pages, slot_offsets].set(k[:, 0])
        vp = vp.at[slot_pages, slot_offsets].set(v[:, 0])
        qh = q.reshape(b, kvh, g, cfg.head_dim)
        a = paged_attention(qh, kp, vp, block_tables, lengths + 1,
                            impl=impl)
        a = a.reshape(b, 1, cfg.num_heads, cfg.head_dim)
        h = h + jnp.einsum("bshk,hkd->bsd", a, lp["attn"]["wo"])
        x = L.rms_norm(h, lp["ln2"], cfg.norm_eps)
        if cfg.is_moe:
            from repro.models.moe import moe_block

            m, _ = moe_block(cfg, lp["moe"], x)
        else:
            m = L.mlp_block(cfg, lp["mlp"], x)
        return h + m, (kp, vp)

    h, (k_pages, v_pages) = jax.lax.scan(
        body, h, (params["layers"], k_pages, v_pages))
    h = L.rms_norm(h, params["final_norm"], cfg.norm_eps)
    return lm_head(cfg, params, h), k_pages, v_pages


@partial(jax.jit, donate_argnums=(0, 1))
def _copy_pages(k_pages: jax.Array, v_pages: jax.Array,
                src: jax.Array, dst: jax.Array):
    """Batched CoW fault service: pages[:, src] -> pages[:, dst].

    ``src``/``dst`` are int32 vectors covering *every* pending CoW op of
    a decode step, so the whole batch costs one device dispatch.  The
    gather reads the pre-copy pool, so a page freed by one fault and
    reallocated as another fault's destination still copies the right
    bytes; destination indices are unique (each is freshly allocated) or
    duplicated only as identical padding pairs.
    """
    return (k_pages.at[:, dst].set(k_pages[:, src]),
            v_pages.at[:, dst].set(v_pages[:, src]))


def _pad_pow2(src: List[int], dst: List[int]) -> tuple:
    """Pad the CoW op list to a power-of-two bucket to bound recompiles.

    Padding repeats the last real (src, dst) pair: duplicate scatter
    indices then carry identical payloads, which is deterministic.
    """
    n = len(src)
    m = 1
    while m < n:
        m *= 2
    src = src + [src[-1]] * (m - n)
    dst = dst + [dst[-1]] * (m - n)
    return jnp.asarray(src, jnp.int32), jnp.asarray(dst, jnp.int32)


# ---------------------------------------------------------------------------
# token tails as a lifecycle domain
# ---------------------------------------------------------------------------

class TokenDomain:
    """Host token tails plugged into the branch-lifecycle kernel.

    The serving analogue of the paper's process-group domain: each live
    sequence owns its generated-token list, and the kernel's hooks move
    ownership on fork (copy), commit (child's tail replaces the
    parent's) and abort/invalidate (tail dropped) — so losers of a
    first-commit-wins race can never strand their tails.
    """

    def __init__(self) -> None:
        self._tokens: Dict[int, List[int]] = {}

    # -- BranchDomain hooks (called under the tree lock) ----------------
    def on_fork(self, parent: int, children: List[int]) -> None:
        base = self._tokens.get(parent)
        if base is not None:
            for c in children:
                self._tokens[c] = list(base)

    def on_commit(self, child: int, parent: int) -> None:
        if child in self._tokens:
            self._tokens[parent] = self._tokens.pop(child)

    def on_abort(self, branch: int) -> None:
        self._tokens.pop(branch, None)

    def on_invalidate(self, branch: int) -> None:
        self._tokens.pop(branch, None)

    def on_reap(self, branch: int) -> None:
        self._tokens.pop(branch, None)

    # -- accessors -------------------------------------------------------
    def seed(self, seq: int, tokens: Sequence[int]) -> None:
        self._tokens[seq] = list(tokens)

    def get(self, seq: int) -> List[int]:
        return self._tokens[seq]

    def append(self, seq: int, token: int) -> None:
        self._tokens[seq].append(token)

    def truncate(self, seq: int, n_tokens: int) -> None:
        del self._tokens[seq][n_tokens:]

    def __contains__(self, seq: int) -> bool:
        return seq in self._tokens

    def __len__(self) -> int:
        return len(self._tokens)


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------

class ServeEngine:
    def __init__(self, model: Model, params: Any, *, num_pages: int = 256,
                 page_size: int = 16, max_pages_per_seq: int = 32,
                 attn_impl: str = "ref"):
        cfg = model.cfg
        assert cfg.family in ("dense", "vlm", "audio", "moe"), (
            "paged-KV serving targets attention archs; SSM archs branch "
            "their recurrent state via BranchStore (DESIGN §6)")
        self.model = model
        self.cfg = cfg
        self.params = params
        self.kv = KVBranchManager(num_pages=num_pages, page_size=page_size)
        self.page_size = page_size
        self.max_pages = max_pages_per_seq
        self.attn_impl = attn_impl
        dt = jnp.dtype(cfg.dtype)
        shape = (cfg.num_layers, num_pages, page_size, cfg.num_kv_heads,
                 cfg.head_dim)
        self.k_pages = jnp.zeros(shape, dt)
        self.v_pages = jnp.zeros(shape, dt)
        # Token tails ride the same lifecycle kernel as the page tables:
        # kv.commit/abort/invalidate resolves both domains atomically.
        self.token_domain = TokenDomain()
        self.kv.tree.attach(self.token_domain)
        # CoW fault-service instrumentation (benchmarks read these)
        self.cow_dispatches = 0   # fused _copy_pages device calls
        self.cow_faults = 0       # individual page copies serviced

    # ------------------------------------------------------------------
    def add_request(self, prompt: Sequence[int]) -> int:
        """Prefill a prompt into a fresh paged sequence.

        Invariant: ``kv.length == len(tokens) - 1`` — the last token is
        "pending": its KV is written by the decode step that consumes it.
        """
        prompt = list(prompt)
        assert prompt, "empty prompt"
        n_cached = len(prompt) - 1
        sid = self.kv.new_seq(length=n_cached)
        if n_cached:
            toks = jnp.asarray(prompt[:-1], jnp.int32)[None]
            # dense prefill, then scatter the cache into this seq's pages
            _, cache = self.model.prefill(self.params, toks)
            table = self.kv.block_table(sid)
            k = cache["k"][:, 0]      # [L, s, kv, hd]
            v = cache["v"][:, 0]
            for pi, page in enumerate(table):
                lo = pi * self.page_size
                hi = min(lo + self.page_size, n_cached)
                self.k_pages = self.k_pages.at[:, page, : hi - lo].set(
                    k[:, lo:hi])
                self.v_pages = self.v_pages.at[:, page, : hi - lo].set(
                    v[:, lo:hi])
        self.token_domain.seed(sid, prompt)
        return sid

    # ------------------------------------------------------------------
    # branch ops (the paper's lifecycle, resolved by the shared kernel)
    # ------------------------------------------------------------------
    def fork(self, seq: int, n: int, *, eager_cow: bool = False) -> List[int]:
        """Fork ``n`` branches (token tails copied by the lifecycle hook).

        With ``eager_cow`` the shared-tail copy-on-write every child
        would fault at its first append is hoisted into the fork itself
        and serviced as ONE fused ``_copy_pages`` dispatch for the whole
        sibling set (``KVBranchManager.fork_batch``) — the vectorized
        ``branch(parent, n=k)`` hot path of ``repro.api``.  The default
        stays lazy so a fork that never decodes remains zero-copy.
        """
        if not eager_cow:
            return self.kv.fork(seq, n)
        children, ops = self.kv.fork_batch(seq, n)
        if ops:
            self._service_cow([op.src_page for op in ops],
                              [op.dst_page for op in ops])
        return children

    def commit(self, seq: int) -> int:
        return self.kv.commit(seq)    # tokens + pages promoted atomically

    def abort(self, seq: int) -> None:
        self.kv.abort(seq)

    def release(self, seq: int) -> None:
        """Evict a finished/abandoned sequence, freeing every domain."""
        self.kv.release(seq)

    def truncate(self, seq: int, n_tokens: int) -> None:
        """Keep only the first ``n_tokens`` tokens of a sequence.

        The speculative-decoding primitive: a draft branch commits its
        verified prefix by dropping the unverified suffix first.  Both
        domains shrink together, preserving ``kv.length == tokens - 1``
        (the last retained token becomes the pending one).
        """
        if n_tokens < 1:
            raise ValueError("cannot truncate below one token")
        self.kv.truncate(seq, n_tokens - 1)
        self.token_domain.truncate(seq, n_tokens)

    # ------------------------------------------------------------------
    def _service_cow(self, src: List[int], dst: List[int]) -> None:
        """Service all pending CoW faults in one fused device dispatch."""
        s, d = _pad_pow2(src, dst)
        self.k_pages, self.v_pages = _copy_pages(
            self.k_pages, self.v_pages, s, d)
        self.cow_dispatches += 1
        self.cow_faults += len(src)

    def decode(self, seq_ids: Sequence[int], *, greedy: Any = True,
               temperature: Any = 1.0,
               key: Optional[jax.Array] = None) -> List[int]:
        """One token for each sequence (they decode as one batch).

        ``greedy`` and ``temperature`` may be scalars (whole batch) or
        per-sequence lists, so one continuous batch can mix greedy
        verification branches with sampled exploration branches at
        different temperatures — the exploration driver multiplexes many
        policies' decode work into a single device dispatch.
        """
        b = len(seq_ids)
        # resolve sampling rows BEFORE any metadata mutates: a mis-sized
        # per-sequence list must fail cleanly, not after slots were
        # reserved and the device step ran
        greedy_row = ([bool(greedy)] * b if isinstance(greedy, (bool, int))
                      else [bool(g) for g in greedy])
        temp_row = ([float(temperature)] * b
                    if isinstance(temperature, (int, float))
                    else [float(t) for t in temperature])
        if len(greedy_row) != b or len(temp_row) != b:
            raise ValueError("per-sequence sampling rows must match batch")
        lengths_before = np.array([self.kv.length(s) for s in seq_ids],
                                  np.int32)
        # refuse BEFORE mutating metadata if any sequence's table would
        # outgrow the per-sequence limit (dense_block_tables would raise
        # only after the batch's slots were already reserved)
        for s, ln in zip(seq_ids, lengths_before):
            if int(ln) // self.page_size + 1 > self.max_pages:
                raise ValueError(
                    f"sequence {s} would need "
                    f"{int(ln) // self.page_size + 1} pages > "
                    f"{self.max_pages} (max_pages_per_seq)")
        # host: reserve slots transactionally — if the pool exhausts on a
        # later batch member, earlier members' tables/lengths/CoW swaps
        # are rolled back before the MemoryError propagates, so a decode
        # step either runs for the whole batch or mutates nothing
        slot_lists = self.kv.prepare_append_batch(seq_ids, 1)
        slots = [sl[0] for sl in slot_lists]
        cow_src: List[int] = []
        cow_dst: List[int] = []
        for slot in slots:
            for cow in slot.cow:
                cow_src.append(cow.src_page)
                cow_dst.append(cow.dst_page)
        if cow_src:
            self._service_cow(cow_src, cow_dst)
        bt, _ = self.kv.dense_block_tables(seq_ids, self.max_pages)
        last_tokens = jnp.asarray(
            [[self.token_domain.get(s)[-1]] for s in seq_ids], jnp.int32)

        logits, self.k_pages, self.v_pages = paged_decode_step(
            self.cfg, self.params, self.k_pages, self.v_pages,
            jnp.asarray(bt), jnp.asarray(lengths_before),
            jnp.asarray([sl.page for sl in slots], jnp.int32),
            jnp.asarray([sl.offset for sl in slots], jnp.int32),
            last_tokens, impl=self.attn_impl,
        )
        logits = logits[:, 0]
        if all(greedy_row):
            nxt = jnp.argmax(logits, axis=-1)
        else:
            assert key is not None
            temps = jnp.asarray(temp_row, jnp.float32)
            sampled = jax.random.categorical(key, logits / temps[:, None])
            nxt = jnp.where(jnp.asarray(greedy_row),
                            jnp.argmax(logits, axis=-1), sampled)
        out = [int(t) for t in np.asarray(nxt)]
        for s, t in zip(seq_ids, out):
            self.token_domain.append(s, t)
        return out

    def tokens(self, seq: int) -> List[int]:
        return list(self.token_domain.get(seq))

    def stats(self) -> Dict[str, int]:
        st = self.kv.stats()
        st["token_tails"] = len(self.token_domain)
        st["cow_dispatches"] = self.cow_dispatches
        st["cow_faults"] = self.cow_faults
        return st
