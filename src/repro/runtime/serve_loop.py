"""ServeEngine — branchable paged-KV engine (device step + state domains).

The paper's serving workload as a first-class engine feature:

* KV lives in fixed-size **pages** ([L, n_pages, page, kv, hd] pools);
  sequences hold block tables managed by :class:`KVBranchManager`.
* ``fork(seq, n)`` creates N generation branches sharing every page
  (CoW); the first append to a shared tail page triggers a single-page
  device copy (the CoW fault).  All pending CoW faults of a decode step
  are serviced by **one** fused ``_copy_pages`` dispatch, not one jit
  call per page.
* ``commit(branch)`` promotes the branch into its parent and invalidates
  siblings, whose pages are recycled — first-commit-wins.
* nesting: branches fork sub-branches (Tree-of-Thoughts style).
* decode runs the **paged-attention** path per layer (Pallas kernel on
  TPU; the jnp gather oracle on CPU — same math).

The engine does not implement a branch lifecycle of its own: its host
token tails are a :class:`TokenDomain` attached to the KV manager's
:class:`~repro.core.lifecycle.BranchTree`, so one kernel-level
``commit``/``abort``/invalidation resolves pages *and* tokens atomically
— a raced commit can no longer strand token tails (DESIGN §2).

Admission, continuous batching and fork admission live in
:mod:`repro.runtime.scheduler`; this module is only the device step plus
the per-sequence state domains.

**Sharded serving** (DESIGN §11): constructing the engine with ``tp=``
or ``mesh=`` rebases the hot loop onto a tensor-parallel device mesh —
weights shard per the training rules (heads / d_ff / experts over the
tp axis), the KV pools shard on the **kv-head dim**, and the decode
step runs under one compat-shimmed ``shard_map`` so a step is still one
device dispatch.  All branch bookkeeping (block tables, refcounts, the
lifecycle tree, token tails) is host-side integer metadata and stays
replicated/device-agnostic; fork/commit cost does not change with mesh
size.  Unset, behavior is exactly the single-device path.

Only attention-family archs use paged KV; SSM archs branch their
recurrent state through the BranchStore instead (DESIGN §6).
"""

from __future__ import annotations

from functools import partial
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.core import KVBranchManager
from repro.distributed.compat import shard_map
from repro.distributed.mesh import ParallelPlan, serving_mesh, serving_plan
from repro.distributed.sharding import kv_page_spec, serve_param_specs
from repro.kernels.paged_attention.ops import paged_attention
from repro.models import layers as L
from repro.models.model import Model
from repro.models.transformer import embed_tokens, lm_head


# ---------------------------------------------------------------------------
# paged decode step (dense/moe families) — one body, two bindings:
# the single-device jit and the shard_map'd tensor-parallel step
# ---------------------------------------------------------------------------

def _decode_body(
    cfg: ArchConfig,
    params: Any,
    k_pages: jax.Array,       # [L, n_pages, page, kv(_local), hd]
    v_pages: jax.Array,
    block_tables: jax.Array,  # [b, max_pages]
    lengths: jax.Array,       # [b] length BEFORE this token
    slot_pages: jax.Array,    # [b] page for this token's KV
    slot_offsets: jax.Array,  # [b] offset within that page
    tokens: jax.Array,        # [b, 1]
    *,
    impl: str,
    axis_name: Optional[str] = None,
):
    """One decode step over paged KV.  Returns (logits, k_pages, v_pages).

    With ``axis_name`` the body runs *shard-local* under ``shard_map``:
    weights arrive as tensor-parallel slices (heads / kv heads / d_ff /
    experts over the axis), the KV pools carry only the local kv-head
    slice, and the two contractions whose reduction dim is sharded
    (attention output over heads, MLP/MoE down-projection) psum across
    the axis.  Block tables, lengths and slots are replicated — page
    ids mean the same thing on every shard, so the host-side CoW
    bookkeeping is mesh-agnostic.
    """
    b = tokens.shape[0]
    h = embed_tokens(cfg, params, tokens)

    def combine(x):
        return jax.lax.psum(x, axis_name) if axis_name else x

    def body(h, xs):
        lp, kp, vp = xs
        x = L.rms_norm(h, lp["ln1"], cfg.norm_eps)
        q, k, v = L.qkv_project(cfg, lp["attn"], x, lengths[:, None])
        # write this token's K/V into its (possibly CoW'd) page slot
        kp = kp.at[slot_pages, slot_offsets].set(k[:, 0])
        vp = vp.at[slot_pages, slot_offsets].set(v[:, 0])
        # heads are kv-major (head = kv * g + g_idx), so a contiguous
        # head shard is a contiguous kv-head shard: local shapes fall
        # out of the projection weights
        kvh = k.shape[2]
        g = q.shape[2] // kvh
        qh = q.reshape(b, kvh, g, cfg.head_dim)
        a = paged_attention(qh, kp, vp, block_tables, lengths + 1,
                            impl=impl)
        a = a.reshape(b, 1, kvh * g, cfg.head_dim)
        h = h + combine(jnp.einsum("bshk,hkd->bsd", a, lp["attn"]["wo"]))
        x = L.rms_norm(h, lp["ln2"], cfg.norm_eps)
        if cfg.is_moe:
            from repro.models.moe import moe_apply_local, moe_block

            if axis_name is None:
                m, _ = moe_block(cfg, lp["moe"], x)
            else:
                # expert-parallel slice of the MoE FFN; the EP combine
                # is the same psum a TP MLP needs (DESIGN §5)
                mp = lp["moe"]
                e_loc = mp["wu"].shape[0]
                e0 = (jax.lax.axis_index(axis_name) * e_loc).astype(
                    jnp.int32)
                y, _ = moe_apply_local(
                    cfg, x.reshape(-1, cfg.d_model), mp["router"],
                    mp.get("wg"), mp["wu"], mp["wd"], e0)
                m = combine(y).reshape(b, 1, cfg.d_model)
        else:
            m = combine(L.mlp_block(cfg, lp["mlp"], x))
        return h + m, (kp, vp)

    h, (k_pages, v_pages) = jax.lax.scan(
        body, h, (params["layers"], k_pages, v_pages))
    h = L.rms_norm(h, params["final_norm"], cfg.norm_eps)
    return lm_head(cfg, params, h), k_pages, v_pages


@partial(jax.jit, static_argnames=("cfg", "impl"))
def paged_decode_step(
    cfg: ArchConfig,
    params: Any,
    k_pages: jax.Array,       # [L, n_pages, page, kv, hd]
    v_pages: jax.Array,
    block_tables: jax.Array,  # [b, max_pages]
    lengths: jax.Array,       # [b] length BEFORE this token
    slot_pages: jax.Array,    # [b] page for this token's KV
    slot_offsets: jax.Array,  # [b] offset within that page
    tokens: jax.Array,        # [b, 1]
    impl: str = "ref",
):
    """One decode step over paged KV (single device)."""
    return _decode_body(cfg, params, k_pages, v_pages, block_tables,
                        lengths, slot_pages, slot_offsets, tokens,
                        impl=impl)


def serve_specs(cfg: ArchConfig, plan: ParallelPlan, params: Any) -> Any:
    """The engine's parameter spec tree (training rules retargeted to
    the serving tp axis).  Multi-codebook heads keep their vocab dim
    replicated: the ``[b, s, cb, V]`` reshape inside ``lm_head`` needs
    the full codebook-major vocab on every shard."""
    specs = serve_param_specs(cfg, plan, params)
    if cfg.num_codebooks > 1 and "lm_head" in specs:
        specs["lm_head"] = P(*(None,) * params["lm_head"].ndim)
    return specs


def build_tp_decode_step(cfg: ArchConfig, plan: ParallelPlan, params: Any,
                         *, impl: str = "ref",
                         specs: Optional[Any] = None):
    """The tensor-parallel decode step: ``_decode_body`` under ONE
    compat-shimmed ``shard_map`` so a whole fork/explore/commit step
    still costs one device dispatch.

    Weights and KV pages arrive pre-sharded (the engine places them at
    construction); block tables / lengths / slots / tokens replicate.
    Logits leave replicated — a vocab-sharded head is all-gathered
    *inside* the mapped function so sampling stays mesh-agnostic.
    """
    if specs is None:
        specs = serve_specs(cfg, plan, params)
    lm_spec = specs.get("lm_head")
    gather_logits = lm_spec is not None and plan.tp_axis in tuple(lm_spec)
    kv_spec = kv_page_spec(plan)
    rep = P()

    def local_step(p, kp, vp, bt, lengths, slot_pages, slot_offsets,
                   tokens):
        logits, kp, vp = _decode_body(
            cfg, p, kp, vp, bt, lengths, slot_pages, slot_offsets,
            tokens, impl=impl, axis_name=plan.tp_axis)
        if gather_logits:
            logits = jax.lax.all_gather(
                logits, plan.tp_axis, axis=logits.ndim - 1, tiled=True)
        return logits, kp, vp

    fn = shard_map(
        local_step, mesh=plan.mesh,
        in_specs=(specs, kv_spec, kv_spec, rep, rep, rep, rep, rep),
        out_specs=(rep, kv_spec, kv_spec),
        check_rep=False,
    )
    return jax.jit(fn)


@partial(jax.jit, donate_argnums=(0, 1))
def _copy_pages(k_pages: jax.Array, v_pages: jax.Array,
                src: jax.Array, dst: jax.Array):
    """Batched CoW fault service: pages[:, src] -> pages[:, dst].

    ``src``/``dst`` are int32 vectors covering *every* pending CoW op of
    a decode step, so the whole batch costs one device dispatch.  The
    gather reads the pre-copy pool, so a page freed by one fault and
    reallocated as another fault's destination still copies the right
    bytes; destination indices are unique (each is freshly allocated) or
    duplicated only as identical padding pairs.
    """
    return (k_pages.at[:, dst].set(k_pages[:, src]),
            v_pages.at[:, dst].set(v_pages[:, src]))


def _pad_pow2(src: List[int], dst: List[int]) -> tuple:
    """Pad the CoW op list to a power-of-two bucket to bound recompiles.

    Padding repeats the last real (src, dst) pair: duplicate scatter
    indices then carry identical payloads, which is deterministic.
    """
    n = len(src)
    m = 1
    while m < n:
        m *= 2
    src = src + [src[-1]] * (m - n)
    dst = dst + [dst[-1]] * (m - n)
    return jnp.asarray(src, jnp.int32), jnp.asarray(dst, jnp.int32)


# ---------------------------------------------------------------------------
# token tails as a lifecycle domain
# ---------------------------------------------------------------------------

class TokenDomain:
    """Host token tails plugged into the branch-lifecycle kernel.

    The serving analogue of the paper's process-group domain: each live
    sequence owns its generated-token list, and the kernel's hooks move
    ownership on fork (copy), commit (child's tail replaces the
    parent's) and abort/invalidate (tail dropped) — so losers of a
    first-commit-wins race can never strand their tails.
    """

    def __init__(self) -> None:
        self._tokens: Dict[int, List[int]] = {}

    # -- BranchDomain hooks (called under the tree lock) ----------------
    def on_fork(self, parent: int, children: List[int]) -> None:
        base = self._tokens.get(parent)
        if base is not None:
            for c in children:
                self._tokens[c] = list(base)

    def on_commit(self, child: int, parent: int) -> None:
        if child in self._tokens:
            self._tokens[parent] = self._tokens.pop(child)

    def on_abort(self, branch: int) -> None:
        self._tokens.pop(branch, None)

    def on_invalidate(self, branch: int) -> None:
        self._tokens.pop(branch, None)

    def on_reap(self, branch: int) -> None:
        self._tokens.pop(branch, None)

    # -- accessors -------------------------------------------------------
    def seed(self, seq: int, tokens: Sequence[int]) -> None:
        self._tokens[seq] = list(tokens)

    def get(self, seq: int) -> List[int]:
        return self._tokens[seq]

    def append(self, seq: int, token: int) -> None:
        self._tokens[seq].append(token)

    def truncate(self, seq: int, n_tokens: int) -> None:
        del self._tokens[seq][n_tokens:]

    def __contains__(self, seq: int) -> bool:
        return seq in self._tokens

    def __len__(self) -> int:
        return len(self._tokens)


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------

class ServeEngine:
    def __init__(self, model: Model, params: Any, *, num_pages: int = 256,
                 page_size: int = 16, max_pages_per_seq: int = 32,
                 attn_impl: str = "ref", mesh: Optional[Mesh] = None,
                 tp: Optional[int] = None):
        cfg = model.cfg
        assert cfg.family in ("dense", "vlm", "audio", "moe"), (
            "paged-KV serving targets attention archs; SSM archs branch "
            "their recurrent state via BranchStore (DESIGN §6)")
        self.model = model
        self.cfg = cfg
        # --- serving mesh (tensor-parallel decode) --------------------
        # `tp=`/`mesh=` shard the hot loop; unset keeps the exact
        # single-device path.  Branch bookkeeping (block tables,
        # refcounts, lifecycle tree, token tails) is host-side and
        # device-agnostic either way.
        if mesh is None and tp is not None:
            mesh = serving_mesh(tp)
        self.mesh = mesh
        self.plan = serving_plan(mesh)
        self.tp = self.plan.tp_size
        if tp is not None and tp != self.tp:
            raise ValueError(
                f"tp={tp} contradicts the given mesh's tensor-parallel "
                f"width {self.tp}; pass one or the other")
        specs = None
        if self.plan.is_distributed:
            self._check_tp_divisibility(cfg, self.tp)
            specs = serve_specs(cfg, self.plan, params)
            shardings = jax.tree_util.tree_map(
                lambda s: NamedSharding(mesh, s), specs,
                is_leaf=lambda s: isinstance(s, P))
            params = jax.device_put(params, shardings)
            self._kv_sharding = NamedSharding(mesh, kv_page_spec(self.plan))
        else:
            self._kv_sharding = None
        self.params = params
        self.kv = KVBranchManager(num_pages=num_pages, page_size=page_size)
        self.page_size = page_size
        self.max_pages = max_pages_per_seq
        self.attn_impl = attn_impl
        dt = jnp.dtype(cfg.dtype)
        shape = (cfg.num_layers, num_pages, page_size, cfg.num_kv_heads,
                 cfg.head_dim)
        # allocate the pools directly into their mesh sharding — a pool
        # sized for aggregate-mesh HBM must never transit one device
        kv_kw = ({} if self._kv_sharding is None
                 else {"device": self._kv_sharding})
        self.k_pages = jnp.zeros(shape, dt, **kv_kw)
        self.v_pages = jnp.zeros(shape, dt, **kv_kw)
        self._tp_step = (build_tp_decode_step(cfg, self.plan, params,
                                              impl=attn_impl, specs=specs)
                         if self.plan.is_distributed else None)
        # Token tails ride the same lifecycle kernel as the page tables:
        # kv.commit/abort/invalidate resolves both domains atomically.
        self.token_domain = TokenDomain()
        self.kv.tree.attach(self.token_domain)
        # CoW fault-service instrumentation (benchmarks read these)
        self.cow_dispatches = 0   # fused _copy_pages device calls
        self.cow_faults = 0       # individual page copies serviced

    @staticmethod
    def _check_tp_divisibility(cfg: ArchConfig, tp: int) -> None:
        """Refuse a mesh the psums could not be correct on.

        ``sanitize`` silently replicates a non-dividing dim — fine for
        output-dim sharding (vocab), catastrophic for a dim the body
        psums over: every shard would compute the full reduction and
        the psum would multiply it by ``tp``.  Those dims must divide.
        """
        if cfg.num_kv_heads % tp or cfg.num_heads % tp:
            raise ValueError(
                f"tp={tp} must divide num_kv_heads={cfg.num_kv_heads} "
                f"and num_heads={cfg.num_heads} (KV pages and attention "
                "output shard on the head dims)")
        if cfg.is_moe:
            if cfg.num_experts % tp:
                raise ValueError(
                    f"tp={tp} must divide num_experts={cfg.num_experts}")
        elif cfg.d_ff % tp:
            raise ValueError(
                f"tp={tp} must divide d_ff={cfg.d_ff} (MLP down-proj "
                "psums over the sharded d_ff dim)")

    def _pin_kv(self, pages: jax.Array) -> jax.Array:
        """Place a KV pool on its mesh sharding (no-op single-device, and
        free when the array already has the target sharding)."""
        if self._kv_sharding is None:
            return pages
        return jax.device_put(pages, self._kv_sharding)

    # ------------------------------------------------------------------
    def add_request(self, prompt: Sequence[int]) -> int:
        """Prefill a prompt into a fresh paged sequence.

        Invariant: ``kv.length == len(tokens) - 1`` — the last token is
        "pending": its KV is written by the decode step that consumes it.
        """
        prompt = list(prompt)
        assert prompt, "empty prompt"
        n_cached = len(prompt) - 1
        sid = self.kv.new_seq(length=n_cached)
        if n_cached:
            toks = jnp.asarray(prompt[:-1], jnp.int32)[None]
            # dense prefill, then scatter the cache into this seq's pages
            _, cache = self.model.prefill(self.params, toks)
            table = self.kv.block_table(sid)
            k = cache["k"][:, 0]      # [L, s, kv, hd]
            v = cache["v"][:, 0]
            for pi, page in enumerate(table):
                lo = pi * self.page_size
                hi = min(lo + self.page_size, n_cached)
                self.k_pages = self.k_pages.at[:, page, : hi - lo].set(
                    k[:, lo:hi])
                self.v_pages = self.v_pages.at[:, page, : hi - lo].set(
                    v[:, lo:hi])
            # eager scatter of an unsharded prefill cache can drift the
            # pool's layout; re-pin so the hot loop never pays a
            # per-step reshard at the shard_map boundary
            self.k_pages = self._pin_kv(self.k_pages)
            self.v_pages = self._pin_kv(self.v_pages)
        self.token_domain.seed(sid, prompt)
        return sid

    # ------------------------------------------------------------------
    # branch ops (the paper's lifecycle, resolved by the shared kernel)
    # ------------------------------------------------------------------
    def fork(self, seq: int, n: int, *, eager_cow: bool = False) -> List[int]:
        """Fork ``n`` branches (token tails copied by the lifecycle hook).

        With ``eager_cow`` the shared-tail copy-on-write every child
        would fault at its first append is hoisted into the fork itself
        and serviced as ONE fused ``_copy_pages`` dispatch for the whole
        sibling set (``KVBranchManager.fork_batch``) — the vectorized
        ``branch(parent, n=k)`` hot path of ``repro.api``.  The default
        stays lazy so a fork that never decodes remains zero-copy.
        """
        if not eager_cow:
            return self.kv.fork(seq, n)
        children, ops = self.kv.fork_batch(seq, n)
        if ops:
            self._service_cow([op.src_page for op in ops],
                              [op.dst_page for op in ops])
        return children

    def commit(self, seq: int) -> int:
        return self.kv.commit(seq)    # tokens + pages promoted atomically

    def abort(self, seq: int) -> None:
        self.kv.abort(seq)

    def release(self, seq: int) -> None:
        """Evict a finished/abandoned sequence, freeing every domain."""
        self.kv.release(seq)

    def truncate(self, seq: int, n_tokens: int) -> None:
        """Keep only the first ``n_tokens`` tokens of a sequence.

        The speculative-decoding primitive: a draft branch commits its
        verified prefix by dropping the unverified suffix first.  Both
        domains shrink together, preserving ``kv.length == tokens - 1``
        (the last retained token becomes the pending one).
        """
        if n_tokens < 1:
            raise ValueError("cannot truncate below one token")
        self.kv.truncate(seq, n_tokens - 1)
        self.token_domain.truncate(seq, n_tokens)

    # ------------------------------------------------------------------
    def _service_cow(self, src: List[int], dst: List[int]) -> None:
        """Service all pending CoW faults in one fused device dispatch.

        Unchanged under a mesh: page indices are kv-head-agnostic, so
        the same gather/scatter partitions cleanly over the sharded
        kv-head dim — each shard copies its slice of every faulted
        page, still ONE dispatch for the whole batch.
        """
        s, d = _pad_pow2(src, dst)
        self.k_pages, self.v_pages = _copy_pages(
            self.k_pages, self.v_pages, s, d)
        self.k_pages = self._pin_kv(self.k_pages)
        self.v_pages = self._pin_kv(self.v_pages)
        self.cow_dispatches += 1
        self.cow_faults += len(src)

    def decode(self, seq_ids: Sequence[int], *, greedy: Any = True,
               temperature: Any = 1.0,
               key: Optional[jax.Array] = None) -> List[int]:
        """One token for each sequence (they decode as one batch).

        ``greedy`` and ``temperature`` may be scalars (whole batch) or
        per-sequence lists, so one continuous batch can mix greedy
        verification branches with sampled exploration branches at
        different temperatures — the exploration driver multiplexes many
        policies' decode work into a single device dispatch.
        """
        b = len(seq_ids)
        # resolve sampling rows BEFORE any metadata mutates: a mis-sized
        # per-sequence list must fail cleanly, not after slots were
        # reserved and the device step ran
        greedy_row = ([bool(greedy)] * b if isinstance(greedy, (bool, int))
                      else [bool(g) for g in greedy])
        temp_row = ([float(temperature)] * b
                    if isinstance(temperature, (int, float))
                    else [float(t) for t in temperature])
        if len(greedy_row) != b or len(temp_row) != b:
            raise ValueError("per-sequence sampling rows must match batch")
        lengths_before = np.array([self.kv.length(s) for s in seq_ids],
                                  np.int32)
        # refuse BEFORE mutating metadata if any sequence's table would
        # outgrow the per-sequence limit (dense_block_tables would raise
        # only after the batch's slots were already reserved)
        for s, ln in zip(seq_ids, lengths_before):
            if int(ln) // self.page_size + 1 > self.max_pages:
                raise ValueError(
                    f"sequence {s} would need "
                    f"{int(ln) // self.page_size + 1} pages > "
                    f"{self.max_pages} (max_pages_per_seq)")
        # host: reserve slots transactionally — if the pool exhausts on a
        # later batch member, earlier members' tables/lengths/CoW swaps
        # are rolled back before the MemoryError propagates, so a decode
        # step either runs for the whole batch or mutates nothing
        slot_lists = self.kv.prepare_append_batch(seq_ids, 1)
        slots = [sl[0] for sl in slot_lists]
        cow_src: List[int] = []
        cow_dst: List[int] = []
        for slot in slots:
            for cow in slot.cow:
                cow_src.append(cow.src_page)
                cow_dst.append(cow.dst_page)
        if cow_src:
            self._service_cow(cow_src, cow_dst)
        bt, _ = self.kv.dense_block_tables(seq_ids, self.max_pages)
        last_tokens = jnp.asarray(
            [[self.token_domain.get(s)[-1]] for s in seq_ids], jnp.int32)

        step_args = (
            self.k_pages, self.v_pages,
            jnp.asarray(bt), jnp.asarray(lengths_before),
            jnp.asarray([sl.page for sl in slots], jnp.int32),
            jnp.asarray([sl.offset for sl in slots], jnp.int32),
            last_tokens,
        )
        if self._tp_step is not None:
            logits, self.k_pages, self.v_pages = self._tp_step(
                self.params, *step_args)
        else:
            logits, self.k_pages, self.v_pages = paged_decode_step(
                self.cfg, self.params, *step_args, impl=self.attn_impl)
        logits = logits[:, 0]
        if all(greedy_row):
            nxt = jnp.argmax(logits, axis=-1)
        else:
            assert key is not None
            temps = jnp.asarray(temp_row, jnp.float32)
            sampled = jax.random.categorical(key, logits / temps[:, None])
            nxt = jnp.where(jnp.asarray(greedy_row),
                            jnp.argmax(logits, axis=-1), sampled)
        out = [int(t) for t in np.asarray(nxt)]
        for s, t in zip(seq_ids, out):
            self.token_domain.append(s, t)
        return out

    def tokens(self, seq: int) -> List[int]:
        return list(self.token_domain.get(seq))

    def stats(self) -> Dict[str, int]:
        st = self.kv.stats()
        st["token_tails"] = len(self.token_domain)
        st["cow_dispatches"] = self.cow_dispatches
        st["cow_faults"] = self.cow_faults
        st["tp"] = self.tp
        return st
