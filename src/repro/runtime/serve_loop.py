"""ServeEngine — continuous-batching serving with branchable paged KV.

The paper's serving workload as a first-class engine feature:

* KV lives in fixed-size **pages** ([L, n_pages, page, kv, hd] pools);
  sequences hold block tables managed by :class:`KVBranchManager`.
* ``fork(seq, n)`` creates N generation branches sharing every page
  (CoW); the first append to a shared tail page triggers a single-page
  device copy (the CoW fault).
* ``commit(branch)`` promotes the branch into its parent and invalidates
  siblings, whose pages are recycled — first-commit-wins.
* nesting: branches fork sub-branches (Tree-of-Thoughts style).
* decode runs the **paged-attention** path per layer (Pallas kernel on
  TPU; the jnp gather oracle on CPU — same math).

Only attention-family archs use paged KV; SSM archs branch their
recurrent state through the BranchStore instead (DESIGN §6).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import KVBranchManager
from repro.kernels.paged_attention.ops import paged_attention
from repro.models import layers as L
from repro.models.model import Model
from repro.models.transformer import embed_tokens, lm_head


# ---------------------------------------------------------------------------
# jitted paged decode step (dense/moe families)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("cfg", "impl"))
def paged_decode_step(
    cfg: ArchConfig,
    params: Any,
    k_pages: jax.Array,       # [L, n_pages, page, kv, hd]
    v_pages: jax.Array,
    block_tables: jax.Array,  # [b, max_pages]
    lengths: jax.Array,       # [b] length BEFORE this token
    slot_pages: jax.Array,    # [b] page for this token's KV
    slot_offsets: jax.Array,  # [b] offset within that page
    tokens: jax.Array,        # [b, 1]
    impl: str = "ref",
):
    """One decode step over paged KV.  Returns (logits, k_pages, v_pages)."""
    b = tokens.shape[0]
    kvh, g = cfg.num_kv_heads, cfg.num_heads // cfg.num_kv_heads
    h = embed_tokens(cfg, params, tokens)
    batch_idx = jnp.arange(b)

    def body(h, xs):
        lp, kp, vp = xs
        x = L.rms_norm(h, lp["ln1"], cfg.norm_eps)
        q, k, v = L.qkv_project(cfg, lp["attn"], x, lengths[:, None])
        # write this token's K/V into its (possibly CoW'd) page slot
        kp = kp.at[slot_pages, slot_offsets].set(k[:, 0])
        vp = vp.at[slot_pages, slot_offsets].set(v[:, 0])
        qh = q.reshape(b, kvh, g, cfg.head_dim)
        a = paged_attention(qh, kp, vp, block_tables, lengths + 1,
                            impl=impl)
        a = a.reshape(b, 1, cfg.num_heads, cfg.head_dim)
        h = h + jnp.einsum("bshk,hkd->bsd", a, lp["attn"]["wo"])
        x = L.rms_norm(h, lp["ln2"], cfg.norm_eps)
        if cfg.is_moe:
            from repro.models.moe import moe_block

            m, _ = moe_block(cfg, lp["moe"], x)
        else:
            m = L.mlp_block(cfg, lp["mlp"], x)
        return h + m, (kp, vp)

    h, (k_pages, v_pages) = jax.lax.scan(
        body, h, (params["layers"], k_pages, v_pages))
    h = L.rms_norm(h, params["final_norm"], cfg.norm_eps)
    return lm_head(cfg, params, h), k_pages, v_pages


@partial(jax.jit, donate_argnums=(0,))
def _copy_pages(pages: jax.Array, src: jax.Array, dst: jax.Array
                ) -> jax.Array:
    """CoW fault service: copy pages[:, src] -> pages[:, dst]."""
    return pages.at[:, dst].set(pages[:, src])


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------

@dataclass
class Branch:
    """A generation branch handle (sequence id + host token tail)."""

    seq: int
    tokens: List[int]
    parent: Optional["Branch"] = None


class ServeEngine:
    def __init__(self, model: Model, params: Any, *, num_pages: int = 256,
                 page_size: int = 16, max_pages_per_seq: int = 32,
                 attn_impl: str = "ref"):
        cfg = model.cfg
        assert cfg.family in ("dense", "vlm", "audio", "moe"), (
            "paged-KV serving targets attention archs; SSM archs branch "
            "their recurrent state via BranchStore (DESIGN §6)")
        self.model = model
        self.cfg = cfg
        self.params = params
        self.kv = KVBranchManager(num_pages=num_pages, page_size=page_size)
        self.page_size = page_size
        self.max_pages = max_pages_per_seq
        self.attn_impl = attn_impl
        dt = jnp.dtype(cfg.dtype)
        shape = (cfg.num_layers, num_pages, page_size, cfg.num_kv_heads,
                 cfg.head_dim)
        self.k_pages = jnp.zeros(shape, dt)
        self.v_pages = jnp.zeros(shape, dt)
        self._tokens: Dict[int, List[int]] = {}

    # ------------------------------------------------------------------
    def add_request(self, prompt: Sequence[int]) -> int:
        """Prefill a prompt into a fresh paged sequence.

        Invariant: ``kv.length == len(tokens) - 1`` — the last token is
        "pending": its KV is written by the decode step that consumes it.
        """
        prompt = list(prompt)
        assert prompt, "empty prompt"
        n_cached = len(prompt) - 1
        sid = self.kv.new_seq(length=n_cached)
        if n_cached:
            toks = jnp.asarray(prompt[:-1], jnp.int32)[None]
            # dense prefill, then scatter the cache into this seq's pages
            _, cache = self.model.prefill(self.params, toks)
            table = self.kv.block_table(sid)
            k = cache["k"][:, 0]      # [L, s, kv, hd]
            v = cache["v"][:, 0]
            for pi, page in enumerate(table):
                lo = pi * self.page_size
                hi = min(lo + self.page_size, n_cached)
                self.k_pages = self.k_pages.at[:, page, : hi - lo].set(
                    k[:, lo:hi])
                self.v_pages = self.v_pages.at[:, page, : hi - lo].set(
                    v[:, lo:hi])
        self._tokens[sid] = prompt
        return sid

    # ------------------------------------------------------------------
    # branch ops (the paper's lifecycle, KV domain)
    # ------------------------------------------------------------------
    def fork(self, seq: int, n: int) -> List[int]:
        children = self.kv.fork(seq, n)
        for c in children:
            self._tokens[c] = list(self._tokens[seq])
        return children

    def commit(self, seq: int) -> int:
        parent = self.kv.commit(seq)
        self._tokens[parent] = self._tokens.pop(seq)
        return parent

    def abort(self, seq: int) -> None:
        self.kv.abort(seq)
        self._tokens.pop(seq, None)

    # ------------------------------------------------------------------
    def decode(self, seq_ids: Sequence[int], *, greedy: bool = True,
               temperature: float = 1.0,
               key: Optional[jax.Array] = None) -> List[int]:
        """One token for each sequence (they decode as one batch)."""
        lengths_before = np.array([self.kv.length(s) for s in seq_ids],
                                  np.int32)
        # host: reserve slots (may trigger CoW page copies)
        slots = []
        for s in seq_ids:
            (slot,) = self.kv.prepare_append(s, 1)
            for cow in slot.cow:
                self.k_pages = _copy_pages(
                    self.k_pages, jnp.int32(cow.src_page),
                    jnp.int32(cow.dst_page))
                self.v_pages = _copy_pages(
                    self.v_pages, jnp.int32(cow.src_page),
                    jnp.int32(cow.dst_page))
            slots.append(slot)
        bt, _ = self.kv.dense_block_tables(seq_ids, self.max_pages)
        last_tokens = jnp.asarray(
            [[self._tokens[s][-1]] for s in seq_ids], jnp.int32)

        logits, self.k_pages, self.v_pages = paged_decode_step(
            self.cfg, self.params, self.k_pages, self.v_pages,
            jnp.asarray(bt), jnp.asarray(lengths_before),
            jnp.asarray([sl.page for sl in slots], jnp.int32),
            jnp.asarray([sl.offset for sl in slots], jnp.int32),
            last_tokens, impl=self.attn_impl,
        )
        logits = logits[:, 0]
        if greedy:
            nxt = jnp.argmax(logits, axis=-1)
        else:
            assert key is not None
            nxt = jax.random.categorical(key, logits / temperature)
        out = [int(t) for t in np.asarray(nxt)]
        for s, t in zip(seq_ids, out):
            self._tokens[s].append(t)
        return out

    def tokens(self, seq: int) -> List[int]:
        return list(self._tokens[seq])

    def stats(self) -> Dict[str, int]:
        return self.kv.stats()
