"""ServeEngine — branchable paged-KV engine (device step + state domains).

The paper's serving workload as a first-class engine feature:

* KV lives in fixed-size **pages** ([L, n_pages, page, kv, hd] pools);
  sequences hold block tables managed by :class:`KVBranchManager`.
* ``fork(seq, n)`` creates N generation branches sharing every page
  (CoW); the first append to a shared tail page triggers a single-page
  device copy (the CoW fault).  All pending CoW faults of a decode step
  are serviced by **one** fused ``_copy_pages`` dispatch, not one jit
  call per page.
* ``commit(branch)`` promotes the branch into its parent and invalidates
  siblings, whose pages are recycled — first-commit-wins.
* nesting: branches fork sub-branches (Tree-of-Thoughts style).
* decode runs the **paged-attention** path per layer (Pallas kernel on
  TPU; the jnp gather oracle on CPU — same math).
* the **decode fast path** (DESIGN §12): with any ``attn_impl`` other
  than ``"ref"`` the whole step — pending CoW fault service, the
  token's KV write, and attention — is ONE device dispatch: the fused
  :func:`~repro.kernels.paged_attention.paged_chunk_attention` kernel
  takes the step's CoW indirection vector and the fresh K/V inline, so
  the attention gather resolves page redirects against the *pre-copy*
  pool while the physical copy and slot write ride the same program.
  ``attn_impl="ref"`` keeps the legacy two-dispatch path
  (``_copy_pages`` then the cached-only gather) as the oracle.
* **int8 KV pages** (``kv_dtype="int8"``): pools store int8 with
  per-page/per-kv-head dequant scales alongside — half the HBM of
  bf16, double the branch fan-out at equal pool bytes.  Dequant happens
  inside the kernel; every CoW page copy moves the page's scales with
  it.  Requires the fused path (the legacy gather is fp-only).
* ``spec_verify(seq, drafts)`` scores k draft tokens against the target
  in ONE fused pass over a shared block table — the verify phase of
  ``speculative_decode`` costs one dispatch instead of k sequential
  verifier decode steps.

The engine does not implement a branch lifecycle of its own: its host
token tails are a :class:`TokenDomain` attached to the KV manager's
:class:`~repro.core.lifecycle.BranchTree`, so one kernel-level
``commit``/``abort``/invalidation resolves pages *and* tokens atomically
— a raced commit can no longer strand token tails (DESIGN §2).

Admission, continuous batching and fork admission live in
:mod:`repro.runtime.scheduler`; this module is only the device step plus
the per-sequence state domains.

**Sharded serving** (DESIGN §11): constructing the engine with ``tp=``
or ``mesh=`` rebases the hot loop onto a tensor-parallel device mesh —
weights shard per the training rules (heads / d_ff / experts over the
tp axis), the KV pools shard on the **kv-head dim**, and the decode
step runs under one compat-shimmed ``shard_map`` so a step is still one
device dispatch.  All branch bookkeeping (block tables, refcounts, the
lifecycle tree, token tails) is host-side integer metadata and stays
replicated/device-agnostic; fork/commit cost does not change with mesh
size.  Unset, behavior is exactly the single-device path.

Only attention-family archs use paged KV; SSM archs branch their
recurrent state through the BranchStore instead (DESIGN §6).
"""

from __future__ import annotations

import time
from functools import partial
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.core import KVBranchManager
from repro.core.kvtier import KVSnapshot, KVTierStore
from repro.distributed.compat import shard_map
from repro.distributed.mesh import ParallelPlan, serving_mesh, serving_plan
from repro.distributed.sharding import kv_page_spec, serve_param_specs
from repro.kernels.paged_attention.ops import (
    paged_attention,
    paged_chunk_attention,
)
from repro.kernels.select import resolve_impl
from repro.obs import ENGINE_TRACK, Observability
from repro.models import layers as L
from repro.models.model import Model
from repro.models.transformer import embed_tokens, lm_head


# ---------------------------------------------------------------------------
# paged decode step (dense/moe families) — one body, two bindings:
# the single-device jit and the shard_map'd tensor-parallel step
# ---------------------------------------------------------------------------

def _ffn(cfg: ArchConfig, lp: Any, x: jax.Array, combine,
         axis_name: Optional[str]) -> jax.Array:
    """Post-attention FFN of one layer, shared by every step body.

    ``x`` is the ln2-normed hidden [b, s, d]; returns the residual
    delta.  Under ``axis_name`` the MoE branch runs its expert-parallel
    slice and the EP combine is the same psum a TP MLP needs (DESIGN §5).
    """
    if cfg.is_moe:
        from repro.models.moe import moe_apply_local, moe_block

        if axis_name is None:
            m, _ = moe_block(cfg, lp["moe"], x)
        else:
            mp = lp["moe"]
            e_loc = mp["wu"].shape[0]
            e0 = (jax.lax.axis_index(axis_name) * e_loc).astype(jnp.int32)
            y, _ = moe_apply_local(
                cfg, x.reshape(-1, cfg.d_model), mp["router"],
                mp.get("wg"), mp["wu"], mp["wd"], e0)
            m = combine(y).reshape(x.shape)
    else:
        m = combine(L.mlp_block(cfg, lp["mlp"], x))
    return m


def _decode_body(
    cfg: ArchConfig,
    params: Any,
    k_pages: jax.Array,       # [L, n_pages, page, kv(_local), hd]
    v_pages: jax.Array,
    block_tables: jax.Array,  # [b, max_pages]
    lengths: jax.Array,       # [b] length BEFORE this token
    slot_pages: jax.Array,    # [b] page for this token's KV
    slot_offsets: jax.Array,  # [b] offset within that page
    tokens: jax.Array,        # [b, 1]
    *,
    impl: str,
    axis_name: Optional[str] = None,
):
    """One decode step over paged KV.  Returns (logits, k_pages, v_pages).

    With ``axis_name`` the body runs *shard-local* under ``shard_map``:
    weights arrive as tensor-parallel slices (heads / kv heads / d_ff /
    experts over the axis), the KV pools carry only the local kv-head
    slice, and the two contractions whose reduction dim is sharded
    (attention output over heads, MLP/MoE down-projection) psum across
    the axis.  Block tables, lengths and slots are replicated — page
    ids mean the same thing on every shard, so the host-side CoW
    bookkeeping is mesh-agnostic.
    """
    b = tokens.shape[0]
    h = embed_tokens(cfg, params, tokens)

    def combine(x):
        return jax.lax.psum(x, axis_name) if axis_name else x

    def body(h, xs):
        lp, kp, vp = xs
        x = L.rms_norm(h, lp["ln1"], cfg.norm_eps)
        q, k, v = L.qkv_project(cfg, lp["attn"], x, lengths[:, None])
        # write this token's K/V into its (possibly CoW'd) page slot
        kp = kp.at[slot_pages, slot_offsets].set(k[:, 0])
        vp = vp.at[slot_pages, slot_offsets].set(v[:, 0])
        # heads are kv-major (head = kv * g + g_idx), so a contiguous
        # head shard is a contiguous kv-head shard: local shapes fall
        # out of the projection weights
        kvh = k.shape[2]
        g = q.shape[2] // kvh
        qh = q.reshape(b, kvh, g, cfg.head_dim)
        a = paged_attention(qh, kp, vp, block_tables, lengths + 1,
                            impl=impl)
        a = a.reshape(b, 1, kvh * g, cfg.head_dim)
        h = h + combine(jnp.einsum("bshk,hkd->bsd", a, lp["attn"]["wo"]))
        x = L.rms_norm(h, lp["ln2"], cfg.norm_eps)
        return h + _ffn(cfg, lp, x, combine, axis_name), (kp, vp)

    h, (k_pages, v_pages) = jax.lax.scan(
        body, h, (params["layers"], k_pages, v_pages))
    h = L.rms_norm(h, params["final_norm"], cfg.norm_eps)
    return lm_head(cfg, params, h), k_pages, v_pages


@partial(jax.jit, static_argnames=("cfg", "impl"))
def paged_decode_step(
    cfg: ArchConfig,
    params: Any,
    k_pages: jax.Array,       # [L, n_pages, page, kv, hd]
    v_pages: jax.Array,
    block_tables: jax.Array,  # [b, max_pages]
    lengths: jax.Array,       # [b] length BEFORE this token
    slot_pages: jax.Array,    # [b] page for this token's KV
    slot_offsets: jax.Array,  # [b] offset within that page
    tokens: jax.Array,        # [b, 1]
    impl: str = "ref",
):
    """One decode step over paged KV (single device)."""
    return _decode_body(cfg, params, k_pages, v_pages, block_tables,
                        lengths, slot_pages, slot_offsets, tokens,
                        impl=impl)


def serve_specs(cfg: ArchConfig, plan: ParallelPlan, params: Any) -> Any:
    """The engine's parameter spec tree (training rules retargeted to
    the serving tp axis).  Multi-codebook heads keep their vocab dim
    replicated: the ``[b, s, cb, V]`` reshape inside ``lm_head`` needs
    the full codebook-major vocab on every shard."""
    specs = serve_param_specs(cfg, plan, params)
    if cfg.num_codebooks > 1 and "lm_head" in specs:
        specs["lm_head"] = P(*(None,) * params["lm_head"].ndim)
    return specs


def build_tp_decode_step(cfg: ArchConfig, plan: ParallelPlan, params: Any,
                         *, impl: str = "ref",
                         specs: Optional[Any] = None):
    """The tensor-parallel decode step: ``_decode_body`` under ONE
    compat-shimmed ``shard_map`` so a whole fork/explore/commit step
    still costs one device dispatch.

    Weights and KV pages arrive pre-sharded (the engine places them at
    construction); block tables / lengths / slots / tokens replicate.
    Logits leave replicated — a vocab-sharded head is all-gathered
    *inside* the mapped function so sampling stays mesh-agnostic.
    """
    if specs is None:
        specs = serve_specs(cfg, plan, params)
    lm_spec = specs.get("lm_head")
    gather_logits = lm_spec is not None and plan.tp_axis in tuple(lm_spec)
    kv_spec = kv_page_spec(plan)
    rep = P()

    def local_step(p, kp, vp, bt, lengths, slot_pages, slot_offsets,
                   tokens):
        logits, kp, vp = _decode_body(
            cfg, p, kp, vp, bt, lengths, slot_pages, slot_offsets,
            tokens, impl=impl, axis_name=plan.tp_axis)
        if gather_logits:
            logits = jax.lax.all_gather(
                logits, plan.tp_axis, axis=logits.ndim - 1, tiled=True)
        return logits, kp, vp

    fn = shard_map(
        local_step, mesh=plan.mesh,
        in_specs=(specs, kv_spec, kv_spec, rep, rep, rep, rep, rep),
        out_specs=(rep, kv_spec, kv_spec),
        check_rep=False,
    )
    return jax.jit(fn)


# ---------------------------------------------------------------------------
# fused decode fast path + speculative verify (DESIGN §12)
# ---------------------------------------------------------------------------

def _quant_token_write(pages: jax.Array,    # [n_pages, page, kv, hd] int8
                       scales: jax.Array,   # [n_pages, kv] f32
                       slot_pages: jax.Array,    # [b]
                       slot_offsets: jax.Array,  # [b]
                       tok: jax.Array):          # [b, kv, hd] fp
    """Write one fp K/V row per sequence into its int8 slot page.

    Dequant the page, set the row, requant with a **monotone** scale:
    ``new = max(old, amax|tok|/127)``.  Requant under an unchanged scale
    is lossless (``round(q·s/s) = q``), so earlier entries drift only at
    the rare growth events.  A write at offset 0 starts a fresh page, so
    the stale occupant's scale is discarded rather than inherited.
    """
    b = tok.shape[0]
    sc = jnp.where(slot_offsets[:, None] == 0, 0.0,
                   scales[slot_pages])                     # [b, kv]
    fp = pages[slot_pages].astype(jnp.float32) * sc[:, None, :, None]
    fp = fp.at[jnp.arange(b), slot_offsets].set(tok.astype(jnp.float32))
    need = jnp.max(jnp.abs(tok.astype(jnp.float32)), axis=-1) / 127.0
    nsc = jnp.maximum(jnp.maximum(sc, need), 1e-8)
    q8 = jnp.clip(jnp.round(fp / nsc[:, None, :, None]),
                  -127, 127).astype(jnp.int8)
    return pages.at[slot_pages].set(q8), scales.at[slot_pages].set(nsc)


def _fused_decode_body(
    cfg: ArchConfig,
    params: Any,
    k_pages: jax.Array,       # [L, n_pages, page, kv(_local), hd]
    v_pages: jax.Array,
    block_tables: jax.Array,  # [b, max_pages]
    lengths: jax.Array,       # [b] length BEFORE this token
    slot_pages: jax.Array,    # [b]
    slot_offsets: jax.Array,  # [b]
    tokens: jax.Array,        # [b, 1]
    cow_src: jax.Array,       # [n_cow] int32 (may be length 0)
    cow_dst: jax.Array,       # [n_cow] int32
    k_scales: Optional[jax.Array] = None,  # [L, n_pages, kv] (int8 mode)
    v_scales: Optional[jax.Array] = None,
    *,
    impl: str,
    axis_name: Optional[str] = None,
):
    """One decode step, CoW fault service included — ONE device dispatch.

    The step's pending CoW faults arrive as an (src, dst) indirection
    vector instead of a prior ``_copy_pages`` dispatch.  Attention reads
    the **pre-copy** pool through ``page_map`` (a faulted dst gathers its
    src page), so the gather has no data dependency on the copy; the
    physical page copy and this token's KV write ride the same program
    as plain scatter ops.  With scales the pools are int8 and the kernel
    dequants per page; the slot write requants (see _quant_token_write).
    """
    b = tokens.shape[0]
    h = embed_tokens(cfg, params, tokens)
    quant = k_scales is not None
    n_pages = k_pages.shape[1]
    page_map = jnp.arange(n_pages, dtype=jnp.int32)
    if cow_src.shape[0]:
        page_map = page_map.at[cow_dst].set(cow_src)

    def combine(x):
        return jax.lax.psum(x, axis_name) if axis_name else x

    def body(h, xs):
        if quant:
            lp, kp, vp, ks, vs = xs
        else:
            lp, kp, vp = xs
            ks = vs = None
        x = L.rms_norm(h, lp["ln1"], cfg.norm_eps)
        q, k, v = L.qkv_project(cfg, lp["attn"], x, lengths[:, None])
        kvh = k.shape[2]
        g = q.shape[2] // kvh
        qc = q.reshape(b, 1, kvh, g, cfg.head_dim)
        # attention first, against the pre-maintenance pool: the fresh
        # token rides inline as the chunk, CoW redirects via page_map
        a = paged_chunk_attention(qc, k, v, kp, vp, block_tables,
                                  lengths, page_map, ks, vs, impl=impl)
        a = a.reshape(b, 1, kvh * g, cfg.head_dim)
        h = h + combine(jnp.einsum("bshk,hkd->bsd", a, lp["attn"]["wo"]))
        # pool maintenance rides the same dispatch: service the faults
        # (scales travel with their pages), then write the token's KV
        # into its freshly-private slot
        if cow_src.shape[0]:
            kp = kp.at[cow_dst].set(kp[cow_src])
            vp = vp.at[cow_dst].set(vp[cow_src])
            if quant:
                ks = ks.at[cow_dst].set(ks[cow_src])
                vs = vs.at[cow_dst].set(vs[cow_src])
        if quant:
            kp, ks = _quant_token_write(kp, ks, slot_pages, slot_offsets,
                                        k[:, 0])
            vp, vs = _quant_token_write(vp, vs, slot_pages, slot_offsets,
                                        v[:, 0])
        else:
            kp = kp.at[slot_pages, slot_offsets].set(k[:, 0])
            vp = vp.at[slot_pages, slot_offsets].set(v[:, 0])
        x = L.rms_norm(h, lp["ln2"], cfg.norm_eps)
        h = h + _ffn(cfg, lp, x, combine, axis_name)
        return h, ((kp, vp, ks, vs) if quant else (kp, vp))

    xs = ((params["layers"], k_pages, v_pages, k_scales, v_scales)
          if quant else (params["layers"], k_pages, v_pages))
    h, pools = jax.lax.scan(body, h, xs)
    h = L.rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = lm_head(cfg, params, h)
    return (logits,) + tuple(pools)


@partial(jax.jit, static_argnames=("cfg", "impl"))
def paged_fused_decode_step(
    cfg: ArchConfig,
    params: Any,
    k_pages: jax.Array,
    v_pages: jax.Array,
    block_tables: jax.Array,
    lengths: jax.Array,
    slot_pages: jax.Array,
    slot_offsets: jax.Array,
    tokens: jax.Array,
    cow_src: jax.Array,
    cow_dst: jax.Array,
    k_scales: Optional[jax.Array] = None,
    v_scales: Optional[jax.Array] = None,
    impl: str = "ref",
):
    """One fused decode step (single device): returns
    ``(logits, k_pages, v_pages[, k_scales, v_scales])``."""
    return _fused_decode_body(cfg, params, k_pages, v_pages, block_tables,
                              lengths, slot_pages, slot_offsets, tokens,
                              cow_src, cow_dst, k_scales, v_scales,
                              impl=impl)


def _verify_body(
    cfg: ArchConfig,
    params: Any,
    k_pages: jax.Array,
    v_pages: jax.Array,
    block_tables: jax.Array,  # [n, max_pages] — drafts share one table
    lengths: jax.Array,       # [n] cached length (same for all rows)
    tokens: jax.Array,        # [n, t] teacher-forced draft rows
    k_scales: Optional[jax.Array] = None,
    v_scales: Optional[jax.Array] = None,
    *,
    impl: str,
    axis_name: Optional[str] = None,
):
    """Score t teacher-forced tokens per row in ONE pass (no pool writes).

    The fused speculative-verify step: every row attends to the shared
    cached prefix through the block table plus its own inline chunk with
    in-chunk causal masking.  Pure scoring — the pools are read-only, so
    k draft tokens cost one dispatch instead of k sequential decode
    steps.  Returns logits [n, t, V].
    """
    b, t = tokens.shape
    h = embed_tokens(cfg, params, tokens)
    positions = lengths[:, None] + jnp.arange(t, dtype=jnp.int32)[None, :]
    quant = k_scales is not None
    page_map = jnp.arange(k_pages.shape[1], dtype=jnp.int32)

    def combine(x):
        return jax.lax.psum(x, axis_name) if axis_name else x

    def body(h, xs):
        if quant:
            lp, kp, vp, ks, vs = xs
        else:
            lp, kp, vp = xs
            ks = vs = None
        x = L.rms_norm(h, lp["ln1"], cfg.norm_eps)
        q, k, v = L.qkv_project(cfg, lp["attn"], x, positions)
        kvh = k.shape[2]
        g = q.shape[2] // kvh
        qc = q.reshape(b, t, kvh, g, cfg.head_dim)
        a = paged_chunk_attention(qc, k, v, kp, vp, block_tables,
                                  lengths, page_map, ks, vs, impl=impl)
        a = a.reshape(b, t, kvh * g, cfg.head_dim)
        h = h + combine(jnp.einsum("bshk,hkd->bsd", a, lp["attn"]["wo"]))
        x = L.rms_norm(h, lp["ln2"], cfg.norm_eps)
        h = h + _ffn(cfg, lp, x, combine, axis_name)
        return h, None

    xs = ((params["layers"], k_pages, v_pages, k_scales, v_scales)
          if quant else (params["layers"], k_pages, v_pages))
    h, _ = jax.lax.scan(body, h, xs)
    h = L.rms_norm(h, params["final_norm"], cfg.norm_eps)
    return lm_head(cfg, params, h)


@partial(jax.jit, static_argnames=("cfg", "impl"))
def paged_verify_step(
    cfg: ArchConfig,
    params: Any,
    k_pages: jax.Array,
    v_pages: jax.Array,
    block_tables: jax.Array,
    lengths: jax.Array,
    tokens: jax.Array,
    k_scales: Optional[jax.Array] = None,
    v_scales: Optional[jax.Array] = None,
    impl: str = "ref",
):
    """Fused speculative verify (single device): logits [n, t, V]."""
    return _verify_body(cfg, params, k_pages, v_pages, block_tables,
                        lengths, tokens, k_scales, v_scales, impl=impl)


def _prefix_body(
    cfg: ArchConfig,
    params: Any,
    k_pages: jax.Array,
    v_pages: jax.Array,
    block_tables: jax.Array,  # [b, max_pages] — prefix pages + fresh tail
    lengths: jax.Array,       # [b] tokens already cached (the shared prefix)
    tokens: jax.Array,        # [b, t] suffix tokens to prefill
    k_scales: Optional[jax.Array] = None,
    v_scales: Optional[jax.Array] = None,
    *,
    impl: str,
    axis_name: Optional[str] = None,
):
    """Suffix ("chunk") prefill over an already-cached shared prefix.

    The prefix-cache counterpart of :func:`_verify_body`: every suffix
    position attends to the cached prefix through the block table plus
    the in-chunk causal window, but instead of logits the pass returns
    the suffix's per-layer K/V (stacked ``[L, b, t, kv, hd]``) for the
    host to scatter into the sequence's fresh tail pages.  A request
    whose prompt shares ``lengths`` tokens with the cache pays one
    dispatch over ``t = prompt - shared`` positions instead of a dense
    prefill over the whole prompt.
    """
    b, t = tokens.shape
    h = embed_tokens(cfg, params, tokens)
    positions = lengths[:, None] + jnp.arange(t, dtype=jnp.int32)[None, :]
    quant = k_scales is not None
    page_map = jnp.arange(k_pages.shape[1], dtype=jnp.int32)

    def combine(x):
        return jax.lax.psum(x, axis_name) if axis_name else x

    def body(h, xs):
        if quant:
            lp, kp, vp, ks, vs = xs
        else:
            lp, kp, vp = xs
            ks = vs = None
        x = L.rms_norm(h, lp["ln1"], cfg.norm_eps)
        q, k, v = L.qkv_project(cfg, lp["attn"], x, positions)
        kvh = k.shape[2]
        g = q.shape[2] // kvh
        qc = q.reshape(b, t, kvh, g, cfg.head_dim)
        a = paged_chunk_attention(qc, k, v, kp, vp, block_tables,
                                  lengths, page_map, ks, vs, impl=impl)
        a = a.reshape(b, t, kvh * g, cfg.head_dim)
        h = h + combine(jnp.einsum("bshk,hkd->bsd", a, lp["attn"]["wo"]))
        x = L.rms_norm(h, lp["ln2"], cfg.norm_eps)
        h = h + _ffn(cfg, lp, x, combine, axis_name)
        return h, (k, v)

    xs = ((params["layers"], k_pages, v_pages, k_scales, v_scales)
          if quant else (params["layers"], k_pages, v_pages))
    _, (k_new, v_new) = jax.lax.scan(body, h, xs)
    return k_new, v_new


@partial(jax.jit, static_argnames=("cfg", "impl"))
def paged_prefix_step(
    cfg: ArchConfig,
    params: Any,
    k_pages: jax.Array,
    v_pages: jax.Array,
    block_tables: jax.Array,
    lengths: jax.Array,
    tokens: jax.Array,
    k_scales: Optional[jax.Array] = None,
    v_scales: Optional[jax.Array] = None,
    impl: str = "ref",
):
    """Suffix prefill over a shared prefix (single device): per-layer
    K/V for the suffix, ``[L, b, t, kv, hd]`` each."""
    return _prefix_body(cfg, params, k_pages, v_pages, block_tables,
                        lengths, tokens, k_scales, v_scales, impl=impl)


def scale_spec(plan: ParallelPlan) -> P:
    """Spec for int8 dequant scales [L, n_pages, kv]: shard the kv-head
    dim exactly like the pools, so each shard's scales stay consistent
    with its pool slice."""
    return P(None, None, plan.tp_axis)


def build_tp_fused_decode_step(cfg: ArchConfig, plan: ParallelPlan,
                               params: Any, *, impl: str = "ref",
                               specs: Optional[Any] = None,
                               quantized: bool = False):
    """The tensor-parallel fused decode step — ``_fused_decode_body``
    under ONE compat-shimmed ``shard_map``; CoW vectors replicate (page
    ids are kv-head-agnostic), int8 scales shard with their pools."""
    if specs is None:
        specs = serve_specs(cfg, plan, params)
    lm_spec = specs.get("lm_head")
    gather_logits = lm_spec is not None and plan.tp_axis in tuple(lm_spec)
    kv_spec = kv_page_spec(plan)
    sc_spec = scale_spec(plan)
    rep = P()

    if quantized:
        def local_step(p, kp, vp, ks, vs, bt, lengths, slot_pages,
                       slot_offsets, tokens, cow_src, cow_dst):
            out = _fused_decode_body(
                cfg, p, kp, vp, bt, lengths, slot_pages, slot_offsets,
                tokens, cow_src, cow_dst, ks, vs, impl=impl,
                axis_name=plan.tp_axis)
            logits = out[0]
            if gather_logits:
                logits = jax.lax.all_gather(
                    logits, plan.tp_axis, axis=logits.ndim - 1, tiled=True)
            return (logits,) + out[1:]

        in_specs = (specs, kv_spec, kv_spec, sc_spec, sc_spec,
                    rep, rep, rep, rep, rep, rep, rep)
        out_specs = (rep, kv_spec, kv_spec, sc_spec, sc_spec)
    else:
        def local_step(p, kp, vp, bt, lengths, slot_pages, slot_offsets,
                       tokens, cow_src, cow_dst):
            out = _fused_decode_body(
                cfg, p, kp, vp, bt, lengths, slot_pages, slot_offsets,
                tokens, cow_src, cow_dst, impl=impl,
                axis_name=plan.tp_axis)
            logits = out[0]
            if gather_logits:
                logits = jax.lax.all_gather(
                    logits, plan.tp_axis, axis=logits.ndim - 1, tiled=True)
            return (logits,) + out[1:]

        in_specs = (specs, kv_spec, kv_spec,
                    rep, rep, rep, rep, rep, rep, rep)
        out_specs = (rep, kv_spec, kv_spec)

    fn = shard_map(local_step, mesh=plan.mesh, in_specs=in_specs,
                   out_specs=out_specs, check_rep=False)
    return jax.jit(fn)


def build_tp_verify_step(cfg: ArchConfig, plan: ParallelPlan, params: Any,
                         *, impl: str = "ref",
                         specs: Optional[Any] = None,
                         quantized: bool = False):
    """The tensor-parallel fused verify step (read-only pools)."""
    if specs is None:
        specs = serve_specs(cfg, plan, params)
    lm_spec = specs.get("lm_head")
    gather_logits = lm_spec is not None and plan.tp_axis in tuple(lm_spec)
    kv_spec = kv_page_spec(plan)
    sc_spec = scale_spec(plan)
    rep = P()

    if quantized:
        def local_step(p, kp, vp, ks, vs, bt, lengths, tokens):
            logits = _verify_body(cfg, p, kp, vp, bt, lengths, tokens,
                                  ks, vs, impl=impl,
                                  axis_name=plan.tp_axis)
            if gather_logits:
                logits = jax.lax.all_gather(
                    logits, plan.tp_axis, axis=logits.ndim - 1, tiled=True)
            return logits

        in_specs = (specs, kv_spec, kv_spec, sc_spec, sc_spec,
                    rep, rep, rep)
    else:
        def local_step(p, kp, vp, bt, lengths, tokens):
            logits = _verify_body(cfg, p, kp, vp, bt, lengths, tokens,
                                  impl=impl, axis_name=plan.tp_axis)
            if gather_logits:
                logits = jax.lax.all_gather(
                    logits, plan.tp_axis, axis=logits.ndim - 1, tiled=True)
            return logits

        in_specs = (specs, kv_spec, kv_spec, rep, rep, rep)

    fn = shard_map(local_step, mesh=plan.mesh, in_specs=in_specs,
                   out_specs=rep, check_rep=False)
    return jax.jit(fn)


def build_tp_prefix_step(cfg: ArchConfig, plan: ParallelPlan, params: Any,
                         *, impl: str = "ref",
                         specs: Optional[Any] = None,
                         quantized: bool = False):
    """The tensor-parallel suffix-prefill step: pools read sharded on the
    kv-head dim, and the returned suffix K/V stays sharded the same way
    (``[L, b, t, kv_local, hd]`` per shard) so the host scatter into the
    sharded pools never regathers heads."""
    if specs is None:
        specs = serve_specs(cfg, plan, params)
    kv_spec = kv_page_spec(plan)
    sc_spec = scale_spec(plan)
    rep = P()
    new_kv_spec = P(None, None, None, plan.tp_axis)

    if quantized:
        def local_step(p, kp, vp, ks, vs, bt, lengths, tokens):
            return _prefix_body(cfg, p, kp, vp, bt, lengths, tokens,
                                ks, vs, impl=impl, axis_name=plan.tp_axis)

        in_specs = (specs, kv_spec, kv_spec, sc_spec, sc_spec,
                    rep, rep, rep)
    else:
        def local_step(p, kp, vp, bt, lengths, tokens):
            return _prefix_body(cfg, p, kp, vp, bt, lengths, tokens,
                                impl=impl, axis_name=plan.tp_axis)

        in_specs = (specs, kv_spec, kv_spec, rep, rep, rep)

    fn = shard_map(local_step, mesh=plan.mesh, in_specs=in_specs,
                   out_specs=(new_kv_spec, new_kv_spec), check_rep=False)
    return jax.jit(fn)


@partial(jax.jit, donate_argnums=(0, 1))
def _copy_pages(k_pages: jax.Array, v_pages: jax.Array,
                src: jax.Array, dst: jax.Array):
    """Batched CoW fault service: pages[:, src] -> pages[:, dst].

    ``src``/``dst`` are int32 vectors covering *every* pending CoW op of
    a decode step, so the whole batch costs one device dispatch.  The
    gather reads the pre-copy pool, so a page freed by one fault and
    reallocated as another fault's destination still copies the right
    bytes; destination indices are unique (each is freshly allocated) or
    duplicated only as identical padding pairs.
    """
    return (k_pages.at[:, dst].set(k_pages[:, src]),
            v_pages.at[:, dst].set(v_pages[:, src]))


@partial(jax.jit, donate_argnums=(0, 1, 2, 3))
def _copy_pages_scaled(k_pages: jax.Array, v_pages: jax.Array,
                       k_scales: jax.Array, v_scales: jax.Array,
                       src: jax.Array, dst: jax.Array):
    """``_copy_pages`` for int8 pools: the per-page dequant scales travel
    with their pages in the same single dispatch."""
    return (k_pages.at[:, dst].set(k_pages[:, src]),
            v_pages.at[:, dst].set(v_pages[:, src]),
            k_scales.at[:, dst].set(k_scales[:, src]),
            v_scales.at[:, dst].set(v_scales[:, src]))


def _pad_pow2(src: List[int], dst: List[int]) -> tuple:
    """Pad the CoW op list to a power-of-two bucket to bound recompiles.

    Padding repeats the last real (src, dst) pair: duplicate scatter
    indices then carry identical payloads, which is deterministic.  An
    empty op list stays empty — callers skip the dispatch (or pass the
    zero-length vectors straight to the fused step, whose page_map is
    then the identity).
    """
    n = len(src)
    if n == 0:
        return (jnp.zeros((0,), jnp.int32), jnp.zeros((0,), jnp.int32))
    m = 1
    while m < n:
        m *= 2
    src = src + [src[-1]] * (m - n)
    dst = dst + [dst[-1]] * (m - n)
    return jnp.asarray(src, jnp.int32), jnp.asarray(dst, jnp.int32)


# ---------------------------------------------------------------------------
# token tails as a lifecycle domain
# ---------------------------------------------------------------------------

class TokenDomain:
    """Host token tails plugged into the branch-lifecycle kernel.

    The serving analogue of the paper's process-group domain: each live
    sequence owns its generated-token list, and the kernel's hooks move
    ownership on fork (copy), commit (child's tail replaces the
    parent's) and abort/invalidate (tail dropped) — so losers of a
    first-commit-wins race can never strand their tails.
    """

    def __init__(self) -> None:
        self._tokens: Dict[int, List[int]] = {}

    # -- BranchDomain hooks (called under the tree lock) ----------------
    def on_fork(self, parent: int, children: List[int]) -> None:
        base = self._tokens.get(parent)
        if base is not None:
            for c in children:
                self._tokens[c] = list(base)

    def on_commit(self, child: int, parent: int) -> None:
        if child in self._tokens:
            self._tokens[parent] = self._tokens.pop(child)

    def on_abort(self, branch: int) -> None:
        self._tokens.pop(branch, None)

    def on_invalidate(self, branch: int) -> None:
        self._tokens.pop(branch, None)

    def on_reap(self, branch: int) -> None:
        self._tokens.pop(branch, None)

    # -- accessors -------------------------------------------------------
    def seed(self, seq: int, tokens: Sequence[int]) -> None:
        self._tokens[seq] = list(tokens)

    def get(self, seq: int) -> List[int]:
        return self._tokens[seq]

    def append(self, seq: int, token: int) -> None:
        self._tokens[seq].append(token)

    def truncate(self, seq: int, n_tokens: int) -> None:
        del self._tokens[seq][n_tokens:]

    def __contains__(self, seq: int) -> bool:
        return seq in self._tokens

    def __len__(self) -> int:
        return len(self._tokens)


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------

class ServeEngine:
    def __init__(self, model: Model, params: Any, *, num_pages: int = 256,
                 page_size: int = 16, max_pages_per_seq: int = 32,
                 attn_impl: str = "auto", kv_dtype: Optional[str] = None,
                 mesh: Optional[Mesh] = None, tp: Optional[int] = None,
                 prefix_cache: bool = False,
                 tier_host_bytes: int = 64 << 20,
                 tier_disk_dir: Optional[str] = None,
                 obs: Optional[Observability] = None):
        cfg = model.cfg
        assert cfg.family in ("dense", "vlm", "audio", "moe"), (
            "paged-KV serving targets attention archs; SSM archs branch "
            "their recurrent state via BranchStore (DESIGN §6)")
        self.model = model
        self.cfg = cfg
        # --- serving mesh (tensor-parallel decode) --------------------
        # `tp=`/`mesh=` shard the hot loop; unset keeps the exact
        # single-device path.  Branch bookkeeping (block tables,
        # refcounts, lifecycle tree, token tails) is host-side and
        # device-agnostic either way.
        if mesh is None and tp is not None:
            mesh = serving_mesh(tp)
        self.mesh = mesh
        self.plan = serving_plan(mesh)
        self.tp = self.plan.tp_size
        if tp is not None and tp != self.tp:
            raise ValueError(
                f"tp={tp} contradicts the given mesh's tensor-parallel "
                f"width {self.tp}; pass one or the other")
        specs = None
        if self.plan.is_distributed:
            self._check_tp_divisibility(cfg, self.tp)
            specs = serve_specs(cfg, self.plan, params)
            shardings = jax.tree_util.tree_map(
                lambda s: NamedSharding(mesh, s), specs,
                is_leaf=lambda s: isinstance(s, P))
            params = jax.device_put(params, shardings)
            self._kv_sharding = NamedSharding(mesh, kv_page_spec(self.plan))
        else:
            self._kv_sharding = None
        self.params = params
        # one obs hub per engine stack (engine -> KV manager -> lifecycle
        # tracer), so concurrent engines never share counters; pass obs=
        # to aggregate explicitly, Observability(trace=True) for spans
        self.obs = Observability() if obs is None else obs
        self.kv = KVBranchManager(num_pages=num_pages, page_size=page_size,
                                  obs=self.obs)
        self.page_size = page_size
        self.max_pages = max_pages_per_seq
        # --- impl resolution + decode fast path -----------------------
        # "auto" -> pallas on TPU, interpret under REPRO_KERNELS_INTERPRET,
        # else the jnp reference.  Any impl but "ref" takes the fused
        # one-dispatch path; "fused_ref" is the CPU spelling of it (the
        # fused step with the chunk-kernel's jnp oracle inside).
        if kv_dtype not in (None, "int8"):
            raise ValueError(f"kv_dtype must be None or 'int8', "
                             f"got {kv_dtype!r}")
        self.kv_dtype = kv_dtype
        self.quantized = kv_dtype == "int8"
        impl = resolve_impl(
            attn_impl,
            cpu_fallback="fused_ref" if self.quantized else "ref")
        if impl not in ("ref", "fused_ref", "interpret", "pallas"):
            raise ValueError(f"unknown attn_impl {attn_impl!r}")
        if self.quantized and impl == "ref":
            raise ValueError(
                "kv_dtype='int8' requires the fused decode path "
                "(attn_impl 'auto', 'fused_ref', 'interpret' or "
                "'pallas'); the legacy 'ref' gather is fp-only")
        self.attn_impl = impl
        self.fast_path = impl != "ref"
        # what the fused chunk op is told to run ("fused_ref" is engine-
        # level routing, the kernel-level impl underneath it is "ref")
        self._chunk_impl = "ref" if impl == "fused_ref" else impl
        dt = jnp.dtype(jnp.int8) if self.quantized else jnp.dtype(cfg.dtype)
        shape = (cfg.num_layers, num_pages, page_size, cfg.num_kv_heads,
                 cfg.head_dim)
        # allocate the pools directly into their mesh sharding — a pool
        # sized for aggregate-mesh HBM must never transit one device
        kv_kw = ({} if self._kv_sharding is None
                 else {"device": self._kv_sharding})
        self.k_pages = jnp.zeros(shape, dt, **kv_kw)
        self.v_pages = jnp.zeros(shape, dt, **kv_kw)
        if self.quantized:
            sshape = (cfg.num_layers, num_pages, cfg.num_kv_heads)
            self._scale_sharding = (
                None if mesh is None or not self.plan.is_distributed
                else NamedSharding(mesh, scale_spec(self.plan)))
            sc_kw = ({} if self._scale_sharding is None
                     else {"device": self._scale_sharding})
            self.k_scales = jnp.zeros(sshape, jnp.float32, **sc_kw)
            self.v_scales = jnp.zeros(sshape, jnp.float32, **sc_kw)
        else:
            self._scale_sharding = None
            self.k_scales = None
            self.v_scales = None
        if self.plan.is_distributed:
            if self.fast_path:
                self._tp_step = build_tp_fused_decode_step(
                    cfg, self.plan, params, impl=self._chunk_impl,
                    specs=specs, quantized=self.quantized)
            else:
                self._tp_step = build_tp_decode_step(
                    cfg, self.plan, params, impl=impl, specs=specs)
            self._tp_verify = build_tp_verify_step(
                cfg, self.plan, params, impl=self._chunk_impl,
                specs=specs, quantized=self.quantized)
            self._tp_prefix = build_tp_prefix_step(
                cfg, self.plan, params, impl=self._chunk_impl,
                specs=specs, quantized=self.quantized)
        else:
            self._tp_step = None
            self._tp_verify = None
            self._tp_prefix = None
        # Cross-request prefix sharing: opt-in because the cache retains
        # page references past release (exact pool accounting changes);
        # the serving front door turns it on — raw-engine users keep the
        # one-request-one-prefill contract unless they ask.
        self.prefix_cache = prefix_cache
        # Tiered snapshot store (device -> host -> disk); attached to the
        # lifecycle tree so snapshots die with their branch.
        self.tier = KVTierStore(host_bytes=tier_host_bytes,
                                disk_dir=tier_disk_dir, obs=self.obs)
        self.kv.tree.attach(self.tier)
        # Token tails ride the same lifecycle kernel as the page tables:
        # kv.commit/abort/invalidate resolves both domains atomically.
        self.token_domain = TokenDomain()
        self.kv.tree.attach(self.token_domain)
        # CoW fault-service instrumentation: the former ad-hoc int
        # attributes are now registry counters; the same names stay
        # readable as properties below (benchmarks/tests read those)
        m = self.obs.metrics
        self._c_cow_dispatches = m.counter("engine.cow_dispatches")
        self._c_cow_faults = m.counter("engine.cow_faults")
        self._c_cow_inline_steps = m.counter("engine.cow_inline_steps")
        self._c_verify_dispatches = m.counter("engine.verify_dispatches")
        self._c_decode_steps = m.counter("engine.decode_steps")
        self._c_tokens = m.counter("engine.tokens_decoded")
        self._c_prefill_dispatches = m.counter("engine.prefill_dispatches")
        self._h_fork_us = m.histogram("engine.fork_us")
        self._h_commit_us = m.histogram("engine.commit_us")
        self._h_prefill_us = m.histogram("engine.prefill_us")
        self._h_checkpoint_us = m.histogram("tier.checkpoint_us")
        self._h_restore_us = m.histogram("tier.restore_us")
        self._h_decode_us = m.histogram("engine.decode_step_us")
        self._h_batch = m.histogram("engine.batch_occupancy",
                                    lo=1.0, growth=2.0, buckets=12)
        pool_bytes = int(self.k_pages.nbytes + self.v_pages.nbytes)
        if self.quantized:
            pool_bytes += int(self.k_scales.nbytes + self.v_scales.nbytes)
        # int8 pools report ~quarter the bf16 bytes at equal page count —
        # the fan-out-at-equal-bytes telemetry DESIGN §12 benches
        m.gauge(f"engine.kv_pool_bytes_{self.kv_dtype or 'fp'}").set(
            pool_bytes)
        m.gauge("engine.kv_pool_bytes").set(pool_bytes)

    # former ad-hoc counter attributes, now views over the obs registry
    # (`eng.cow_dispatches` keeps working everywhere it is asserted on)
    @property
    def cow_dispatches(self) -> int:
        """Fused ``_copy_pages`` device calls."""
        return self._c_cow_dispatches.value

    @property
    def cow_faults(self) -> int:
        """Individual page copies serviced."""
        return self._c_cow_faults.value

    @property
    def cow_inline_steps(self) -> int:
        """Steps whose faults rode the fused decode dispatch."""
        return self._c_cow_inline_steps.value

    @property
    def verify_dispatches(self) -> int:
        """Fused spec-verify device calls."""
        return self._c_verify_dispatches.value

    @property
    def prefill_dispatches(self) -> int:
        """Prefill device calls (dense or suffix-chunk) — a full
        prefix-cache hit performs zero."""
        return self._c_prefill_dispatches.value

    @staticmethod
    def _check_tp_divisibility(cfg: ArchConfig, tp: int) -> None:
        """Refuse a mesh the psums could not be correct on.

        ``sanitize`` silently replicates a non-dividing dim — fine for
        output-dim sharding (vocab), catastrophic for a dim the body
        psums over: every shard would compute the full reduction and
        the psum would multiply it by ``tp``.  Those dims must divide.
        """
        if cfg.num_kv_heads % tp or cfg.num_heads % tp:
            raise ValueError(
                f"tp={tp} must divide num_kv_heads={cfg.num_kv_heads} "
                f"and num_heads={cfg.num_heads} (KV pages and attention "
                "output shard on the head dims)")
        if cfg.is_moe:
            if cfg.num_experts % tp:
                raise ValueError(
                    f"tp={tp} must divide num_experts={cfg.num_experts}")
        elif cfg.d_ff % tp:
            raise ValueError(
                f"tp={tp} must divide d_ff={cfg.d_ff} (MLP down-proj "
                "psums over the sharded d_ff dim)")

    def _pin_kv(self, pages: jax.Array) -> jax.Array:
        """Place a KV pool on its mesh sharding (no-op single-device, and
        free when the array already has the target sharding)."""
        if self._kv_sharding is None:
            return pages
        return jax.device_put(pages, self._kv_sharding)

    def _pin_scales(self) -> None:
        if self._scale_sharding is None:
            return
        self.k_scales = jax.device_put(self.k_scales, self._scale_sharding)
        self.v_scales = jax.device_put(self.v_scales, self._scale_sharding)

    # ------------------------------------------------------------------
    def _scatter_prefill(self, pages: Sequence[int], k: jax.Array,
                         v: jax.Array, n_tokens: int) -> None:
        """Scatter ``n_tokens`` of per-layer K/V into ``pages``.

        ``k``/``v`` are ``[L, n_tokens, kv, hd]``; token ``j`` lands in
        ``pages[j // page_size]`` at offset ``j % page_size`` — callers
        pass a page list whose first page starts at token offset 0 (the
        suffix path slices its table at the page-aligned prefix
        boundary).  int8 pools quantize per page/per-kv-head here.
        """
        for pi, page in enumerate(pages):
            lo = pi * self.page_size
            hi = min(lo + self.page_size, n_tokens)
            if self.quantized:
                # per-page/per-kv-head scale over the filled part
                for pool, scales, src in (
                        ("k_pages", "k_scales", k[:, lo:hi]),
                        ("v_pages", "v_scales", v[:, lo:hi])):
                    fp = src.astype(jnp.float32)   # [L, n, kv, hd]
                    sc = jnp.maximum(
                        jnp.max(jnp.abs(fp), axis=(1, 3)) / 127.0,
                        1e-8)                      # [L, kv]
                    q8 = jnp.clip(
                        jnp.round(fp / sc[:, None, :, None]),
                        -127, 127).astype(jnp.int8)
                    setattr(self, pool, getattr(self, pool).at[
                        :, page, : hi - lo].set(q8))
                    setattr(self, scales, getattr(self, scales).at[
                        :, page].set(sc))
            else:
                self.k_pages = self.k_pages.at[
                    :, page, : hi - lo].set(k[:, lo:hi])
                self.v_pages = self.v_pages.at[
                    :, page, : hi - lo].set(v[:, lo:hi])
        # eager scatter of an unsharded prefill cache can drift the
        # pool's layout; re-pin so the hot loop never pays a
        # per-step reshard at the shard_map boundary
        self.k_pages = self._pin_kv(self.k_pages)
        self.v_pages = self._pin_kv(self.v_pages)
        self._pin_scales()

    def _dense_prefill(self, sid: int, tokens: List[int]) -> None:
        """Full-prompt prefill: dense forward, scatter into the table."""
        toks = jnp.asarray(tokens, jnp.int32)[None]
        _, cache = self.model.prefill(self.params, toks)
        self._c_prefill_dispatches.inc()
        self._scatter_prefill(self.kv.block_table(sid),
                              cache["k"][:, 0], cache["v"][:, 0],
                              len(tokens))

    def _chunk_prefill(self, sid: int, tokens: List[int],
                       covered: int) -> None:
        """Suffix prefill: the first ``covered`` tokens are already in
        shared prefix pages; compute KV only for the remainder, attending
        to the shared pages through the block table (one dispatch)."""
        table = self.kv.block_table(sid)
        bt = np.zeros((1, self.max_pages), np.int32)
        bt[0, :len(table)] = table
        suffix = jnp.asarray(tokens[covered:], jnp.int32)[None]
        args = (self.k_pages, self.v_pages, jnp.asarray(bt),
                jnp.asarray([covered], jnp.int32), suffix)
        if self.quantized:
            args = args + (self.k_scales, self.v_scales)
        if self._tp_prefix is not None:
            k, v = self._tp_prefix(self.params, *args)
        else:
            k, v = paged_prefix_step(self.cfg, self.params, *args,
                                     impl=self._chunk_impl)
        self._c_prefill_dispatches.inc()
        # the prefix boundary is page-aligned (partial tail pages only
        # match whole prompts, which skip prefill entirely)
        self._scatter_prefill(table[covered // self.page_size:],
                              k[:, 0], v[:, 0], len(tokens) - covered)

    def add_request(self, prompt: Sequence[int]) -> int:
        """Prefill a prompt into a fresh paged sequence.

        Invariant: ``kv.length == len(tokens) - 1`` — the last token is
        "pending": its KV is written by the decode step that consumes it.

        With ``prefix_cache`` enabled the prompt is first matched against
        the cross-request prefix cache: cached page runs are adopted
        CoW-shared into the new sequence's table, and only the uncovered
        suffix is prefilled (zero dispatches on a whole-prompt hit — N
        users sending the same prompt pay ONE prefill total).  The new
        prompt's own pages are then registered for the next request.
        """
        prompt = list(prompt)
        assert prompt, "empty prompt"
        t0 = time.perf_counter_ns()
        n_cached = len(prompt) - 1
        shared: List[int] = []
        covered = 0
        if self.prefix_cache and n_cached:
            shared, covered = self.kv.match_prefix(prompt[:-1])
        sid = self.kv.new_seq(length=n_cached,
                              prefix_pages=shared or None)
        if n_cached > covered:
            if covered:
                self._chunk_prefill(sid, prompt[:-1], covered)
            else:
                self._dense_prefill(sid, prompt[:-1])
        if self.prefix_cache and n_cached:
            self.kv.register_prefix(sid, prompt[:-1])
        self.token_domain.seed(sid, prompt)
        self._h_prefill_us.observe((time.perf_counter_ns() - t0) / 1000.0)
        return sid

    # ------------------------------------------------------------------
    # branch ops (the paper's lifecycle, resolved by the shared kernel)
    # ------------------------------------------------------------------
    def fork(self, seq: int, n: int, *, eager_cow: bool = False) -> List[int]:
        """Fork ``n`` branches (token tails copied by the lifecycle hook).

        With ``eager_cow`` the shared-tail copy-on-write every child
        would fault at its first append is hoisted into the fork itself
        and serviced as ONE fused ``_copy_pages`` dispatch for the whole
        sibling set (``KVBranchManager.fork_batch``) — the vectorized
        ``branch(parent, n=k)`` hot path of ``repro.api``.  The default
        stays lazy so a fork that never decodes remains zero-copy.
        """
        t0 = time.perf_counter_ns()
        if not eager_cow:
            children = self.kv.fork(seq, n)
        else:
            children, ops = self.kv.fork_batch(seq, n)
            if ops:
                self._service_cow([op.src_page for op in ops],
                                  [op.dst_page for op in ops])
        # per-branch creation latency — the paper's sub-350 µs claim
        self._h_fork_us.observe(
            (time.perf_counter_ns() - t0) / 1000.0 / n)
        return children

    def commit(self, seq: int) -> int:
        t0 = time.perf_counter_ns()
        parent = self.kv.commit(seq)  # tokens + pages promoted atomically
        self._h_commit_us.observe((time.perf_counter_ns() - t0) / 1000.0)
        return parent

    def abort(self, seq: int) -> None:
        self.kv.abort(seq)

    def release(self, seq: int) -> None:
        """Evict a finished/abandoned sequence, freeing every domain."""
        self.kv.release(seq)

    def truncate(self, seq: int, n_tokens: int) -> None:
        """Keep only the first ``n_tokens`` tokens of a sequence.

        The speculative-decoding primitive: a draft branch commits its
        verified prefix by dropping the unverified suffix first.  Both
        domains shrink together, preserving ``kv.length == tokens - 1``
        (the last retained token becomes the pending one).
        """
        if n_tokens < 1:
            raise ValueError("cannot truncate below one token")
        self.kv.truncate(seq, n_tokens - 1)
        self.token_domain.truncate(seq, n_tokens)

    # ------------------------------------------------------------------
    # tiering: checkpoint (demote) / restore (promote)
    # ------------------------------------------------------------------
    def checkpoint(self, seq: int) -> int:
        """Demote a branch's KV out of the device pool into the tier
        store (host RAM, spilling to disk under pressure).

        The snapshot carries the pages in the pool's native dtype (int8
        pages travel with their per-page scales), the block-table shape
        and the token tail, so :meth:`restore` is token-identical.  The
        branch stays live — held in the lifecycle tree, invisible to
        decode until restored.  Returns the number of device pages
        freed.
        """
        t0 = time.perf_counter_ns()
        table = self.kv.block_table(seq)      # raises ENOENT if unknown
        length = self.kv.length(seq)
        tokens = list(self.token_domain.get(seq))
        idx = jnp.asarray(table, jnp.int32)
        snap = KVSnapshot(
            seq_id=seq, length=length, n_pages=len(table), tokens=tokens,
            k_pages=np.asarray(self.k_pages[:, idx]),
            v_pages=np.asarray(self.v_pages[:, idx]),
            k_scales=(np.asarray(self.k_scales[:, idx])
                      if self.quantized else None),
            v_scales=(np.asarray(self.v_scales[:, idx])
                      if self.quantized else None))
        # demote AFTER the gather: it validates (live, leaf, not already
        # tiered) and raises with the snapshot discarded and the device
        # state untouched
        self.kv.demote(seq)
        self.tier.put(snap)
        self._h_checkpoint_us.observe(
            (time.perf_counter_ns() - t0) / 1000.0)
        return len(table)

    def restore(self, seq: int) -> None:
        """Re-seat a tiered branch into freshly allocated device pages.

        Fails with the snapshot intact and the branch still tiered if
        the pool cannot fit it (``PoolExhausted``) — the caller demotes
        something else and retries (the scheduler's demote-before-deny).
        """
        t0 = time.perf_counter_ns()
        snap = self.tier.get(seq)             # ENOENT if never tiered
        pages = self.kv.promote(seq)          # ENOSPC leaves snap stored
        if pages:
            idx = jnp.asarray(pages, jnp.int32)
            self.k_pages = self._pin_kv(
                self.k_pages.at[:, idx].set(jnp.asarray(snap.k_pages)))
            self.v_pages = self._pin_kv(
                self.v_pages.at[:, idx].set(jnp.asarray(snap.v_pages)))
            if self.quantized and snap.k_scales is not None:
                self.k_scales = self.k_scales.at[:, idx].set(
                    jnp.asarray(snap.k_scales))
                self.v_scales = self.v_scales.at[:, idx].set(
                    jnp.asarray(snap.v_scales))
                self._pin_scales()
        self.token_domain.seed(seq, snap.tokens)
        self.tier.drop(seq)
        self._h_restore_us.observe((time.perf_counter_ns() - t0) / 1000.0)

    def is_tiered(self, seq: int) -> bool:
        return self.kv.is_tiered(seq)

    # ------------------------------------------------------------------
    def _service_cow(self, src: List[int], dst: List[int]) -> None:
        """Service all pending CoW faults in one fused device dispatch.

        Unchanged under a mesh: page indices are kv-head-agnostic, so
        the same gather/scatter partitions cleanly over the sharded
        kv-head dim — each shard copies its slice of every faulted
        page, still ONE dispatch for the whole batch.
        """
        if not src:
            return            # empty plan: nothing to dispatch
        s, d = _pad_pow2(src, dst)
        if self.quantized:
            (self.k_pages, self.v_pages, self.k_scales,
             self.v_scales) = _copy_pages_scaled(
                self.k_pages, self.v_pages, self.k_scales,
                self.v_scales, s, d)
            self._pin_scales()
        else:
            self.k_pages, self.v_pages = _copy_pages(
                self.k_pages, self.v_pages, s, d)
        self.k_pages = self._pin_kv(self.k_pages)
        self.v_pages = self._pin_kv(self.v_pages)
        self._c_cow_dispatches.inc()
        self._c_cow_faults.inc(len(src))

    def decode(self, seq_ids: Sequence[int], *, greedy: Any = True,
               temperature: Any = 1.0,
               key: Optional[jax.Array] = None) -> List[int]:
        """One token for each sequence (they decode as one batch).

        ``greedy`` and ``temperature`` may be scalars (whole batch) or
        per-sequence lists, so one continuous batch can mix greedy
        verification branches with sampled exploration branches at
        different temperatures — the exploration driver multiplexes many
        policies' decode work into a single device dispatch.
        """
        b = len(seq_ids)
        t0 = time.perf_counter_ns()
        # resolve sampling rows BEFORE any metadata mutates: a mis-sized
        # per-sequence list must fail cleanly, not after slots were
        # reserved and the device step ran
        greedy_row = ([bool(greedy)] * b if isinstance(greedy, (bool, int))
                      else [bool(g) for g in greedy])
        temp_row = ([float(temperature)] * b
                    if isinstance(temperature, (int, float))
                    else [float(t) for t in temperature])
        if len(greedy_row) != b or len(temp_row) != b:
            raise ValueError("per-sequence sampling rows must match batch")
        lengths_before = np.array([self.kv.length(s) for s in seq_ids],
                                  np.int32)
        # refuse BEFORE mutating metadata if any sequence's table would
        # outgrow the per-sequence limit (dense_block_tables would raise
        # only after the batch's slots were already reserved)
        for s, ln in zip(seq_ids, lengths_before):
            if int(ln) // self.page_size + 1 > self.max_pages:
                raise ValueError(
                    f"sequence {s} would need "
                    f"{int(ln) // self.page_size + 1} pages > "
                    f"{self.max_pages} (max_pages_per_seq)")
        # host: reserve slots transactionally — if the pool exhausts on a
        # later batch member, earlier members' tables/lengths/CoW swaps
        # are rolled back before the MemoryError propagates, so a decode
        # step either runs for the whole batch or mutates nothing
        slot_lists = self.kv.prepare_append_batch(seq_ids, 1)
        slots = [sl[0] for sl in slot_lists]
        cow_src: List[int] = []
        cow_dst: List[int] = []
        for slot in slots:
            for cow in slot.cow:
                cow_src.append(cow.src_page)
                cow_dst.append(cow.dst_page)
        if not self.fast_path and cow_src:
            # legacy path: service faults as their own dispatch first
            self._service_cow(cow_src, cow_dst)
        bt, _ = self.kv.dense_block_tables(seq_ids, self.max_pages)
        last_tokens = jnp.asarray(
            [[self.token_domain.get(s)[-1]] for s in seq_ids], jnp.int32)

        step_args = (
            self.k_pages, self.v_pages,
            jnp.asarray(bt), jnp.asarray(lengths_before),
            jnp.asarray([sl.page for sl in slots], jnp.int32),
            jnp.asarray([sl.offset for sl in slots], jnp.int32),
            last_tokens,
        )
        if self.fast_path:
            # fused path: faults ride the decode dispatch itself as a
            # CoW indirection vector — cow_dispatches stays untouched
            cs, cd = _pad_pow2(cow_src, cow_dst)
            if cow_src:
                self._c_cow_faults.inc(len(cow_src))
                self._c_cow_inline_steps.inc()
            step_args = step_args + (cs, cd)
            if self.quantized:
                step_args = step_args + (self.k_scales, self.v_scales)
            if self._tp_step is not None:
                out = self._tp_step(self.params, *step_args)
            else:
                out = paged_fused_decode_step(
                    self.cfg, self.params, *step_args,
                    impl=self._chunk_impl)
            if self.quantized:
                (logits, self.k_pages, self.v_pages,
                 self.k_scales, self.v_scales) = out
                self._pin_scales()
            else:
                logits, self.k_pages, self.v_pages = out
        elif self._tp_step is not None:
            logits, self.k_pages, self.v_pages = self._tp_step(
                self.params, *step_args)
        else:
            logits, self.k_pages, self.v_pages = paged_decode_step(
                self.cfg, self.params, *step_args, impl=self.attn_impl)
        logits = logits[:, 0]
        if all(greedy_row):
            nxt = jnp.argmax(logits, axis=-1)
        else:
            assert key is not None
            temps = jnp.asarray(temp_row, jnp.float32)
            sampled = jax.random.categorical(key, logits / temps[:, None])
            nxt = jnp.where(jnp.asarray(greedy_row),
                            jnp.argmax(logits, axis=-1), sampled)
        out = [int(t) for t in np.asarray(nxt)]
        for s, t in zip(seq_ids, out):
            self.token_domain.append(s, t)
        # np.asarray above synced the device step, so this wall time
        # covers host bookkeeping + the dispatch it timed
        dt_us = (time.perf_counter_ns() - t0) / 1000.0
        self._h_decode_us.observe(dt_us)
        self._h_batch.observe(b)
        self._c_decode_steps.inc()
        self._c_tokens.inc(b)
        tr = self.obs.tracer
        if tr.enabled:
            tr.instant(ENGINE_TRACK, "decode_step", batch=b,
                       us=round(dt_us, 1))
        return out

    def spec_verify(self, seq: int,
                    drafts: Sequence[Sequence[int]]) -> List[List[int]]:
        """Score draft continuations of ``seq`` in ONE fused dispatch.

        Each draft is k proposed next tokens.  The step teacher-forces
        ``[pending_token] + draft[:-1]`` per row over the sequence's
        (shared, read-only) block table, so row position ``i`` yields the
        target's greedy token *given the draft's first i tokens* — the
        exact sequential-verifier result, k dispatches collapsed to one.
        Pure scoring: no KV is written, ``seq`` is untouched.

        Returns the target's greedy token at every draft position, one
        row per draft.  Callers accept each draft's longest prefix that
        matches its row (see ``speculative_decode``).
        """
        drafts = [list(d) for d in drafts]
        if not drafts:
            raise ValueError("need at least one draft")
        t = len(drafts[0])
        if t < 1 or any(len(d) != t for d in drafts):
            raise ValueError("drafts must be non-empty and equal-length")
        length = self.kv.length(seq)       # raises if seq is not live
        pending = self.token_domain.get(seq)[-1]
        rows = jnp.asarray([[pending] + d[:-1] for d in drafts], jnp.int32)
        bt_row, _ = self.kv.dense_block_tables([seq], self.max_pages)
        n = len(drafts)
        bt = jnp.asarray(np.tile(np.asarray(bt_row), (n, 1)))
        lens = jnp.full((n,), length, jnp.int32)
        args = (self.k_pages, self.v_pages, bt, lens, rows)
        if self.quantized:
            args = args + (self.k_scales, self.v_scales)
        if self._tp_verify is not None:
            logits = self._tp_verify(self.params, *args)
        else:
            logits = paged_verify_step(self.cfg, self.params, *args,
                                       impl=self._chunk_impl)
        self._c_verify_dispatches.inc()
        out = np.asarray(jnp.argmax(logits, axis=-1))
        return [[int(x) for x in row] for row in out]

    def tokens(self, seq: int) -> List[int]:
        return list(self.token_domain.get(seq))

    def stats(self) -> Dict[str, int]:
        st = self.kv.stats()
        st["token_tails"] = len(self.token_domain)
        st["cow_dispatches"] = self.cow_dispatches
        st["cow_faults"] = self.cow_faults
        st["cow_inline_steps"] = self.cow_inline_steps
        st["verify_dispatches"] = self.verify_dispatches
        st["prefill_dispatches"] = self.prefill_dispatches
        st["prefix_cache"] = self.prefix_cache
        st["tier_snapshots"] = len(self.tier)
        st["tp"] = self.tp
        st["attn_impl"] = self.attn_impl
        st["kv_dtype"] = self.kv_dtype or str(self.cfg.dtype)
        return st
