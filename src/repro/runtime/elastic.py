"""Elastic scaling: re-mesh + re-shard on device-count change.

Checkpoints are logical (mesh-free manifests of full arrays), so scaling
is: drain → commit checkpoint → ``plan_mesh(surviving_devices)`` →
restore onto the new mesh.  For in-flight resharding (no restart),
``reshard`` device_puts every leaf onto its sharding under the new plan —
XLA moves only the bytes that change owners.
"""

from __future__ import annotations

import math
from typing import Any, List, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh

from repro.configs.base import ArchConfig
from repro.distributed.mesh import ParallelPlan, plan_from_mesh
from repro.distributed.sharding import param_shardings


def factor_mesh(n_devices: int, prefer_model: int = 16
                ) -> Tuple[int, int]:
    """Largest model axis ≤ prefer_model that divides n_devices."""
    model = min(prefer_model, n_devices)
    while model > 1 and n_devices % model:
        model -= 1
    return n_devices // model, model


def plan_mesh(devices: Optional[Sequence[Any]] = None,
              prefer_model: int = 16,
              multi_pod: bool = False) -> ParallelPlan:
    """Build the best-fit mesh from the currently live devices."""
    devices = list(devices if devices is not None else jax.devices())
    n = len(devices)
    if multi_pod and n % 2 == 0 and n >= 4:
        data, model = factor_mesh(n // 2, prefer_model)
        mesh = jax.make_mesh((2, data, model), ("pod", "data", "model"),
                             devices=devices)
    else:
        data, model = factor_mesh(n, prefer_model)
        mesh = jax.make_mesh((data, model), ("data", "model"),
                             devices=devices)
    return plan_from_mesh(mesh)


def reshard(cfg: ArchConfig, state: Any, new_plan: ParallelPlan) -> Any:
    """Move a (params-shaped) pytree onto the new plan's shardings."""
    sh = param_shardings(cfg, new_plan, state)
    return jax.tree_util.tree_map(
        lambda x, s: jax.device_put(x, s) if s is not None else x,
        state, sh)


class ElasticController:
    """Drives shrink/grow events: each event re-plans the mesh and
    re-shards (or restores) the training state.

    On a real cluster the device list comes from the coordinator's
    health service; tests drive it with explicit device subsets.
    """

    def __init__(self, cfg: ArchConfig, prefer_model: int = 16):
        self.cfg = cfg
        self.prefer_model = prefer_model
        self.events: List[Tuple[int, Tuple[int, ...]]] = []

    def remesh(self, state: Any, devices: Sequence[Any]) -> Tuple[Any,
                                                                  ParallelPlan]:
        plan = plan_mesh(devices, self.prefer_model)
        new_state = reshard(self.cfg, state, plan)
        self.events.append((len(devices), tuple(plan.mesh.shape.values())))
        return new_state, plan
