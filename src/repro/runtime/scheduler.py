"""Serving scheduler — admission, continuous batching, fork admission.

The engine/scheduler split mirrors production LLM servers: the
:class:`~repro.runtime.serve_loop.ServeEngine` owns the device step and
the per-sequence state domains (pages + token tails on the shared
lifecycle kernel), while the :class:`Scheduler` decides *what runs when*:

* **Admission** — requests wait in a FIFO until the page pool can hold
  their prompt plus a decode reserve, so a burst cannot -ENOSPC a decode
  step mid-flight.
* **Continuous batching** — every step decodes all runnable sequences
  (live, unfrozen, unfinished), chunked into device batches; new
  requests join the running batch at page-granularity with no draining.
* **Page-budget-aware fork admission** — ``fork`` is denied (not
  crashed) when the pool cannot absorb the worst-case immediate cost of
  ``n`` branches (one CoW'd tail page each plus the decode reserve).
  Agentic exploration degrades gracefully under memory pressure instead
  of taking down the serving loop.

Branch bookkeeping is intentionally absent here: the scheduler tracks
only which sequence ids it may decode, and asks the lifecycle kernel for
liveness each step, so commits/aborts/invalidations performed by agents
(directly or through :class:`~repro.core.runtime_api.BranchRuntime`)
are observed without any scheduler-side state machine (DESIGN §3).
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence

import jax

from repro.core.errors import BranchError
from repro.core.lifecycle import BranchStatus
from repro.runtime.serve_loop import ServeEngine


class AdmissionDenied(BranchError):
    """Raised when fork admission would overrun the page budget.

    The -EAGAIN of the serving layer: the caller may retry after commits
    or retirements recycle pages.
    """


@dataclass
class SchedulerConfig:
    max_batch: int = 8          # device batch width per decode dispatch
    decode_reserve: int = 2     # pages kept free per runnable sequence
    fork_cost_pages: int = 1    # worst-case immediate pages per new branch


@dataclass
class Request:
    """One user request: a prompt plus a decode budget."""

    req_id: int
    prompt: List[int]
    max_new_tokens: int
    seq: Optional[int] = None          # assigned at admission
    finished: List[int] = field(default_factory=list)  # completed outputs


class Scheduler:
    """Admission + continuous batching over the engine's live branches."""

    def __init__(self, engine: ServeEngine,
                 config: Optional[SchedulerConfig] = None):
        self.engine = engine
        self.config = config or SchedulerConfig()
        self._req_ids = itertools.count(0)
        self._waiting: List[Request] = []
        self._requests: Dict[int, Request] = {}
        # every sequence the scheduler may decode, mapped to its request
        self._seq_owner: Dict[int, int] = {}
        self.steps = 0
        self.tokens_generated = 0

    # ------------------------------------------------------------------
    # admission
    # ------------------------------------------------------------------
    def _pages_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.engine.page_size)

    def submit(self, prompt: Sequence[int], max_new_tokens: int = 16) -> int:
        """Queue a request; it is admitted when the page budget allows.

        A request that could never fit the pool — even with it entirely
        free — is rejected up front (``AdmissionDenied``) instead of
        blocking the FIFO head and starving everything behind it.
        """
        need_min = (self._pages_for(len(prompt))
                    + self.config.decode_reserve)
        if need_min > self.engine.kv.num_pages:
            raise AdmissionDenied(
                f"prompt needs {need_min} pages but the pool only has "
                f"{self.engine.kv.num_pages}; request can never be admitted")
        req = Request(req_id=next(self._req_ids), prompt=list(prompt),
                      max_new_tokens=max_new_tokens)
        self._requests[req.req_id] = req
        self._waiting.append(req)
        return req.req_id

    def admit(self) -> List[int]:
        """Admit waiting requests in FIFO order while pages last."""
        admitted: List[int] = []
        while self._waiting:
            req = self._waiting[0]
            need = (self._pages_for(len(req.prompt))
                    + self.config.decode_reserve)
            if self.engine.kv.free_pages < need:
                break   # FIFO: do not starve the head request
            self._waiting.pop(0)
            req.seq = self.engine.add_request(req.prompt)
            self._seq_owner[req.seq] = req.req_id
            admitted.append(req.req_id)
        return admitted

    # ------------------------------------------------------------------
    # fork admission
    # ------------------------------------------------------------------
    def fork(self, seq: int, n: int) -> List[int]:
        """Fork ``n`` exploration branches if the page budget allows.

        Worst case each branch immediately CoW-faults its shared tail
        page, and every runnable sequence still needs its decode
        reserve; deny the fork (``AdmissionDenied``) rather than let a
        later decode step hit -ENOSPC.
        """
        if seq not in self._seq_owner:
            raise BranchError(f"sequence {seq} is not scheduled here")
        # post-fork runnable set: the parent freezes out, n children join
        post_fork_runnable = len(self.runnable()) - 1 + n
        need = (n * self.config.fork_cost_pages
                + self.config.decode_reserve * post_fork_runnable)
        if self.engine.kv.free_pages < need:
            raise AdmissionDenied(
                f"fork({seq}, n={n}) needs ~{need} free pages, "
                f"have {self.engine.kv.free_pages} (-EAGAIN)")
        children = self.engine.fork(seq, n)
        owner = self._seq_owner[seq]
        for c in children:
            self._seq_owner[c] = owner
        return children

    # ------------------------------------------------------------------
    # continuous batching
    # ------------------------------------------------------------------
    def _request_done(self, req: Request, seq: int) -> bool:
        # kv.length == len(tokens) - 1 (last token pending), so produced
        # count is O(1) host work — no token-list copy on the hot path
        produced = self.engine.kv.length(seq) + 1 - len(req.prompt)
        return produced >= req.max_new_tokens

    def runnable(self) -> List[int]:
        """Sequences that may decode this step.

        Asks the lifecycle kernel directly: ACTIVE sequences run, FROZEN
        origins wait for their children, and anything resolved by a
        commit/abort/invalidation is dropped from tracking here.
        """
        out: List[int] = []
        for seq in list(self._seq_owner):
            status = self.engine.kv.status(seq)
            if status is BranchStatus.ACTIVE:
                out.append(seq)
            elif status is not BranchStatus.FROZEN:
                # resolved (committed / aborted / stale): stop tracking
                self._seq_owner.pop(seq, None)
        return out

    def _retire(self, seq: int) -> None:
        req = self._requests[self._seq_owner[seq]]
        node = self.engine.kv.tree.node(seq)
        if node.parent is None:
            # a finished root request leaves the engine entirely
            req.finished = self.engine.tokens(seq)
            self.engine.release(seq)
            self._seq_owner.pop(seq, None)
        # a finished *branch* stays live: the agent decides commit/abort

    def step(self, *, greedy: bool = True, temperature: float = 1.0,
             key: Optional[jax.Array] = None) -> Dict[str, Any]:
        """One scheduling round: admit, batch-decode, retire.

        Returns counters for the serving loop / benchmarks.
        """
        admitted = self.admit()
        batch = [s for s in self.runnable()
                 if not self._request_done(
                     self._requests[self._seq_owner[s]], s)]
        decoded = 0
        for lo in range(0, len(batch), self.config.max_batch):
            group = batch[lo: lo + self.config.max_batch]
            sub = None
            if key is not None:
                key, sub = jax.random.split(key)
            self.engine.decode(group, greedy=greedy,
                               temperature=temperature, key=sub)
            decoded += len(group)
        retired = 0
        for seq in list(self._seq_owner):
            status = self.engine.kv.status(seq)
            if status is BranchStatus.ACTIVE and self._request_done(
                    self._requests[self._seq_owner[seq]], seq):
                self._retire(seq)
                retired += int(seq not in self._seq_owner)
        self.steps += 1
        self.tokens_generated += decoded
        return {
            "admitted": len(admitted),
            "batch": len(batch),
            "decoded": decoded,
            "retired": retired,
            "waiting": len(self._waiting),
            "running": len(self._seq_owner),
        }

    def run(self, max_steps: int = 1000, **decode_kw: Any) -> int:
        """Step until no work remains; returns tokens generated."""
        t0 = self.tokens_generated
        for _ in range(max_steps):
            st = self.step(**decode_kw)
            if st["decoded"] == 0 and st["waiting"] == 0:
                break
        return self.tokens_generated - t0

    # ------------------------------------------------------------------
    def result(self, req_id: int) -> List[int]:
        """Final token list of a retired request."""
        return list(self._requests[req_id].finished)

    def seq_of(self, req_id: int) -> int:
        """The admitted root sequence of a request (its fork origin)."""
        seq = self._requests[req_id].seq
        if seq is None:
            raise BranchError(f"request {req_id} not admitted yet")
        return seq

    def stats(self) -> Dict[str, Any]:
        st = self.engine.stats()
        st.update(steps=self.steps, tokens_generated=self.tokens_generated,
                  waiting=len(self._waiting), running=len(self._seq_owner))
        return st


__all__ = ["AdmissionDenied", "Request", "Scheduler", "SchedulerConfig"]
