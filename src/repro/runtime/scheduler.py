"""Serving scheduler — admission, continuous batching, fork admission.

The engine/scheduler split mirrors production LLM servers: the
:class:`~repro.runtime.serve_loop.ServeEngine` owns the device step and
the per-sequence state domains (pages + token tails on the shared
lifecycle kernel), while the :class:`Scheduler` decides *what runs when*:

* **Admission** — requests wait in a FIFO behind a worst-case page
  **reservation ledger**: a request is admitted only when the pool can
  hold ``pages_for(prompt + max_new_tokens)`` on top of every reservation
  already outstanding, so an admitted request can always decode to
  completion — the pool cannot -ENOSPC mid-flight.  A request whose
  worst case exceeds the pool, or the per-sequence block-table limit,
  can never run and is rejected at ``submit`` (``AdmissionDenied``).
* **Continuous batching** — every step decodes all runnable sequences
  (live, unfrozen, unfinished), chunked into device batches; new
  requests join the running batch at page-granularity with no draining.
* **Page-budget-aware fork admission** — ``fork`` is denied (not
  crashed) when the ledger cannot absorb the worst-case cost of ``n``
  branches (one CoW'd tail page each plus every page the branch may
  still append before its request's decode budget runs out).  Agentic
  exploration degrades gracefully under memory pressure (-EAGAIN)
  instead of taking down the serving loop.

Branch bookkeeping is intentionally absent here: the scheduler tracks
only which sequence ids it may decode (and their reservations), and asks
the lifecycle kernel for liveness each step, so commits/aborts/
invalidations performed by agents (directly or through
:class:`~repro.core.runtime_api.BranchRuntime`) are observed without any
scheduler-side state machine (DESIGN §3).  Subtrees that resolve are
*reaped* from the kernel once the scheduler stops tracking them, so a
long-running loop does not accumulate lifecycle nodes or payload
entries for retired work.
"""

from __future__ import annotations

import itertools
import time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence

import jax

# AdmissionDenied now lives in the shared errno vocabulary
# (repro.core.errors); re-exported here for backward compatibility.
from repro.core.errors import AdmissionDenied, BranchError, Errno
from repro.core.lifecycle import BranchStatus
from repro.runtime.serve_loop import ServeEngine


@dataclass
class SchedulerConfig:
    max_batch: int = 8          # device batch width per decode dispatch
    seed: int = 0               # scheduler-owned PRNG for sampled decode


@dataclass
class Request:
    """One user request: a prompt plus a decode budget."""

    req_id: int
    prompt: List[int]
    max_new_tokens: int
    worst_pages: int = 0               # pages_for(prompt + max_new_tokens)
    seq: Optional[int] = None          # assigned at admission
    hold_on_admit: bool = False        # park immediately (explorations)
    submitted_ns: int = 0              # queue-wait clock start


class Scheduler:
    """Admission + continuous batching over the engine's live branches.

    .. deprecated:: the raw verbs (``submit``/``fork``/``hold``/``wait``/
       ``finish``/``result``) are the *mechanism* behind
       :class:`repro.api.BranchSession` and remain stable for internal
       use, but application code should enter through ``repro.api`` —
       one handle table, one flags word, one errno discipline, and a
       poll/wait event interface over every state domain.
    """

    def __init__(self, engine: ServeEngine,
                 config: Optional[SchedulerConfig] = None):
        self.engine = engine
        self.config = config or SchedulerConfig()
        self._req_ids = itertools.count(0)
        self._waiting: List[Request] = []
        self._requests: Dict[int, Request] = {}
        # every sequence the scheduler may decode, mapped to its request
        self._seq_owner: Dict[int, int] = {}
        # worst-case pages each tracked sequence may still hold from the
        # pool; the sum over all tracked sequences never exceeds the pool
        self._reserved: Dict[int, int] = {}
        # finished token lists, claimed one-shot via result()
        self._results: Dict[int, List[int]] = {}
        # sequences parked by an exploration driver: tracked (they keep
        # their reservations) but neither decoded nor auto-retired until
        # released — the policy, not the budget, decides their pace
        self._holds: set = set()
        # reservations of checkpointed (tiered) sequences: moved out of
        # the live ledger — their device pages are freed — and moved
        # back at restore() after a budget re-check
        self._tiered_reserved: Dict[int, int] = {}
        # per-sequence sampling overrides: seq -> (greedy, temperature)
        self._sampling: Dict[int, tuple] = {}
        self._key = jax.random.PRNGKey(self.config.seed)
        self.steps = 0
        self.tokens_generated = 0
        # admission outcomes + ledger telemetry, on the engine's hub
        self.obs = engine.obs
        m = self.obs.metrics
        self._c_submitted = m.counter("sched.submitted")
        self._c_rejected = m.counter("sched.rejected")
        self._c_admitted = m.counter("sched.admitted")
        self._c_forks_admitted = m.counter("sched.forks_admitted")
        self._c_forks_denied = m.counter("sched.forks_denied")
        self._c_retired = m.counter("sched.retired")
        self._c_demotions = m.counter("sched.demotions")
        self._c_restores = m.counter("sched.restores")
        self._h_admission_wait = m.histogram("sched.admission_wait_us")
        self._g_reserved = m.gauge("sched.pages_reserved")

    @property
    def tp(self) -> int:
        """Tensor-parallel width of the engine's serving mesh (1 when
        single-device).  The scheduler itself is mesh-agnostic: its
        ledger counts pages, and a page id means the same thing on
        every shard."""
        return self.engine.tp

    # ------------------------------------------------------------------
    # admission
    # ------------------------------------------------------------------
    def _pages_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.engine.page_size)

    def _pages_reserved(self) -> int:
        return sum(self._reserved.values())

    def submit(self, prompt: Sequence[int], max_new_tokens: int = 16,
               *, hold: bool = False) -> int:
        """Queue a request; it is admitted when the page budget allows.

        With ``hold=True`` the admitted root is parked in the same
        admission transaction — it never decodes a token until its owner
        (an exploration policy) releases it, regardless of where in a
        scheduler step the admission lands.

        A request that could never run to completion — its worst case
        (prompt + full decode budget) exceeds the pool even entirely
        free, or the per-sequence block-table limit — is rejected up
        front (``AdmissionDenied``) instead of blocking the FIFO head or
        blowing up a later decode step.
        """
        worst = self._pages_for(len(prompt) + max_new_tokens)
        self._c_submitted.inc()
        if worst > self.engine.kv.num_pages:
            self._c_rejected.inc()
            raise AdmissionDenied(
                f"request needs up to {worst} pages but the pool only has "
                f"{self.engine.kv.num_pages}; it can never be admitted",
                errno=Errno.ENOSPC)
        if worst > self.engine.max_pages:
            self._c_rejected.inc()
            raise AdmissionDenied(
                f"request needs up to {worst} pages but a sequence's block "
                f"table holds at most {self.engine.max_pages}; it can "
                "never decode to completion", errno=Errno.ENOSPC)
        req = Request(req_id=next(self._req_ids), prompt=list(prompt),
                      max_new_tokens=max_new_tokens, worst_pages=worst,
                      hold_on_admit=hold,
                      submitted_ns=time.perf_counter_ns())
        self._requests[req.req_id] = req
        self._waiting.append(req)
        return req.req_id

    def _demote_for(self, deficit: int) -> int:
        """Checkpoint held branches until ``deficit`` reservation pages
        free up (demote-before-deny).  Held branches are the coldest
        work the scheduler owns — parking them in the tier store instead
        of denying the FIFO head turns page pressure into host/disk
        bytes.  Branches that cannot demote (frozen origins, already
        tiered) are skipped.  Returns the reservation pages released.
        """
        released = 0
        for seq in sorted(s for s in self._holds if s in self._reserved):
            if released >= deficit:
                break
            worst = self._reserved[seq]
            try:
                self.checkpoint(seq)
            except BranchError:
                continue
            released += worst
        return released

    def admit(self) -> List[int]:
        """Admit waiting requests in FIFO order while reservations fit.

        When the head request does not fit, held branches are demoted to
        the tier store before the head is made to wait (demote-before-
        deny) — admission is denied only once nothing else can move.
        """
        admitted: List[int] = []
        while self._waiting:
            req = self._waiting[0]
            budget = self.engine.kv.num_pages - self._pages_reserved()
            if req.worst_pages > budget:
                self._demote_for(req.worst_pages - budget)
                budget = self.engine.kv.num_pages - self._pages_reserved()
            if req.worst_pages > budget:
                break   # FIFO: do not starve the head request
            self._waiting.pop(0)
            req.seq = self.engine.add_request(req.prompt)
            self._seq_owner[req.seq] = req.req_id
            self._reserved[req.seq] = req.worst_pages
            if req.hold_on_admit:
                self._holds.add(req.seq)
            admitted.append(req.req_id)
            self._c_admitted.inc()
            self._h_admission_wait.observe(
                (time.perf_counter_ns() - req.submitted_ns) / 1000.0)
        if admitted:
            self._g_reserved.set(self._pages_reserved())
        return admitted

    # ------------------------------------------------------------------
    # fork admission
    # ------------------------------------------------------------------
    def _fork_cost(self, seq: int, n: int) -> tuple:
        """(worst-case pages ``fork(seq, n)`` needs, current free budget)."""
        if seq not in self._seq_owner:
            raise BranchError(f"sequence {seq} is not scheduled here")
        req = self._requests[self._seq_owner[seq]]
        table_len = len(self.engine.kv.block_table(seq))
        child_cost = req.worst_pages - table_len + 1
        budget = self.engine.kv.num_pages - self._pages_reserved()
        return n * child_cost, budget

    def can_fork(self, seq: int, n: int) -> bool:
        """Whether ``fork(seq, n)`` would be admitted right now.

        Side-effect free: composite creates use it to check the cheap
        ledger BEFORE forking other domains, so a backpressure retry
        loop does not churn (fork + unwind) the store tree every round.
        """
        needed, budget = self._fork_cost(seq, n)
        return needed <= budget

    def fork(self, seq: int, n: int, *, eager_cow: bool = False) -> List[int]:
        """Fork ``n`` exploration branches if the page budget allows.

        All ``n`` siblings are admitted under ONE reservation-ledger
        transaction (one cost check, one exclusive commit group) — the
        vectorized-fork property ``repro.api``'s ``branch(parent, n=k)``
        builds on.  Worst case each branch CoW-faults its shared tail
        page and then grows its table from the fork point to the
        request's full decode budget; deny the fork (``AdmissionDenied``)
        rather than let a later decode step hit -ENOSPC.  The frozen
        origin keeps its own reservation (it holds its pages and resumes
        when the children resolve), so shared pages are never
        double-booked.  ``eager_cow`` hoists every child's tail-page CoW
        into one fused device dispatch here (see ``ServeEngine.fork``);
        the ledger already reserves that page per child.
        """
        needed, budget = self._fork_cost(seq, n)
        if needed > budget:
            self._c_forks_denied.inc()
            raise AdmissionDenied(
                f"fork({seq}, n={n}) needs up to {needed} free "
                f"pages, budget is {budget} (-EAGAIN)")
        child_cost = needed // n
        children = self.engine.fork(seq, n, eager_cow=eager_cow)
        self._c_forks_admitted.inc(n)
        owner = self._seq_owner[seq]
        for c in children:
            self._seq_owner[c] = owner
            self._reserved[c] = child_cost
            # children inherit the origin's pacing and sampling so an
            # exploration's subtree stays under its driver's control
            if seq in self._holds:
                self._holds.add(c)
            if seq in self._sampling:
                self._sampling[c] = self._sampling[seq]
        self._g_reserved.set(self._pages_reserved())
        return children

    # ------------------------------------------------------------------
    # exploration pacing (holds + per-sequence sampling)
    # ------------------------------------------------------------------
    def hold(self, seq: int) -> None:
        """Park a tracked sequence: no decode, no auto-retire."""
        if seq not in self._seq_owner:
            raise BranchError(f"sequence {seq} is not scheduled here")
        self._holds.add(seq)

    def unhold(self, seq: int) -> None:
        if seq in self._tiered_reserved:
            raise BranchError(
                f"sequence {seq} is checkpointed to the tier store; "
                "restore() it before unholding (-EAGAIN)",
                errno=Errno.EAGAIN)
        self._holds.discard(seq)

    def is_held(self, seq: int) -> bool:
        return seq in self._holds

    # ------------------------------------------------------------------
    # tiering (checkpoint / restore with ledger movement)
    # ------------------------------------------------------------------
    def checkpoint(self, seq: int) -> int:
        """Demote a tracked, held branch's KV to the tier store.

        The branch's reservation leaves the live ledger (its device
        pages are freed), so the pages it was holding become admissible
        budget; the reservation is remembered and re-checked at
        :meth:`restore`.  Only held branches may checkpoint — a decoding
        branch would just fault straight back in.  Returns the number of
        device pages freed.
        """
        if seq not in self._seq_owner:
            raise BranchError(f"sequence {seq} is not scheduled here")
        if seq not in self._holds:
            raise BranchError(
                f"sequence {seq} must be held before checkpoint; a "
                "running branch cannot leave the device (-EINVAL)",
                errno=Errno.EINVAL)
        n = self.engine.checkpoint(seq)
        worst = self._reserved.pop(seq, 0)
        self._tiered_reserved[seq] = worst
        self._g_reserved.set(self._pages_reserved())
        self._c_demotions.inc()
        return n

    def restore(self, seq: int, *, unhold: bool = False) -> None:
        """Promote a tiered branch back into device pages.

        Re-checks the reservation against the live ledger first —
        restoring must honor the same admission discipline as new work
        (``AdmissionDenied``/-EAGAIN when it does not fit; demote or
        retire something and retry).  With ``unhold`` the branch rejoins
        continuous batching immediately.
        """
        if seq not in self._seq_owner:
            raise BranchError(f"sequence {seq} is not scheduled here")
        worst = self._tiered_reserved.get(seq)
        if worst is None:
            raise BranchError(
                f"sequence {seq} is not tiered (-EINVAL)",
                errno=Errno.EINVAL)
        budget = self.engine.kv.num_pages - self._pages_reserved()
        if worst > budget:
            raise AdmissionDenied(
                f"restoring sequence {seq} needs {worst} reserved pages, "
                f"budget is {budget} (-EAGAIN)")
        self.engine.restore(seq)
        self._reserved[seq] = self._tiered_reserved.pop(seq)
        self._g_reserved.set(self._pages_reserved())
        self._c_restores.inc()
        if unhold:
            self._holds.discard(seq)

    def is_checkpointed(self, seq: int) -> bool:
        return seq in self._tiered_reserved

    def set_sampling(self, seq: int, *, greedy: bool = True,
                     temperature: float = 1.0) -> None:
        """Per-sequence decode settings applied by :meth:`step`."""
        if seq not in self._seq_owner:
            raise BranchError(f"sequence {seq} is not scheduled here")
        self._sampling[seq] = (bool(greedy), float(temperature))

    def verify(self, seq: int,
               drafts: Sequence[Sequence[int]]) -> List[List[int]]:
        """Fused speculative verify on a tracked sequence.

        Pure scoring — one device dispatch for all drafts × k positions,
        no KV writes, no ledger movement (the sequence's reservation and
        hold state are untouched).  See ``ServeEngine.spec_verify``.
        """
        if seq not in self._seq_owner:
            raise BranchError(f"sequence {seq} is not scheduled here")
        return self.engine.spec_verify(seq, drafts)

    def produced(self, seq: int) -> int:
        """Tokens generated beyond the owning request's prompt."""
        req = self._requests[self._seq_owner[seq]]
        return self.engine.kv.length(seq) + 1 - len(req.prompt)

    def is_tracked(self, seq: int) -> bool:
        """Whether this scheduler may still decode ``seq``."""
        return seq in self._seq_owner

    def reserved_pages(self, seq: int) -> int:
        """Worst-case pages the ledger still reserves for ``seq`` (0 if
        untracked) — surfaced in ``repro.api``'s ``stat()``."""
        return self._reserved.get(seq, 0)

    def request_of(self, seq: int) -> Optional[Request]:
        """The owning request of a tracked sequence (None if untracked
        or the request record is already gone)."""
        rid = self._seq_owner.get(seq)
        return None if rid is None else self._requests.get(rid)

    def waiting_head(self) -> Optional[Request]:
        """The admission FIFO's head request (None when the queue is
        empty).  Admission is strictly FIFO, so the head is the *only*
        request whose reservation shortfall matters — a tenancy layer
        relieving page pressure (preempting held/speculative branches)
        targets exactly this request's deficit."""
        return self._waiting[0] if self._waiting else None

    def admission_deficit(self) -> int:
        """Pages the FIFO head still lacks (0 when it fits or no queue).

        ``worst_pages(head) - (pool - reserved)``, clamped at 0: how
        many pages preemption must recycle before the next ``admit()``
        round can seat the head request.
        """
        head = self.waiting_head()
        if head is None:
            return 0
        budget = self.engine.kv.num_pages - self._pages_reserved()
        return max(0, head.worst_pages - budget)

    def peek_result(self, req_id: int) -> Optional[List[int]]:
        """A finished request's tokens without claiming them (None while
        pending or after the one-shot :meth:`result` claim)."""
        res = self._results.get(req_id)
        return None if res is None else list(res)

    # ------------------------------------------------------------------
    # continuous batching
    # ------------------------------------------------------------------
    def _request_done(self, req: Request, seq: int) -> bool:
        # kv.length == len(tokens) - 1 (last token pending), so produced
        # count is O(1) host work — no token-list copy on the hot path
        produced = self.engine.kv.length(seq) + 1 - len(req.prompt)
        if produced >= req.max_new_tokens:
            return True
        # belt-and-suspenders: stop before the next append could overflow
        # the per-sequence block table (submit() makes this unreachable
        # for its own requests)
        return (self._pages_for(self.engine.kv.length(seq) + 1)
                > self.engine.max_pages)

    def _untrack(self, seq: int) -> None:
        rid = self._seq_owner.pop(seq, None)
        if self._reserved.pop(seq, None) is not None:
            self._g_reserved.set(self._pages_reserved())
        self._tiered_reserved.pop(seq, None)
        self._holds.discard(seq)
        self._sampling.pop(seq, None)
        if rid is not None:
            req = self._requests.get(rid)
            if req is not None and req.seq == seq:
                # the request's *root* resolved without retiring (evicted
                # or invalidated): it can never finish — drop it outright
                self._requests.pop(rid, None)

    def _drop(self, seq: int) -> None:
        """Stop tracking a sequence: free its reservation, GC its nodes."""
        self._untrack(seq)
        if self.engine.kv.tree.reap(seq):
            # the reap removes the whole resolved subtree, which may
            # include other tracked branches (e.g. children of an
            # aborted interior branch) — purge them too
            for s in list(self._seq_owner):
                if s not in self.engine.kv.tree:
                    self._untrack(s)

    def runnable(self) -> List[int]:
        """Sequences that may decode this step.

        Asks the lifecycle kernel directly: ACTIVE sequences run, FROZEN
        origins wait for their children, and anything resolved by a
        commit/abort/invalidation is dropped from tracking (and its
        resolved subtree reaped from the kernel).
        """
        out: List[int] = []
        for seq in list(self._seq_owner):
            if seq not in self._seq_owner:
                continue   # dropped with an earlier subtree this round
            if seq not in self.engine.kv.tree:
                self._untrack(seq)   # reaped externally (release/evict)
                continue
            status = self.engine.kv.status(seq)
            if status is BranchStatus.ACTIVE:
                out.append(seq)
            elif status is not BranchStatus.FROZEN:
                # resolved (committed / aborted / stale): stop tracking
                self._drop(seq)
        return out

    def _retire(self, seq: int) -> None:
        rid = self._seq_owner[seq]
        node = self.engine.kv.tree.node(seq)
        if node.parent is None:
            # a finished root request leaves the engine entirely;
            # release() invalidates and reaps every domain's entries,
            # and the Request itself moves to the one-shot result slot
            # so host state stays bounded in a long-running loop
            self._results[rid] = self.engine.tokens(seq)
            self._requests.pop(rid, None)
            self.engine.release(seq)
            self._seq_owner.pop(seq, None)
            self._reserved.pop(seq, None)
            self._g_reserved.set(self._pages_reserved())
            self._c_retired.inc()
        # a finished *branch* stays live: the agent decides commit/abort

    def step(self, *, greedy: bool = True, temperature: float = 1.0,
             key: Optional[jax.Array] = None) -> Dict[str, Any]:
        """One scheduling round: admit, batch-decode, retire.

        Returns counters for the serving loop / benchmarks.
        """
        admitted = self.admit()
        batch = [s for s in self.runnable()
                 if s not in self._holds and not self._request_done(
                     self._requests[self._seq_owner[s]], s)]
        decoded = 0
        for lo in range(0, len(batch), self.config.max_batch):
            group = batch[lo: lo + self.config.max_batch]
            g_row = [self._sampling.get(s, (greedy, temperature))[0]
                     for s in group]
            t_row = [self._sampling.get(s, (greedy, temperature))[1]
                     for s in group]
            sub = None
            if not all(g_row):
                if key is not None:
                    key, sub = jax.random.split(key)
                else:
                    self._key, sub = jax.random.split(self._key)
            self.engine.decode(group, greedy=g_row,
                               temperature=t_row, key=sub)
            decoded += len(group)
        retired = 0
        for seq in self.runnable():   # re-asks the kernel; purges resolved
            if seq in self._holds:
                continue   # an exploration owns this sequence's pace
            req = self._requests.get(self._seq_owner[seq])
            if req is not None and self._request_done(req, seq):
                self._retire(seq)
                retired += int(seq not in self._seq_owner)
        self.steps += 1
        self.tokens_generated += decoded
        return {
            "admitted": len(admitted),
            "batch": len(batch),
            "decoded": decoded,
            "retired": retired,
            "waiting": len(self._waiting),
            "running": len(self._seq_owner),
        }

    def seed_sampling(self, key: jax.Array) -> None:
        """Reseed the scheduler-owned PRNG stream for sampled decode."""
        self._key = key

    def _absorb_key(self, decode_kw: Dict[str, Any]) -> Dict[str, Any]:
        """Fold a caller key into the scheduler's own PRNG stream.

        Repeated-step APIs must not pass one key to every step — each
        step would derive identical sampling noise.  Seeding the
        internal key instead gives every step a fresh split.
        """
        key = decode_kw.pop("key", None)
        if key is not None:
            self.seed_sampling(key)
        return decode_kw

    def run(self, max_steps: int = 1000, **decode_kw: Any) -> int:
        """Step until no work remains; returns tokens generated."""
        decode_kw = self._absorb_key(decode_kw)
        t0 = self.tokens_generated
        for _ in range(max_steps):
            st = self.step(**decode_kw)
            if st["decoded"] == 0 and st["waiting"] == 0:
                break
        return self.tokens_generated - t0

    # ------------------------------------------------------------------
    # completion / wait primitives
    # ------------------------------------------------------------------
    def finished(self, req_id: int) -> bool:
        """True once the request can no longer produce more tokens —
        its result is claimable (or was already claimed / evicted)."""
        return req_id not in self._requests

    def finish(self, req_id: int) -> None:
        """Force-retire a request now (exploration decided it is done).

        The paper's commit-terminates-the-search: a policy that committed
        its winner before the decode budget ran out retires the request
        early instead of letting continuous batching keep decoding the
        root.  Captures the result, releases the root's whole subtree
        across every domain, and frees all its reservations.  A request
        still waiting in the FIFO is cancelled with an empty result;
        finishing an unknown/finished request is a no-op.
        """
        req = self._requests.pop(req_id, None)
        if req is None:
            return
        if req.seq is None:
            self._waiting.remove(req)
            self._results[req_id] = []
            return
        if req.seq in self.engine.kv.tree:
            self._results[req_id] = self.engine.tokens(req.seq)
            self.engine.release(req.seq)   # invalidates + reaps subtree
        else:
            self._results[req_id] = []
        for s in list(self._seq_owner):
            if s not in self.engine.kv.tree:
                self._untrack(s)

    def wait(self, req_id: int, max_steps: int = 1000,
             **decode_kw: Any) -> List[int]:
        """Step the scheduler until ``req_id`` finishes; claim its result."""
        decode_kw = self._absorb_key(decode_kw)
        for _ in range(max_steps):
            if self.finished(req_id):
                break
            self.step(**decode_kw)
        if not self.finished(req_id):
            raise BranchError(
                f"request {req_id} did not finish in {max_steps} steps")
        return self.result(req_id)

    # ------------------------------------------------------------------
    def result(self, req_id: int) -> List[int]:
        """Claim the final token list of a retired request.

        One-shot: claiming drops the request's last host state, so a
        long-running loop stays bounded.  Returns ``[]`` while the
        request is still queued or decoding; raises ``BranchError`` for
        an unknown (or already-claimed, or evicted-unfinished) request.
        """
        if req_id in self._results:
            return self._results.pop(req_id)
        if req_id in self._requests:
            return []
        raise BranchError(f"unknown or already-claimed request {req_id}")

    def seq_of(self, req_id: int) -> int:
        """The admitted root sequence of a request (its fork origin)."""
        seq = self._requests[req_id].seq
        if seq is None:
            raise BranchError(f"request {req_id} not admitted yet")
        return seq

    def stats(self) -> Dict[str, Any]:
        st = self.engine.stats()
        st.update(steps=self.steps, tokens_generated=self.tokens_generated,
                  waiting=len(self._waiting), running=len(self._seq_owner),
                  held=len(self._holds),
                  checkpointed=len(self._tiered_reserved),
                  pages_reserved=self._pages_reserved())
        return st


__all__ = ["AdmissionDenied", "Request", "Scheduler", "SchedulerConfig"]
