from repro.runtime.train_loop import TrainState, build_train_step
from repro.runtime.fault import FaultTolerantTrainer
from repro.runtime.serve_loop import ServeEngine, TokenDomain
from repro.runtime.scheduler import (
    AdmissionDenied,
    Request,
    Scheduler,
    SchedulerConfig,
)

__all__ = ["TrainState", "build_train_step", "FaultTolerantTrainer",
           "ServeEngine", "TokenDomain",
           "AdmissionDenied", "Request", "Scheduler", "SchedulerConfig"]
