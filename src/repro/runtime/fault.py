"""Fault tolerance as branch-context semantics.

Every training step runs inside a branch context forked from the last
committed state (O(1), zero-copy):

* **NaN/divergence rollback** — a non-finite loss aborts the branch; the
  committed origin is untouched, the offending batch is skipped.  This is
  the paper's try-and-rollback (n_branches=1) mode (§8).
* **checkpoint/restart** — committed states flow to the BranchFS-backed
  CheckpointManager (async, delta).  ``FaultTolerantTrainer.restore``
  rebuilds params, optimizer state, RNG, and the data cursor, replaying
  the exact stream.
* **straggler mitigation** — ``speculative_step`` races N redundant
  executors over device slices (simulated by threads here; pods on a real
  cluster); first-commit-wins — the exclusive commit group means no
  barrier and no coordination beyond the paper's commit race.
* **failure injection** — deterministic hooks for tests (kill an
  executor, corrupt a loss, delay a straggler).
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.core import BranchStore, StaleBranchError
from repro.core.store import BranchStatus
from repro.data.synthetic import SyntheticLMPipeline
from repro.runtime.train_loop import TrainState


def _finite(x) -> bool:
    return bool(np.isfinite(np.asarray(x, dtype=np.float32)).all())


@dataclass
class FaultTolerantTrainer:
    step_fn: Callable[[TrainState, Dict[str, Any]],
                      Tuple[TrainState, Dict[str, Any]]]
    state: TrainState
    data: SyntheticLMPipeline
    ckpt: Optional[CheckpointManager] = None
    ckpt_every: int = 50
    # failure injection hooks (tests)
    corrupt_loss_at: Optional[int] = None
    metrics_log: List[Dict[str, float]] = field(default_factory=list)
    rollbacks: int = 0
    steps_done: int = 0

    def __post_init__(self):
        self.store = BranchStore()
        self.store.write(BranchStore.ROOT, "state", self.state)
        self.store.write(BranchStore.ROOT, "data_step",
                         self.data.state().step)

    # ------------------------------------------------------------------
    @property
    def committed_state(self) -> TrainState:
        return self.store.read(BranchStore.ROOT, "state")

    def run(self, n_steps: int) -> List[Dict[str, float]]:
        for _ in range(n_steps):
            self._one_step()
        if self.ckpt is not None:
            self._checkpoint()
            self.ckpt.wait()
        return self.metrics_log

    def _one_step(self) -> None:
        (branch,) = self.store.fork()
        batch = self.data.next()
        state = self.store.read(branch, "state")
        new_state, metrics = self.step_fn(state, batch)
        loss = metrics["loss"]
        if self.corrupt_loss_at is not None and \
                self.steps_done == self.corrupt_loss_at:
            loss = float("nan")  # injected fault
        if not _finite(loss):
            # abort: rollback is free — the committed origin was never
            # touched; the bad batch is skipped (cursor already advanced)
            self.store.abort(branch)
            self.rollbacks += 1
            self.steps_done += 1
            return
        self.store.write(branch, "state", new_state)
        self.store.write(branch, "data_step", self.data.state().step)
        self.store.commit(branch)
        self.steps_done += 1
        self.metrics_log.append(
            {k: float(np.asarray(v, dtype=np.float32))
             for k, v in metrics.items()})
        if self.ckpt is not None and \
                self.steps_done % self.ckpt_every == 0:
            self._checkpoint()

    def _checkpoint(self) -> None:
        state = self.committed_state
        self.ckpt.save_async(
            int(state.step), state,
            extra={"data_step": self.store.read(BranchStore.ROOT,
                                                "data_step")},
        )

    # ------------------------------------------------------------------
    @classmethod
    def restore(
        cls,
        step_fn,
        like_state: TrainState,
        data: SyntheticLMPipeline,
        ckpt: CheckpointManager,
        **kw,
    ) -> "FaultTolerantTrainer":
        """Restart path after a process/node failure."""
        state = ckpt.restore(like_state)
        meta = ckpt.restore_meta()
        data.restore(data.state()._replace(step=meta["extra"]["data_step"]))
        return cls(step_fn=step_fn, state=state, data=data, ckpt=ckpt, **kw)

    # ------------------------------------------------------------------
    # straggler mitigation: speculative redundant execution
    # ------------------------------------------------------------------
    def speculative_step(
        self,
        n_replicas: int = 2,
        delays: Optional[List[float]] = None,
        kill: Optional[List[bool]] = None,
    ) -> Dict[str, Any]:
        """Race ``n_replicas`` executors on the same step; first commit
        wins, losers get -ESTALE.  ``delays``/``kill`` inject stragglers
        and failures."""
        delays = delays or [0.0] * n_replicas
        kill = kill or [False] * n_replicas
        batch = self.data.next()
        branches = self.store.fork(n=n_replicas)
        outcomes: List[Optional[str]] = [None] * n_replicas
        lock = threading.Lock()

        def worker(i: int, bid: int) -> None:
            if kill[i]:
                outcomes[i] = "killed"  # executor died: branch left active,
                return                   # invalidated by the winner's commit
            try:
                time.sleep(delays[i])
                # a straggler whose sibling already committed faults right
                # here (-ESTALE / SIGBUS analogue) — no wasted compute
                state = self.store.read(bid, "state")
                new_state, metrics = self.step_fn(state, batch)
                # ensure device work is finished before racing to commit
                jax.block_until_ready(metrics["loss"])
                with lock:
                    self.store.write(bid, "state", new_state)
                    self.store.write(bid, "data_step",
                                     self.data.state().step)
                    self.store.commit(bid)
                outcomes[i] = "committed"
            except StaleBranchError:
                outcomes[i] = "stale"

        threads = [threading.Thread(target=worker, args=(i, b))
                   for i, b in enumerate(branches)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        self.steps_done += 1
        return {
            "outcomes": outcomes,
            "statuses": [self.store.status(b) for b in branches],
        }
