"""Train-step builder: grad accumulation, clipping, gradient compression,
donation-ready state layout.

``build_train_step`` returns a pure function
``step(state, batch) -> (state, metrics)`` suitable for ``jax.jit`` with
``donate_argnums=(0,)`` under any ParallelPlan.  Distribution is by
sharding propagation: batch comes in sharded over (pod, data), parameters
over (data=FSDP, model=TP); XLA inserts all-gathers at weight use and
reduce-scatters on gradients (verified in the dry-run HLO).
"""

from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.optim import (
    Optimizer,
    apply_updates,
    clip_by_global_norm,
    compressed_gradients,
)
from repro.optim.compress import ErrorFeedbackState, ef_init


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    ef: Optional[ErrorFeedbackState]  # gradient-compression residual
    step: jax.Array


def init_train_state(model: Model, optimizer: Optimizer, key: jax.Array,
                     *, compress: Optional[str] = None) -> TrainState:
    params = model.init(key)
    return TrainState(
        params=params,
        opt_state=optimizer.init(params),
        ef=ef_init(params) if compress else None,
        step=jnp.zeros((), jnp.int32),
    )


def build_train_step(
    model: Model,
    optimizer: Optimizer,
    *,
    accum_steps: int = 1,
    clip_norm: Optional[float] = 1.0,
    compress: Optional[str] = None,
    grad_shardings: Any = None,   # e.g. ZeRO pod-sharded fp32 accumulator
) -> Callable[[TrainState, Dict[str, jax.Array]],
              Tuple[TrainState, Dict[str, jax.Array]]]:
    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def step(state: TrainState, batch: Dict[str, jax.Array]):
        if accum_steps == 1:
            (loss, metrics), grads = grad_fn(state.params, batch)
        else:
            # microbatch over a leading accum axis; grads accumulate in
            # fp32 — compute/"comm" overlap comes from XLA pipelining the
            # per-microbatch reduce-scatters against the next microbatch
            def split(x):
                b = x.shape[0]
                assert b % accum_steps == 0, (b, accum_steps)
                return x.reshape((accum_steps, b // accum_steps)
                                 + x.shape[1:])

            mb = {k: split(v) for k, v in batch.items()}

            def constrain_grads(t):
                if grad_shardings is None:
                    return t
                return jax.tree_util.tree_map(
                    lambda x, s: jax.lax.with_sharding_constraint(x, s)
                    if s is not None else x, t, grad_shardings)

            def body(carry, mbatch):
                acc, loss_acc = carry
                (loss, _), g = grad_fn(state.params, mbatch)
                acc = jax.tree_util.tree_map(
                    lambda a, b_: a + b_.astype(jnp.float32), acc, g)
                return (constrain_grads(acc), loss_acc + loss), None

            zeros = constrain_grads(jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params))
            (gsum, loss_sum), _ = jax.lax.scan(
                body, (zeros, jnp.float32(0)), mb)
            grads = jax.tree_util.tree_map(lambda g: g / accum_steps, gsum)
            loss = loss_sum / accum_steps
            metrics = {"xent": loss, "moe_aux": jnp.float32(0)}

        ef = state.ef
        if compress and ef is not None:
            # cross-pod gradient compression with error feedback: the
            # reconstruction is exact math; the wire-volume saving enters
            # the roofline collective term via compression_ratio()
            grads, ef = compressed_gradients(grads, ef, method=compress)

        gnorm = None
        if clip_norm is not None:
            grads, gnorm = clip_by_global_norm(grads, clip_norm)

        updates, opt_state = optimizer.update(grads, state.opt_state,
                                              state.params)
        params = apply_updates(state.params, updates)
        out_metrics = {
            "loss": loss,
            "grad_norm": gnorm if gnorm is not None else jnp.float32(0),
            **{k: v for k, v in metrics.items()},
        }
        return TrainState(params=params, opt_state=opt_state, ef=ef,
                          step=state.step + 1), out_metrics

    return step
