"""Async client for the serving front door (stdlib only).

Speaks exactly the dialect :mod:`repro.server.app` serves: HTTP/1.1
with ``Connection: close`` and SSE frames of the form
``event: <name>\\ndata: <json>\\n\\n``.  Used by
``examples/agentic_serve.py --client`` and the closed-loop load
generator in ``benchmarks/front_door.py``; it is intentionally not a
general HTTP client.
"""

from __future__ import annotations

import asyncio
import json
from typing import Any, AsyncIterator, Dict, List, Optional, Tuple


class ServeError(RuntimeError):
    """A non-2xx front-door response."""

    def __init__(self, status: int, body: Any):
        super().__init__(f"HTTP {status}: {body}")
        self.status = status
        self.body = body


class ServeClient:
    """One front-door endpoint (``http://host:port`` or ``host:port``)."""

    def __init__(self, url: str):
        url = url.strip()
        for prefix in ("http://", "https://"):
            if url.startswith(prefix):
                url = url[len(prefix):]
        url = url.rstrip("/")
        host, _, port = url.rpartition(":")
        self.host = host or "127.0.0.1"
        self.port = int(port)

    # ------------------------------------------------------------------
    # transport
    # ------------------------------------------------------------------
    async def _connect(self, method: str, path: str,
                       body: Optional[Dict[str, Any]] = None
                       ) -> Tuple[int, str, asyncio.StreamReader,
                                  asyncio.StreamWriter]:
        reader, writer = await asyncio.open_connection(self.host, self.port)
        payload = json.dumps(body).encode() if body is not None else b""
        writer.write(
            f"{method} {path} HTTP/1.1\r\n"
            f"Host: {self.host}:{self.port}\r\n"
            "Content-Type: application/json\r\n"
            f"Content-Length: {len(payload)}\r\n"
            "Connection: close\r\n\r\n".encode() + payload)
        await writer.drain()
        head = await reader.readuntil(b"\r\n\r\n")
        lines = head.decode("latin-1").split("\r\n")
        status = int(lines[0].split(" ")[1])
        ctype = ""
        for line in lines[1:]:
            if line.lower().startswith("content-type:"):
                ctype = line.split(":", 1)[1].strip()
        return status, ctype, reader, writer

    @staticmethod
    async def _close(writer: asyncio.StreamWriter) -> None:
        try:
            writer.close()
            await writer.wait_closed()
        except (OSError, RuntimeError):
            pass    # peer already gone / transport mid-teardown

    async def _request(self, method: str, path: str,
                       body: Optional[Dict[str, Any]] = None) -> Any:
        """One plain (non-streaming) round trip; raises on non-2xx."""
        status, ctype, reader, writer = await self._connect(
            method, path, body)
        try:
            raw = await reader.read()
        finally:
            await self._close(writer)
        data: Any = raw.decode()
        if ctype.startswith("application/json"):
            data = json.loads(raw) if raw else {}
        if status >= 400:
            raise ServeError(status, data)
        return data

    async def stream(self, method: str, path: str,
                     body: Optional[Dict[str, Any]] = None
                     ) -> AsyncIterator[Tuple[str, Dict[str, Any]]]:
        """Yield ``(event, data)`` SSE tuples until the server closes."""
        status, ctype, reader, writer = await self._connect(
            method, path, body)
        if not ctype.startswith("text/event-stream"):
            try:
                raw = await reader.read()
            finally:
                await self._close(writer)
            data = json.loads(raw) if raw else {}
            if status >= 400:
                raise ServeError(status, data)
            yield ("response", data)
            return
        try:
            event, data_lines = "", []
            while True:
                line = await reader.readline()
                if not line:
                    return
                text = line.decode().rstrip("\n").rstrip("\r")
                if text.startswith("event:"):
                    event = text[len("event:"):].strip()
                elif text.startswith("data:"):
                    data_lines.append(text[len("data:"):].strip())
                elif not text and (event or data_lines):
                    payload = json.loads("\n".join(data_lines) or "{}")
                    yield (event or "message", payload)
                    event, data_lines = "", []
        finally:
            await self._close(writer)

    # ------------------------------------------------------------------
    # the API surface
    # ------------------------------------------------------------------
    def generate_events(self, prompt: List[int], *, tenant: str = "default",
                        max_new_tokens: int = 16, greedy: bool = True,
                        temperature: float = 1.0
                        ) -> AsyncIterator[Tuple[str, Dict[str, Any]]]:
        return self.stream("POST", "/v1/generate", {
            "tenant": tenant, "prompt": list(prompt),
            "max_new_tokens": max_new_tokens, "greedy": greedy,
            "temperature": temperature, "stream": True})

    def explore_events(self, prompt: List[int], *, policy: str,
                       tenant: str = "default", max_new_tokens: int = 16,
                       params: Optional[Dict[str, Any]] = None
                       ) -> AsyncIterator[Tuple[str, Dict[str, Any]]]:
        return self.stream("POST", "/v1/explore", {
            "tenant": tenant, "prompt": list(prompt), "policy": policy,
            "max_new_tokens": max_new_tokens, "params": params or {},
            "stream": True})

    async def _collect(self, events: AsyncIterator[Tuple[str, dict]]
                       ) -> Dict[str, Any]:
        final: Dict[str, Any] = {"event": None}
        async for event, data in events:
            if event == "response":        # non-stream error surfaced
                raise ServeError(data.get("status", 500), data)
            if event in ("result", "finished", "evicted", "error"):
                final = {"event": event, **data}
        return final

    async def generate(self, prompt: List[int], **kw: Any
                       ) -> Dict[str, Any]:
        """Stream a /v1/generate to completion; returns the terminal
        event (``finished``/``evicted``/``error`` payload)."""
        return await self._collect(self.generate_events(prompt, **kw))

    async def explore(self, prompt: List[int], *, policy: str,
                      **kw: Any) -> Dict[str, Any]:
        """Stream a /v1/explore to completion; returns the terminal
        ``result`` (or ``evicted``/``error``) payload."""
        return await self._collect(
            self.explore_events(prompt, policy=policy, **kw))

    async def hold(self, prompt: List[int], *, tenant: str = "default",
                   max_new_tokens: int = 16) -> Dict[str, Any]:
        """Admit-and-park a reservation-holding request."""
        return await self._request("POST", "/v1/generate", {
            "tenant": tenant, "prompt": list(prompt),
            "max_new_tokens": max_new_tokens, "hold": True})

    async def tree(self, sid: int) -> Dict[str, Any]:
        return await self._request("GET", f"/v1/sessions/{sid}/tree")

    async def tenants(self) -> Dict[str, Any]:
        return await self._request("GET", "/v1/tenants")

    async def metrics(self) -> str:
        return await self._request("GET", "/metrics")

    async def health(self) -> Dict[str, Any]:
        return await self._request("GET", "/healthz")


__all__ = ["ServeClient", "ServeError"]
