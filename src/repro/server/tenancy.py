"""Multi-tenant admission control over the scheduler's reservation ledger.

The scheduler (DESIGN §3) guarantees *mechanical* safety: an admitted
request can always decode to completion because its worst-case pages
are reserved up front.  This module layers *policy* on that mechanism:

* **Quotas** — each tenant gets a concurrency cap and a worst-case-page
  cap, checked BEFORE anything touches the scheduler.  A request over
  quota is rejected with :class:`QuotaExceeded` (HTTP 429 at the front
  door, ``-EAGAIN`` in errno terms) without submitting, so the
  reservation ledger — and the FIFO every tenant shares — never sees
  work that was never going to be allowed.
* **Priority classes** — each tenant carries an integer priority.
  Admission itself stays FIFO (the ledger's no-mid-decode--ENOSPC proof
  depends on it); priority instead governs **preemption**: when the
  FIFO head cannot be seated and it outranks lower-priority tenants'
  *preemptible* work, that work is evicted to free its reservations.
* **Preemptible work only** — victims are exclusively **held** branches
  (parked requests that are not decoding) and **speculative**
  explorations (declared-disposable drafts).  An actively-decoding,
  non-speculative request is never a victim, so a preempted tenant's
  committed chains survive intact: eviction goes through
  ``session.finish`` (capturing the tokens committed so far and
  releasing every reservation) and surfaces to the owner as an
  ``EV_INVALIDATED``-style event — never as a mid-decode ``-ENOSPC``.

The manager is deliberately ignorant of HTTP and asyncio: it accounts
:class:`ServedRequest` records (attach/detach), answers quota checks,
and ranks victims.  The engine multiplexer executes evictions; the app
layer maps the errors onto status codes.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence

from repro.core.errors import AdmissionDenied, BranchError, Errno


class QuotaExceeded(BranchError):
    """A tenant is over its concurrency or page quota (``-EAGAIN``).

    Retryable by construction — finishing any of the tenant's live
    requests frees quota — which is exactly HTTP 429 semantics, so the
    front door maps this error (and only this error) to 429.
    """

    default_errno = Errno.EAGAIN


@dataclass
class TenantConfig:
    """One tenant's admission contract.

    ``priority`` orders preemption (higher outranks lower; equal
    priorities never preempt each other).  ``max_reserved_pages`` caps
    the sum of worst-case reservations the tenant's live requests may
    hold (None = bounded only by the pool); ``max_concurrent`` caps
    live requests.
    """

    name: str
    max_concurrent: int = 16
    max_reserved_pages: Optional[int] = None
    priority: int = 1


@dataclass
class ServedRequest:
    """One front-door request: the server's bookkeeping record.

    ``kind`` is ``"chat"`` (plain generate), ``"explore"`` (a policy
    run), or ``"parked"`` (a held root — admitted, reserved, never
    decoding until resumed or evicted).  ``preemptible`` marks the
    record evictable under page pressure: parked requests always are,
    explorations are when their policy declared itself speculative.
    """

    sid: int
    tenant: str
    kind: str                           # "chat" | "explore" | "parked"
    prompt_len: int
    max_new_tokens: int
    worst_pages: int
    policy: str = ""
    preemptible: bool = False
    priority: int = 1
    exp: Any = None                     # explore_ctx Exploration (driver)
    root_hd: Optional[int] = None       # parked requests hold the root
    req_id: Optional[int] = None
    queue: Any = None                   # asyncio.Queue, owned by the app
    state: str = "queued"               # queued|running|finished|evicted|error
    sent_admitted: bool = False
    tokens_sent: int = 0
    t_submit: float = field(default_factory=time.perf_counter)
    t_first_token: Optional[float] = None
    final_tokens: Optional[List[int]] = None
    result: Optional[Dict[str, Any]] = None
    error: Optional[str] = None
    evict_reason: Optional[str] = None
    demoted: bool = False               # KV checkpointed to the tier store

    @property
    def live(self) -> bool:
        return self.state in ("queued", "running")


class TenancyManager:
    """Quotas + priorities + victim ranking for one serving session."""

    def __init__(self, session: Any,
                 tenants: Optional[Sequence[TenantConfig]] = None,
                 *, default: Optional[TenantConfig] = None):
        self.session = session
        engine = session.engine
        self._page_size = engine.page_size
        self._num_pages = engine.kv.num_pages
        self._max_pages = engine.max_pages
        self._default = default or TenantConfig("default", max_concurrent=64)
        self._tenants: Dict[str, TenantConfig] = {
            self._default.name: self._default}
        for t in tenants or ():
            self._tenants[t.name] = t
        # live accounting: per-tenant record sets (attach/detach)
        self._live: Dict[str, List[ServedRequest]] = {}
        m = session.obs.metrics
        self._c_quota = m.counter("server.quota_429")
        self._c_enospc = m.counter("server.rejected_enospc")
        self._c_preempt = m.counter("server.preemptions")
        self._c_demote = m.counter("server.demotions")

    # ------------------------------------------------------------------
    # tenant registry
    # ------------------------------------------------------------------
    def register(self, config: TenantConfig) -> None:
        self._tenants[config.name] = config

    def tenant(self, name: str) -> TenantConfig:
        """The tenant's config (unknown tenants get the default class)."""
        return self._tenants.get(name, self._default)

    def priority_of(self, name: str) -> int:
        return self.tenant(name).priority

    def tenants(self) -> List[TenantConfig]:
        return list(self._tenants.values())

    # ------------------------------------------------------------------
    # quota checks (BEFORE the ledger)
    # ------------------------------------------------------------------
    def worst_pages(self, prompt_len: int, max_new_tokens: int) -> int:
        """The scheduler's worst-case page formula, mirrored here so the
        quota check prices a request exactly like the ledger will."""
        return -(-(prompt_len + max_new_tokens) // self._page_size)

    def reserved_pages(self, name: str) -> int:
        return sum(r.worst_pages for r in self._live.get(name, ()))

    def live_count(self, name: str) -> int:
        return len(self._live.get(name, ()))

    def check_admit(self, name: str, prompt_len: int,
                    max_new_tokens: int) -> int:
        """Validate a request against its tenant's quota; returns the
        worst-case page count on success.

        Raises :class:`QuotaExceeded` (→ 429) when the tenant is at its
        concurrency or page cap, and :class:`AdmissionDenied` with
        ``ENOSPC`` when the request could never fit the pool or a block
        table at all (the scheduler's own up-front rejection, applied
        here so the FIFO never sees it).  Neither path touches the
        scheduler: the reservation ledger moves only for requests that
        passed.
        """
        worst = self.worst_pages(prompt_len, max_new_tokens)
        if worst > self._num_pages or worst > self._max_pages:
            self._c_enospc.inc()
            raise AdmissionDenied(
                f"request needs up to {worst} pages but the pool/block "
                f"table holds at most "
                f"{min(self._num_pages, self._max_pages)}; it can never "
                "be admitted", errno=Errno.ENOSPC)
        cfg = self.tenant(name)
        if self.live_count(name) >= cfg.max_concurrent:
            self._c_quota.inc()
            raise QuotaExceeded(
                f"tenant {name!r} is at its concurrency quota "
                f"({cfg.max_concurrent} live requests) (-EAGAIN)")
        if cfg.max_reserved_pages is not None and \
                self.reserved_pages(name) + worst > cfg.max_reserved_pages:
            self._c_quota.inc()
            raise QuotaExceeded(
                f"tenant {name!r} would exceed its page quota "
                f"({self.reserved_pages(name)} + {worst} > "
                f"{cfg.max_reserved_pages}) (-EAGAIN)")
        return worst

    # ------------------------------------------------------------------
    # accounting
    # ------------------------------------------------------------------
    def attach(self, rec: ServedRequest) -> None:
        rec.priority = self.priority_of(rec.tenant)
        self._live.setdefault(rec.tenant, []).append(rec)

    def detach(self, rec: ServedRequest) -> None:
        recs = self._live.get(rec.tenant)
        if recs and rec in recs:
            recs.remove(rec)

    # ------------------------------------------------------------------
    # preemption policy
    # ------------------------------------------------------------------
    def victims_for(self, priority: int) -> List[ServedRequest]:
        """Preemptible records a request of ``priority`` may evict.

        Only held/speculative work qualifies — an actively-decoding,
        non-speculative request is never a victim — and only strictly
        lower-priority tenants pay.  Ordered cheapest-semantic-loss
        first: lowest priority, parked before speculative (a parked
        request loses nothing already committed; a speculative
        exploration loses in-flight drafts), oldest first.
        """
        out = [r for recs in self._live.values() for r in recs
               if r.live and r.preemptible and r.priority < priority]
        out.sort(key=lambda r: (r.priority,
                                0 if r.kind == "parked" else 1,
                                r.t_submit))
        return out

    def note_preemption(self) -> None:
        self._c_preempt.inc()

    def note_demotion(self) -> None:
        """A victim was demoted to the tier store instead of evicted —
        it keeps its tokens and resumes later, losing nothing."""
        self._c_demote.inc()

    # ------------------------------------------------------------------
    def usage(self) -> Dict[str, Dict[str, Any]]:
        """Per-tenant live usage (the /v1/tenants introspection view)."""
        out: Dict[str, Dict[str, Any]] = {}
        for name, cfg in self._tenants.items():
            out[name] = {
                "priority": cfg.priority,
                "live": self.live_count(name),
                "max_concurrent": cfg.max_concurrent,
                "reserved_pages": self.reserved_pages(name),
                "max_reserved_pages": cfg.max_reserved_pages,
            }
        return out


__all__ = ["QuotaExceeded", "ServedRequest", "TenancyManager",
           "TenantConfig"]
