"""The engine multiplexer — one background loop, every tenant's batch.

The serving stack's blocking model (``Scheduler.step`` drives a jitted
device dispatch; ``Waiter`` spins on it) and asyncio's cooperative model
meet exactly here and nowhere else:

* **One engine thread** owns the :class:`~repro.api.BranchSession`, the
  :class:`~repro.explore_ctx.driver.ExplorationDriver`, and every JAX
  dispatch.  Each iteration it (1) executes commands the asyncio side
  posted, (2) relieves page pressure by preempting held/speculative
  work for higher-priority FIFO heads, (3) runs ONE ``driver.step()`` —
  admission, one continuous batched decode over *all* tenants' runnable
  branches, retirement, policy resumption — and (4) publishes per-stream
  deltas.  There is no per-request loop: a thousand concurrent streams
  cost the same number of device dispatches as one busy stream.
* **Commands** (``await mux.call(fn)``) marshal session access onto the
  engine thread: the asyncio side never touches the session directly,
  so the handle table and ledger need no locks.
* **Streams** are plain ``asyncio.Queue``\\ s; the engine thread pushes
  SSE-shaped ``(event, data)`` tuples via ``loop.call_soon_threadsafe``
  — tokens as they decode, ``Waiter``-style lifecycle events
  (``admitted``/``evicted``/``finished``), and the terminal result.
* **Idle costs nothing.**  With no runnable work the thread parks on a
  condition variable; a posted command (or stop) wakes it.

Eviction (preemption and shutdown drain) goes through
``session.finish`` — the one verb that releases a request's whole
subtree across every domain and *returns the tokens committed so far* —
so a preempted tenant keeps its committed chain and observes an
``EV_INVALIDATED``-style event instead of a mid-decode ``-ENOSPC``.
"""

from __future__ import annotations

import threading
import time
import traceback
from collections import OrderedDict
from typing import Any, Callable, Dict, Generator, List, Optional

from repro.core.errors import BranchError, BranchStateError
from repro.explore_ctx.context import policy_result
from repro.explore_ctx.driver import Decode, _WaitFork
from repro.server.tenancy import ServedRequest, TenancyManager


def chat_policy(ctx, *, tokens: int, greedy: bool = True,
                temperature: float = 1.0) -> Generator:
    """Plain generation as a (trivial) exploration policy.

    Routing chat through the driver keeps ONE stepping surface: a chat
    request's decode rides the same continuous batch, pacing (holds)
    and cleanup (``session.finish`` on return) as every policy run.
    """
    yield Decode([ctx], tokens, greedy=greedy, temperature=temperature)
    return policy_result(ctx, committed=False, policy="chat")


def jsonable(x: Any) -> Any:
    """Sanitize policy stats for JSON: numpy/JAX scalars → Python."""
    if isinstance(x, bool) or x is None or isinstance(x, (int, str)):
        return x
    if isinstance(x, float):
        return x
    if isinstance(x, dict):
        return {str(k): jsonable(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [jsonable(v) for v in x]
    if hasattr(x, "item"):
        try:
            return jsonable(x.item())
        except (TypeError, ValueError):
            pass    # multi-element array: fall through to str()
    return str(x)


class Registry:
    """Server-side request records: live map + bounded completed ring."""

    def __init__(self, keep_completed: int = 512):
        self._next_sid = 0
        self.live: "OrderedDict[int, ServedRequest]" = OrderedDict()
        self.completed: "OrderedDict[int, ServedRequest]" = OrderedDict()
        self.by_req: Dict[int, ServedRequest] = {}
        self._keep = keep_completed

    def new_sid(self) -> int:
        sid, self._next_sid = self._next_sid, self._next_sid + 1
        return sid

    def add(self, rec: ServedRequest) -> None:
        self.live[rec.sid] = rec
        if rec.req_id is not None:
            self.by_req[rec.req_id] = rec

    def complete(self, rec: ServedRequest) -> None:
        self.live.pop(rec.sid, None)
        if rec.req_id is not None:
            self.by_req.pop(rec.req_id, None)
        self.completed[rec.sid] = rec
        while len(self.completed) > self._keep:
            self.completed.popitem(last=False)

    def get(self, sid: int) -> Optional[ServedRequest]:
        return self.live.get(sid) or self.completed.get(sid)

    def refresh_req_ids(self) -> None:
        """Learn req_ids assigned since launch (a driver Submit executes
        on a later engine step than the record's creation)."""
        for rec in self.live.values():
            if rec.req_id is None and rec.exp is not None \
                    and rec.exp.req_id is not None:
                rec.req_id = rec.exp.req_id
                self.by_req[rec.req_id] = rec


class EngineLoop:
    """The background engine thread plus its asyncio bridge."""

    def __init__(self, session: Any, driver: Any, tenancy: TenancyManager,
                 *, idle_wait_s: float = 0.02):
        self.session = session
        self.driver = driver
        self.tenancy = tenancy
        self.registry = Registry()
        self.idle_wait_s = idle_wait_s
        self._cv = threading.Condition()
        self._cmds: List[Callable[[Any], None]] = []
        self._running = False
        self._thread: Optional[threading.Thread] = None
        self._aio_loop: Any = None
        self._stalled_rounds = 0
        self.crashed: Optional[BaseException] = None
        m = session.obs.metrics
        self._c_requests = m.counter("server.requests")
        self._c_tokens = m.counter("server.tokens_streamed")
        self._c_evict_shutdown = m.counter("server.evictions_shutdown")
        self._g_streams = m.gauge("server.streams_live")
        self._h_ttft = m.histogram("server.ttft_us")

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------
    def start(self, aio_loop: Any) -> None:
        if self._thread is not None:
            return
        self._aio_loop = aio_loop
        self._running = True
        self._thread = threading.Thread(
            target=self._run, name="repro-engine-loop", daemon=True)
        self._thread.start()

    def stop(self, timeout: float = 30.0) -> None:
        """Stop the engine thread (callers drain first for grace)."""
        with self._cv:
            self._running = False
            self._cv.notify_all()
        if self._thread is not None:
            self._thread.join(timeout)
            self._thread = None

    @property
    def running(self) -> bool:
        return self._running and self._thread is not None

    # ------------------------------------------------------------------
    # asyncio bridge
    # ------------------------------------------------------------------
    def post(self, cmd: Callable[[Any], None]) -> None:
        """Queue a callable for the engine thread and wake it."""
        with self._cv:
            self._cmds.append(cmd)
            self._cv.notify_all()

    async def call(self, fn: Callable[[Any], Any]) -> Any:
        """Run ``fn(session)`` on the engine thread; await its result."""
        if not self.running:
            # BranchStateError is still a RuntimeError for old callers,
            # but carries Errno.EINVAL across the protocol surface
            raise BranchStateError("engine loop is not running")
        loop = self._aio_loop
        fut = loop.create_future()

        def resolve(res: Any, err: Optional[BaseException]) -> None:
            if fut.done():
                return
            if err is not None:
                fut.set_exception(err)
            else:
                fut.set_result(res)

        def cmd(session: Any) -> None:
            try:
                res = fn(session)
            except BaseException as err:   # delivered to the awaiter
                loop.call_soon_threadsafe(resolve, None, err)
            else:
                loop.call_soon_threadsafe(resolve, res, None)

        self.post(cmd)
        return await fut

    def emit(self, rec: ServedRequest, event: str,
             data: Optional[Dict[str, Any]] = None) -> None:
        """Push one SSE-shaped event onto a record's stream queue."""
        if rec.queue is None or self._aio_loop is None:
            return
        item = (event, jsonable(data or {}))
        try:
            self._aio_loop.call_soon_threadsafe(rec.queue.put_nowait, item)
        except RuntimeError:
            rec.queue = None   # event loop gone (teardown): drop stream

    def _end_stream(self, rec: ServedRequest) -> None:
        if rec.queue is None or self._aio_loop is None:
            return
        try:
            self._aio_loop.call_soon_threadsafe(rec.queue.put_nowait, None)
        except RuntimeError:
            rec.queue = None

    # ------------------------------------------------------------------
    # the engine thread
    # ------------------------------------------------------------------
    def _run(self) -> None:
        try:
            while True:
                with self._cv:
                    if not self._cmds and not self._has_work():
                        if not self._running:
                            break
                        self._cv.wait(self.idle_wait_s)
                    if not self._running and not self._cmds \
                            and not self._has_work():
                        break
                    cmds, self._cmds = self._cmds, []
                progress = bool(cmds)
                for cmd in cmds:
                    cmd(self.session)
                progress |= bool(self._relieve_pressure())
                if self._has_step_work():
                    st = self.driver.step()
                    progress |= bool(st.get("resumed") or st.get("decoded")
                                     or st.get("admitted")
                                     or st.get("retired"))
                self._publish()
                if progress:
                    self._stalled_rounds = 0
                else:
                    self._stalled_rounds += 1
                    if self._stalled_rounds >= 2:
                        # a provably idle round with fork-blocked work:
                        # preempt on its behalf, else degrade one policy
                        if not self._relieve_fork_pressure() \
                                and not self.driver.kick_stalled():
                            with self._cv:
                                if self._running and not self._cmds:
                                    self._cv.wait(self.idle_wait_s)
                        self._stalled_rounds = 0
        except BaseException as err:   # pragma: no cover - crash guard
            self.crashed = err
            traceback.print_exc()
            for rec in list(self.registry.live.values()):
                rec.state = "error"
                rec.error = f"engine loop crashed: {err!r}"
                self.emit(rec, "error", {"message": rec.error})
                self._end_stream(rec)
                self.registry.complete(rec)

    def _has_work(self) -> bool:
        return bool(self.driver.live
                    or self.session.sched.waiting_head() is not None
                    or any(r.kind != "parked"
                           for r in self.registry.live.values()))

    def _has_step_work(self) -> bool:
        if self.session.closed:
            return False
        return bool(self.driver.live
                    or self.session.sched.waiting_head() is not None)

    # ------------------------------------------------------------------
    # preemption (engine thread)
    # ------------------------------------------------------------------
    def _relieve_pressure(self) -> int:
        """Evict held/speculative work so the FIFO head can be seated.

        Strictly priority-ordered: only the *head* request matters
        (admission is FIFO), and only strictly-lower-priority
        preemptible records pay for it, cheapest semantic loss first.
        """
        sched = self.session.sched
        head = sched.waiting_head()
        if head is None or sched.admission_deficit() <= 0:
            return 0
        self.registry.refresh_req_ids()
        rec = self.registry.by_req.get(head.req_id)
        if rec is None:
            return 0
        relieved = 0
        for victim in self.tenancy.victims_for(rec.priority):
            if sched.admission_deficit() <= 0:
                break
            # demote-before-deny: a parked victim's KV can leave the
            # device (tier store) without losing anything — eviction is
            # the escalation path, taken only when the victim cannot be
            # checkpointed.  An already-tiered victim holds no device
            # pages, so evicting it would free nothing: skip it.
            if victim.kind == "parked":
                if self.demote(victim,
                               f"demoted by tenant {rec.tenant!r} "
                               f"(priority {rec.priority} > "
                               f"{victim.priority})"):
                    relieved += 1
                    continue
                if victim.demoted:
                    continue
            self.evict(victim,
                       f"preempted by tenant {rec.tenant!r} "
                       f"(priority {rec.priority} > {victim.priority})")
            self.tenancy.note_preemption()
            relieved += 1
        return relieved

    def _relieve_fork_pressure(self) -> int:
        """Same policy for a fork-blocked exploration (no FIFO head):
        a policy whose vectorized fork keeps getting ``-EAGAIN`` may
        preempt lower-priority held/speculative work before the driver
        degrades it to a smaller fan-out."""
        for exp in self.driver.live:
            if not isinstance(exp.wait, _WaitFork):
                continue
            rec = next((r for r in self.registry.live.values()
                        if r.exp is exp), None)
            if rec is None:
                continue
            victims = self.tenancy.victims_for(rec.priority)
            if victims:
                self.evict(victims[0],
                           f"preempted by tenant {rec.tenant!r} fork "
                           f"(priority {rec.priority} > "
                           f"{victims[0].priority})")
                self.tenancy.note_preemption()
                return 1
        return 0

    def demote(self, rec: ServedRequest, reason: str) -> bool:
        """Checkpoint a parked victim's KV to the tier store in place of
        eviction: its device pages are recycled but the record stays
        live (tokens, reservation, handle all survive) and resumes via
        ``session.restore``.  Returns False — caller decides between
        skipping and :meth:`evict` — when the record has no root handle,
        was already demoted, or the checkpoint itself fails."""
        if rec.root_hd is None or rec.demoted:
            return False
        try:
            self.session.checkpoint(rec.root_hd)
        except BranchError:
            # the scheduler's own demote-before-deny (admit()) may have
            # tiered the branch already — adopt its bookkeeping
            self._sync_demoted(rec)
            return False
        rec.demoted = True
        self.tenancy.note_demotion()
        self.emit(rec, "demoted",
                  {"id": rec.sid, "events": [], "reason": reason})
        return True

    def _sync_demoted(self, rec: ServedRequest) -> None:
        """Reflect scheduler-layer tiering into the server record.

        ``Scheduler.admit`` checkpoints held branches on its own
        (demote-before-deny is mechanical, below the priority policy);
        the record's ``demoted`` flag, the ``server.demotions`` counter
        and the ``demoted`` stream event must follow wherever the
        demotion originated.  Restores flip the flag back silently."""
        if rec.root_hd is None:
            return
        try:
            tiered = bool(self.session.stat(rec.root_hd).get("tiered"))
        except BranchError:
            return      # handle raced a resolve; state is terminal
        if tiered and not rec.demoted:
            rec.demoted = True
            self.tenancy.note_demotion()
            self.emit(rec, "demoted", {
                "id": rec.sid, "events": [],
                "reason": "page pressure: KV checkpointed to the tier "
                          "store (demote-before-deny)"})
        elif not tiered and rec.demoted:
            rec.demoted = False

    def evict(self, rec: ServedRequest, reason: str) -> None:
        """Force-finish a record: reservations freed, committed chain
        captured and delivered with the ``EV_INVALIDATED``-style event."""
        hd = rec.root_hd if rec.root_hd is not None else (
            rec.exp.hd if rec.exp is not None else None)
        tokens: Optional[List[int]] = None
        if hd is not None:
            try:
                tokens = self.session.finish(hd)
            except BranchError:
                tokens = None   # already resolved / stale handle
        rec.state = "evicted"
        rec.evict_reason = reason
        rec.final_tokens = tokens
        # bookkeeping strictly BEFORE the terminal event: a consumer
        # that observes it must find the registry already settled
        self.tenancy.detach(rec)
        self.registry.complete(rec)
        self._g_streams.set(len(self.registry.live))
        self.emit(rec, "evicted", {
            "id": rec.sid, "events": ["EV_INVALIDATED"], "reason": reason,
            "tokens": tokens or []})
        self._end_stream(rec)

    def evict_parked(self, reason: str) -> int:
        """Shutdown drain: parked requests never finish on their own."""
        n = 0
        for rec in list(self.registry.live.values()):
            if rec.kind == "parked" and rec.live:
                self.evict(rec, reason)
                self._c_evict_shutdown.inc()
                n += 1
        return n

    def evict_all(self, reason: str) -> int:
        """Hard drain (non-graceful shutdown): everything goes."""
        n = 0
        for rec in list(self.registry.live.values()):
            if rec.live:
                self.evict(rec, reason)
                self._c_evict_shutdown.inc()
                n += 1
        return n

    # ------------------------------------------------------------------
    # launching (engine thread, via call())
    # ------------------------------------------------------------------
    def launch(self, rec: ServedRequest, policy: Any,
               **policy_kw: Any) -> ServedRequest:
        """Attach + start a record (chat and explore kinds run through
        the driver; parked kinds open a held root directly)."""
        from repro.api.flags import BR_HOLD

        prompt = policy_kw.pop("prompt")
        if rec.kind == "parked":
            rec.root_hd = self.session.open(
                list(prompt), rec.max_new_tokens, flags=BR_HOLD)
            rec.req_id = self.session.req_id_of(rec.root_hd)
        else:
            rec.exp = self.driver.explore(
                list(prompt), rec.max_new_tokens, policy=policy,
                name=f"{rec.policy or rec.kind}-{rec.sid}", **policy_kw)
        self.tenancy.attach(rec)
        self.registry.add(rec)
        self._c_requests.inc()
        self._g_streams.set(len(self.registry.live))
        return rec

    # ------------------------------------------------------------------
    # stream publishing (engine thread)
    # ------------------------------------------------------------------
    def _publish(self) -> None:
        self.registry.refresh_req_ids()
        for rec in list(self.registry.live.values()):
            if rec.kind == "parked":
                self._publish_parked(rec)
            else:
                self._publish_exploration(rec)
        self._g_streams.set(len(self.registry.live))

    def _publish_parked(self, rec: ServedRequest) -> None:
        if rec.sent_admitted:
            self._sync_demoted(rec)
        if not rec.sent_admitted and rec.root_hd is not None:
            try:
                admitted = self.session.admitted(rec.root_hd)
            except BranchError:
                return      # handle raced a resolve; try next step
            if admitted:
                rec.sent_admitted = True
                rec.state = "running"
                self.emit(rec, "admitted", {
                    "id": rec.sid, "req_id": rec.req_id,
                    "seq": self.session.seq_of(rec.root_hd),
                    "events": ["EV_ADMITTED"], "held": True})

    def _publish_exploration(self, rec: ServedRequest) -> None:
        exp = rec.exp
        if exp is None:
            return
        if not rec.sent_admitted and exp.root is not None:
            rec.sent_admitted = True
            rec.state = "running"
            self.emit(rec, "admitted", {
                "id": rec.sid, "req_id": exp.req_id,
                "seq": exp.root.seq, "events": ["EV_ADMITTED"]})
        if not exp.done and exp.hd is not None:
            self._stream_tokens(rec, self._root_tokens(rec))
            return
        if not exp.done:
            return
        # terminal: settle the registry FIRST (a consumer observing the
        # terminal event must find the record already completed), then
        # flush the tail + result/error, then the sentinel
        if exp.error is not None:
            rec.state = "error"
            rec.error = str(exp.error)
            self.tenancy.detach(rec)
            self.registry.complete(rec)
            errno = getattr(exp.error, "errno", None)
            self.emit(rec, "error", {
                "id": rec.sid, "message": rec.error,
                "errno": errno.name if errno is not None else None})
        else:
            res = exp.result
            final = list(res.tokens) if res is not None else (
                list(exp.final_tokens or []))
            gen_start = rec.prompt_len + rec.tokens_sent
            if len(final) > gen_start:
                self._note_tokens(rec, final[gen_start:])
            rec.state = "finished"
            rec.final_tokens = final
            if res is not None:
                rec.result = {
                    "tokens": list(res.tokens),
                    "generated": list(res.generated),
                    "score": res.score,
                    "committed": res.committed,
                    "policy": rec.policy or "chat",
                    "stats": jsonable(res.stats),
                }
            self.tenancy.detach(rec)
            self.registry.complete(rec)
            event = "finished" if rec.kind == "chat" else "result"
            self.emit(rec, event, {
                "id": rec.sid, "events": ["EV_FINISHED"],
                "tokens": final, "generated": final[rec.prompt_len:],
                **({"result": rec.result}
                   if rec.kind == "explore" and rec.result else {})})
        self._end_stream(rec)

    def _root_tokens(self, rec: ServedRequest) -> Optional[List[int]]:
        """The exploration root's current chain (None when unreadable:
        mid-resolution windows are fine to skip for a step)."""
        try:
            return self.session.tokens(rec.exp.hd)
        except BranchError:
            return None

    def _stream_tokens(self, rec: ServedRequest,
                       tokens: Optional[List[int]]) -> None:
        if tokens is None:
            return
        new = tokens[rec.prompt_len + rec.tokens_sent:]
        if new:
            self._note_tokens(rec, new)

    def _note_tokens(self, rec: ServedRequest, new: List[int]) -> None:
        if rec.t_first_token is None:
            rec.t_first_token = time.perf_counter()
            self._h_ttft.observe(
                (rec.t_first_token - rec.t_submit) * 1e6)
        rec.tokens_sent += len(new)
        self._c_tokens.inc(len(new))
        self.emit(rec, "token", {
            "id": rec.sid, "tokens": list(new),
            "produced": rec.tokens_sent})


__all__ = ["EngineLoop", "Registry", "chat_policy", "jsonable"]
