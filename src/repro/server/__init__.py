"""repro.server — the multi-tenant async serving front door.

HTTP/SSE over a :class:`~repro.api.BranchSession`: one background
engine loop folds every tenant's branches into one continuous batch
(:mod:`~repro.server.multiplex`), per-tenant quotas and priority-based
preemption layer policy on the scheduler's reservation ledger
(:mod:`~repro.server.tenancy`), and a zero-dependency asyncio HTTP/1.1
app exposes generate/explore/tree/metrics (:mod:`~repro.server.app`).
See DESIGN.md §14.
"""

from repro.server.app import POLICIES, FrontDoor, Response
from repro.server.client import ServeClient, ServeError
from repro.server.multiplex import EngineLoop, Registry, chat_policy
from repro.server.tenancy import (QuotaExceeded, ServedRequest,
                                  TenancyManager, TenantConfig)

__all__ = [
    "EngineLoop",
    "FrontDoor",
    "POLICIES",
    "QuotaExceeded",
    "Registry",
    "Response",
    "ServeClient",
    "ServeError",
    "ServedRequest",
    "TenancyManager",
    "TenantConfig",
    "chat_policy",
]
