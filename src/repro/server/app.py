"""The async serving front door — HTTP/SSE over a BranchSession.

Zero dependencies beyond the standard library: the repo's CI (and the
paper's claim) is that branch-native serving needs an engine and an OS
analogy, not a web framework.  The HTTP/1.1 surface is deliberately
small:

===========================  ============================================
``POST /v1/generate``        plain generation; streams SSE ``token``
                             events plus Waiter lifecycle events
                             (``admitted``/``finished``/``evicted``), or
                             returns one JSON document with
                             ``"stream": false``.  ``"hold": true``
                             admits-and-parks (a reservation-holding
                             agentic request that decodes later — and
                             the canonical preemption victim).
``POST /v1/explore``         a named exploration policy (best_of_n,
                             beam, tree, speculative) run through the
                             shared driver; the first-commit-wins result
                             arrives as a terminal ``result`` event.
``GET /v1/sessions/{id}/tree``  procfs view of one served request.
``GET /v1/tenants``          per-tenant quota/usage introspection.
``GET /metrics``             the obs registry's procfs text format.
``GET /healthz``             liveness + draining state.
===========================  ============================================

Tests (and in-process callers) use :meth:`FrontDoor.dispatch` directly —
an ASGI-shaped ``(method, path, body) -> Response`` surface with no
sockets; :meth:`FrontDoor.serve` wraps the same dispatch in an
``asyncio.start_server`` loop for real clients.

Graceful shutdown (`shutdown(drain=True)`) refuses new work with 503,
evicts parked reservations (they never finish on their own), lets every
in-flight decode run to completion, then stops the engine thread and
closes the session — which wakes any straggler blocked in
``Waiter.wait``.  Nothing is ever cut off mid-decode.
"""

from __future__ import annotations

import asyncio
import json
from dataclasses import dataclass, field
from typing import Any, AsyncIterator, Dict, Optional, Sequence, Tuple

from repro.core.errors import AdmissionDenied, BranchError
from repro.explore_ctx.driver import ExplorationDriver
from repro.explore_ctx.policies import beam_search, best_of_n, tree_search
from repro.explore_ctx.speculative import speculative_decode
from repro.server.multiplex import EngineLoop, chat_policy, jsonable
from repro.server.tenancy import (QuotaExceeded, ServedRequest,
                                  TenancyManager, TenantConfig)

#: policy registry: name -> (fn, allowed JSON params, default max_new,
#: preemptible).  Speculative explorations are declared-disposable
#: drafts, so they (alone among policies) are preemption victims.
POLICIES: Dict[str, Tuple[Any, frozenset, int, bool]] = {
    "best_of_n": (best_of_n,
                  frozenset({"n", "tokens", "temperature"}), 16, False),
    "beam": (beam_search,
             frozenset({"width", "depth", "tokens_per_level",
                        "temperature"}), 16, False),
    "tree": (tree_search,
             frozenset({"fan_out", "tokens_per_node", "max_nodes",
                        "max_depth", "temperature"}), 16, False),
    "speculative": (speculative_decode,
                    frozenset({"n_drafts", "draft_tokens",
                               "temperature"}), 16, True),
}


@dataclass
class Response:
    """One dispatch result: a plain body OR a live SSE event stream."""

    status: int
    body: Optional[Dict[str, Any]] = None
    text: Optional[str] = None
    events: Optional[AsyncIterator[Tuple[str, Dict[str, Any]]]] = None
    headers: Dict[str, str] = field(default_factory=dict)

    @property
    def content_type(self) -> str:
        if self.events is not None:
            return "text/event-stream"
        return "text/plain" if self.text is not None else "application/json"

    def render_body(self) -> bytes:
        if self.text is not None:
            return self.text.encode()
        return json.dumps(self.body or {}).encode()


def _error(status: int, message: str, *, errno: Any = None) -> Response:
    return Response(status, body={
        "error": message,
        "errno": getattr(errno, "name", None)})


def _status_for(err: BaseException) -> int:
    """errno discipline → HTTP discipline."""
    if isinstance(err, QuotaExceeded):
        return 429                       # -EAGAIN: retry after quota frees
    if isinstance(err, AdmissionDenied):
        return 507                       # -ENOSPC: insufficient storage
    return 400


class FrontDoor:
    """Multi-tenant async HTTP/SSE front end over one BranchSession."""

    def __init__(self, session: Any,
                 tenants: Optional[Sequence[TenantConfig]] = None, *,
                 driver: Optional[ExplorationDriver] = None,
                 default_tenant: Optional[TenantConfig] = None):
        self.session = session
        self.driver = driver or ExplorationDriver(session)
        self.tenancy = TenancyManager(session, tenants,
                                      default=default_tenant)
        self.mux = EngineLoop(session, self.driver, self.tenancy)
        self.registry = self.mux.registry
        self.draining = False
        self._server: Any = None

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------
    async def start_backend(self) -> None:
        """Start the engine thread against the running event loop."""
        self.mux.start(asyncio.get_running_loop())

    async def serve(self, host: str, port: int) -> Any:
        """Bind the socket front end (returns the asyncio server)."""
        await self.start_backend()
        self._server = await asyncio.start_server(
            self._handle_conn, host, port)
        return self._server

    async def shutdown(self, *, drain: bool = True,
                       timeout: float = 60.0) -> Dict[str, Any]:
        """Stop serving; with ``drain`` let in-flight decodes finish.

        Draining: (1) new requests get 503, (2) parked reservations are
        evicted — held work never finishes by itself and its owners get
        the ``EV_INVALIDATED``-style event, (3) chat/explore requests
        decode to completion, (4) the engine thread stops and the
        session closes, waking any blocked Waiter.
        """
        self.draining = True
        stats = {"drained": 0, "evicted": 0}
        if self.mux.running:
            if drain:
                stats["evicted"] += await self.mux.call(
                    lambda s: self.mux.evict_parked("server draining"))
                loop = asyncio.get_running_loop()
                deadline = loop.time() + timeout
                while loop.time() < deadline:
                    live = await self.mux.call(
                        lambda s: len(self.registry.live))
                    if live == 0:
                        break
                    stats["drained"] = live
                    await asyncio.sleep(0.01)
                stats["evicted"] += await self.mux.call(
                    lambda s: self.mux.evict_all("drain timeout"))
            else:
                stats["evicted"] += await self.mux.call(
                    lambda s: self.mux.evict_all("server stopped"))
            self.mux.stop()
        self.session.close()   # wakes anything still blocked in a wait
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
            self._server = None
        return stats

    # ------------------------------------------------------------------
    # dispatch (the ASGI-shaped test transport)
    # ------------------------------------------------------------------
    async def dispatch(self, method: str, path: str,
                       body: Optional[Dict[str, Any]] = None) -> Response:
        try:
            if method == "GET":
                return await self._get(path)
            if method == "POST":
                if path == "/v1/generate":
                    return await self._generate(body or {})
                if path == "/v1/explore":
                    return await self._explore(body or {})
                return _error(404, f"no route {method} {path}")
            return _error(405, f"method {method} not allowed")
        except (QuotaExceeded, AdmissionDenied) as err:
            return _error(_status_for(err), str(err), errno=err.errno)
        except BranchError as err:
            return _error(400, str(err), errno=err.errno)

    async def _get(self, path: str) -> Response:
        if path == "/healthz":
            ok = self.mux.running and self.mux.crashed is None
            return Response(200 if ok else 500, body={
                "ok": ok, "draining": self.draining,
                "live": len(self.registry.live)})
        if path == "/metrics":
            if self.mux.running:
                text = await self.mux.call(lambda s: s.obs.metrics.format())
            else:
                text = self.session.obs.metrics.format()
            return Response(200, text=text)
        if path == "/v1/tenants":
            if self.mux.running:
                usage = await self.mux.call(lambda s: self.tenancy.usage())
            else:
                usage = self.tenancy.usage()
            return Response(200, body={"tenants": usage})
        if path.startswith("/v1/sessions/") and path.endswith("/tree"):
            frag = path[len("/v1/sessions/"):-len("/tree")]
            try:
                sid = int(frag)
            except ValueError:
                return _error(400, f"bad session id {frag!r}")
            return await self._tree(sid)
        return _error(404, f"no route GET {path}")

    # ------------------------------------------------------------------
    # request launch paths
    # ------------------------------------------------------------------
    def _reject_if_draining(self) -> Optional[Response]:
        if self.draining or not self.mux.running:
            return _error(503, "server is draining; no new requests")
        return None

    @staticmethod
    def _prompt_of(body: Dict[str, Any]) -> list:
        prompt = body.get("prompt")
        if not isinstance(prompt, list) or not prompt or \
                not all(isinstance(t, int) for t in prompt):
            raise BranchError("prompt must be a non-empty list of ints")
        return prompt

    async def _launch(self, *, tenant: str, kind: str, prompt: list,
                      max_new_tokens: int, policy_name: str,
                      policy: Any, preemptible: bool,
                      **policy_kw: Any) -> ServedRequest:
        """Quota-check + register + start ONE record, atomically on the
        engine thread (the quota read and the attach that consumes it
        must not interleave with another tenant's launch)."""
        queue: asyncio.Queue = asyncio.Queue()

        def op(session: Any) -> ServedRequest:
            worst = self.tenancy.check_admit(
                tenant, len(prompt), max_new_tokens)   # 429/507, no ledger
            rec = ServedRequest(
                sid=self.registry.new_sid(), tenant=tenant, kind=kind,
                prompt_len=len(prompt), max_new_tokens=max_new_tokens,
                worst_pages=worst, policy=policy_name,
                preemptible=preemptible, queue=queue)
            return self.mux.launch(rec, policy, prompt=prompt, **policy_kw)

        return await self.mux.call(op)

    async def _generate(self, body: Dict[str, Any]) -> Response:
        busy = self._reject_if_draining()
        if busy is not None:
            return busy
        prompt = self._prompt_of(body)
        tenant = str(body.get("tenant", "default"))
        max_new = int(body.get("max_new_tokens", 16))
        if body.get("hold"):
            rec = await self._launch(
                tenant=tenant, kind="parked", prompt=prompt,
                max_new_tokens=max_new, policy_name="parked",
                policy=None, preemptible=True)
            return Response(200, body={
                "id": rec.sid, "tenant": tenant, "state": rec.state,
                "held": True, "worst_pages": rec.worst_pages})
        rec = await self._launch(
            tenant=tenant, kind="chat", prompt=prompt,
            max_new_tokens=max_new, policy_name="chat",
            policy=chat_policy, preemptible=False,
            tokens=max_new, greedy=bool(body.get("greedy", True)),
            temperature=float(body.get("temperature", 1.0)))
        return await self._respond(rec, stream=body.get("stream", True))

    async def _explore(self, body: Dict[str, Any]) -> Response:
        busy = self._reject_if_draining()
        if busy is not None:
            return busy
        prompt = self._prompt_of(body)
        tenant = str(body.get("tenant", "default"))
        name = str(body.get("policy", "best_of_n"))
        if name not in POLICIES:
            return _error(400, f"unknown policy {name!r}; have "
                          f"{sorted(POLICIES)}")
        fn, allowed, default_new, preemptible = POLICIES[name]
        params = body.get("params") or {}
        bad = set(params) - set(allowed)
        if bad:
            return _error(400, f"policy {name!r} does not accept "
                          f"{sorted(bad)}; allowed: {sorted(allowed)}")
        max_new = int(body.get("max_new_tokens", default_new))
        rec = await self._launch(
            tenant=tenant, kind="explore", prompt=prompt,
            max_new_tokens=max_new, policy_name=name, policy=fn,
            preemptible=preemptible, **params)
        return await self._respond(rec, stream=body.get("stream", True))

    # ------------------------------------------------------------------
    # responses
    # ------------------------------------------------------------------
    async def _respond(self, rec: ServedRequest, *,
                       stream: bool) -> Response:
        if stream:
            return Response(200, events=self._stream(rec))
        # blocking mode: drain the stream server-side, answer once
        final: Dict[str, Any] = {}
        async for event, data in self._stream(rec):
            if event in ("result", "finished", "evicted", "error"):
                final = {"event": event, **data}
        status = {"error": 500, "evicted": 409}.get(
            final.get("event", ""), 200)
        return Response(status, body={
            "id": rec.sid, "tenant": rec.tenant, "state": rec.state,
            **final})

    async def _stream(self, rec: ServedRequest
                      ) -> AsyncIterator[Tuple[str, Dict[str, Any]]]:
        """Yield a record's SSE events until its terminal sentinel.

        A consumer that goes away mid-stream (client disconnect) evicts
        the record: abandoned requests must not keep page reservations.
        """
        try:
            while True:
                item = await rec.queue.get()
                if item is None:
                    return
                yield item
        finally:
            if rec.live:
                self.mux.post(lambda s: (
                    self.mux.evict(rec, "client disconnected")
                    if rec.live else None))

    async def _tree(self, sid: int) -> Response:
        rec = self.registry.get(sid)
        if rec is None:
            return _error(404, f"no served request {sid}")

        def op(session: Any) -> Dict[str, Any]:
            out: Dict[str, Any] = {
                "id": rec.sid, "tenant": rec.tenant, "kind": rec.kind,
                "policy": rec.policy, "state": rec.state,
                "req_id": rec.req_id, "tokens_sent": rec.tokens_sent,
                "worst_pages": rec.worst_pages,
                "preemptible": rec.preemptible,
                "priority": rec.priority,
                "demoted": rec.demoted,
            }
            if rec.evict_reason:
                out["evict_reason"] = rec.evict_reason
            if rec.final_tokens is not None:
                out["final_tokens"] = list(rec.final_tokens)
            hd = rec.root_hd if rec.root_hd is not None else (
                rec.exp.hd if rec.exp is not None else None)
            if rec.live and hd is not None:
                try:
                    out["stat"] = session.stat(hd)
                except BranchError:
                    pass    # handle raced a resolve; tree still renders
            out["session"] = session.tree()
            return out

        if self.mux.running:
            view = await self.mux.call(op)
        else:
            view = op(self.session)
        return Response(200, body=jsonable(view))

    # ------------------------------------------------------------------
    # the socket front end (thin wrapper over dispatch)
    # ------------------------------------------------------------------
    async def _handle_conn(self, reader: asyncio.StreamReader,
                           writer: asyncio.StreamWriter) -> None:
        try:
            req = await self._read_request(reader)
            if req is None:
                return
            method, path, body = req
            resp = await self.dispatch(method, path, body)
            await self._write_response(writer, resp)
        except (ConnectionError, asyncio.IncompleteReadError):
            pass
        finally:
            try:
                writer.close()
                await writer.wait_closed()
            except (OSError, RuntimeError):
                pass    # peer already gone / transport mid-teardown

    @staticmethod
    async def _read_request(reader: asyncio.StreamReader
                            ) -> Optional[Tuple[str, str, Optional[dict]]]:
        try:
            head = await reader.readuntil(b"\r\n\r\n")
        except (asyncio.IncompleteReadError, asyncio.LimitOverrunError):
            return None
        lines = head.decode("latin-1").split("\r\n")
        parts = lines[0].split(" ")
        if len(parts) < 2:
            return None
        method, path = parts[0].upper(), parts[1]
        length = 0
        for line in lines[1:]:
            if line.lower().startswith("content-length:"):
                length = int(line.split(":", 1)[1].strip())
        body = None
        if length:
            raw = await reader.readexactly(length)
            try:
                body = json.loads(raw)
            except json.JSONDecodeError:
                body = None
        return method, path, body

    async def _write_response(self, writer: asyncio.StreamWriter,
                              resp: Response) -> None:
        reasons = {200: "OK", 400: "Bad Request", 404: "Not Found",
                   405: "Method Not Allowed", 409: "Conflict",
                   429: "Too Many Requests", 500: "Internal Server Error",
                   503: "Service Unavailable",
                   507: "Insufficient Storage"}
        reason = reasons.get(resp.status, "Status")
        if resp.events is None:
            payload = resp.render_body()
            writer.write(
                f"HTTP/1.1 {resp.status} {reason}\r\n"
                f"Content-Type: {resp.content_type}\r\n"
                f"Content-Length: {len(payload)}\r\n"
                "Connection: close\r\n\r\n".encode() + payload)
            await writer.drain()
            return
        writer.write(
            f"HTTP/1.1 {resp.status} {reason}\r\n"
            "Content-Type: text/event-stream\r\n"
            "Cache-Control: no-cache\r\n"
            "Connection: close\r\n\r\n".encode())
        await writer.drain()
        async for event, data in resp.events:
            frame = f"event: {event}\ndata: {json.dumps(data)}\n\n"
            writer.write(frame.encode())
            await writer.drain()   # ConnectionError here → _stream evicts


__all__ = ["FrontDoor", "POLICIES", "Response"]
