"""A small path-sensitive statement simulator for the CFG rules.

BL002 (handle lifecycle) and BL004 (span balance) are *path* properties
— "on every path from acquisition to an exit, the resource is released"
— so a flat AST walk cannot express them.  This module simulates a
function body over sets of abstract states:

* a **state** is whatever immutable fact-set a rule chooses
  (``frozenset`` of strings here: ``{"held:hd"}``, ``{"open:1"}``);
* the rule supplies one ``transfer(node, state) -> iterable[state]``
  callback, invoked on simple statements and on the expression parts of
  control statements (``If.test``, ``While.test``, ``For.iter``,
  ``Return``/``Raise`` nodes themselves, ``with`` items);
* the simulator owns the control flow: both arms of an ``if``, loop
  bodies executed 0/1/2 times (twice, so a second release inside a loop
  is observable), ``try`` handlers entered with the state at try entry
  (an exception may fire before any body statement completed),
  ``finally`` applied to normal *and* escaping paths, and every
  ``return``/``raise``/fall-through recorded as an :class:`ExitPath`.

The approximations are deliberate and conservative-for-our-rules:
conditions are never evaluated (both arms always explored), implicit
exceptions from arbitrary calls are not modeled (only explicit
``raise``), and nested function bodies are opaque (the rule's transfer
sees the ``FunctionDef`` node and decides what escapes into it).
State-set size is capped so pathological functions stay linear.
"""

from __future__ import annotations

import ast
from dataclasses import dataclass
from typing import Callable, FrozenSet, Iterable, List, Set

State = FrozenSet[str]
Transfer = Callable[[ast.AST, State], Iterable[State]]

#: cap on simultaneously tracked states per block (join beyond this)
MAX_STATES = 128


@dataclass
class ExitPath:
    """One way control leaves the simulated body."""

    state: State
    node: ast.AST          # the Return/Raise (or body) anchoring the exit
    kind: str              # "return" | "raise" | "fall"


class _Paths:
    """Mutable simulation context: collected exits + loop break states."""

    def __init__(self, transfer: Transfer):
        self.transfer = transfer
        self.exits: List[ExitPath] = []
        self._breaks: List[Set[State]] = []


def simulate(body: List[ast.stmt], init: State,
             transfer: Transfer) -> List[ExitPath]:
    """Run ``body`` from ``init``; return every exit path (fall-through
    off the end included, anchored at the last statement)."""
    ctx = _Paths(transfer)
    out = _block(body, {init}, ctx)
    anchor = body[-1] if body else ast.Pass()
    for state in out:
        ctx.exits.append(ExitPath(state, anchor, "fall"))
    return ctx.exits


def _cap(states: Set[State]) -> Set[State]:
    if len(states) <= MAX_STATES:
        return states
    # join everything into one superset state: keeps "a fact held on
    # some path" observable while bounding the walk
    merged: Set[str] = set()
    for s in states:
        merged |= s
    return {frozenset(merged)}


def _apply(node: ast.AST, states: Set[State], ctx: _Paths) -> Set[State]:
    out: Set[State] = set()
    for s in states:
        out.update(ctx.transfer(node, s))
    return _cap(out)


def _block(stmts: List[ast.stmt], states: Set[State],
           ctx: _Paths) -> Set[State]:
    for stmt in stmts:
        if not states:
            return states          # all paths already exited
        states = _stmt(stmt, states, ctx)
    return states


def _stmt(stmt: ast.stmt, states: Set[State], ctx: _Paths) -> Set[State]:
    if isinstance(stmt, ast.If):
        states = _apply(stmt.test, states, ctx)
        return _block(stmt.body, set(states), ctx) \
            | _block(stmt.orelse, set(states), ctx)

    if isinstance(stmt, (ast.For, ast.AsyncFor)):
        states = _apply(stmt.iter, states, ctx)
        states = _apply(stmt.target, states, ctx)
        return _loop(stmt.body, stmt.orelse, states, ctx)

    if isinstance(stmt, ast.While):
        states = _apply(stmt.test, states, ctx)
        return _loop(stmt.body, stmt.orelse, states, ctx)

    if isinstance(stmt, (ast.With, ast.AsyncWith)):
        for item in stmt.items:
            states = _apply(item, states, ctx)
        return _block(stmt.body, states, ctx)

    if isinstance(stmt, ast.Try):
        return _try(stmt, states, ctx)

    if isinstance(stmt, ast.Return):
        states = _apply(stmt, states, ctx)
        for s in states:
            ctx.exits.append(ExitPath(s, stmt, "return"))
        return set()

    if isinstance(stmt, ast.Raise):
        states = _apply(stmt, states, ctx)
        for s in states:
            ctx.exits.append(ExitPath(s, stmt, "raise"))
        return set()

    if isinstance(stmt, (ast.Break, ast.Continue)):
        if ctx._breaks:
            ctx._breaks[-1].update(states)
        return set()

    if isinstance(stmt, ast.Match):
        out: Set[State] = set()
        fell_through = True
        for case in stmt.cases:
            out |= _block(case.body, set(states), ctx)
            if case.pattern is not None and \
                    isinstance(case.pattern, ast.MatchAs) and \
                    case.pattern.pattern is None and case.guard is None:
                fell_through = False   # a catch-all case exists
        if fell_through:
            out |= states
        return _cap(out)

    # simple statement (incl. nested FunctionDef/ClassDef, which the
    # transfer may inspect for escapes but whose bodies are opaque)
    return _apply(stmt, states, ctx)


def _loop(body: List[ast.stmt], orelse: List[ast.stmt],
          states: Set[State], ctx: _Paths) -> Set[State]:
    ctx._breaks.append(set())
    once = _block(body, set(states), ctx)
    twice = _block(body, set(once), ctx)
    broke = ctx._breaks.pop()
    out = states | once | twice | broke          # 0, 1, or 2 iterations
    if orelse:
        out = _block(orelse, _cap(out), ctx)
    return _cap(out)


def _try(stmt: ast.Try, states: Set[State], ctx: _Paths) -> Set[State]:
    # exits raised inside the protected region must pass through finally
    outer_exits = ctx.exits
    ctx.exits = []
    body_out = _block(stmt.body, set(states), ctx)
    # an exception may interrupt the body anywhere: handlers see the
    # state at try entry OR at body completion (conservative union)
    handler_in = _cap(set(states) | body_out)
    handler_out: Set[State] = set()
    for handler in stmt.handlers:
        handler_out |= _block(handler.body, set(handler_in), ctx)
    orelse_out = _block(stmt.orelse, body_out, ctx) if stmt.orelse \
        else body_out
    normal = _cap(orelse_out | handler_out)
    captured, ctx.exits = ctx.exits, outer_exits
    if stmt.finalbody:
        normal = _block(stmt.finalbody, normal, ctx)
        for ex in captured:
            fin_out = _block(stmt.finalbody, {ex.state}, ctx)
            for s in fin_out:
                ctx.exits.append(ExitPath(s, ex.node, ex.kind))
    else:
        ctx.exits.extend(captured)
    return normal


def walk_expr_names(node: ast.AST) -> Iterable[ast.Name]:
    """Every Name node in an expression subtree (helper for transfers)."""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Name):
            yield sub


__all__ = ["ExitPath", "MAX_STATES", "State", "simulate",
           "walk_expr_names"]
