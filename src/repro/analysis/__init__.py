"""branchlint — the repo's self-hosted branch-context protocol checker.

Static analysis for the invariants the rest of the codebase promises
but Python cannot express: errno discipline on error surfaces (BL001),
handle lifecycle (BL002), the asyncio/engine thread boundary (BL003),
span balance (BL004), metric hygiene (BL005), and flag-word validity
(BL006).  Stdlib-only (``ast`` + ``re`` + ``json``).

Run it::

    python -m repro.analysis src tests
    python -m repro.analysis --format json --baseline .branchlint-baseline.json src

Library surface::

    from repro.analysis import RULES, analyze_paths
    result = analyze_paths(["src"])
"""

from repro.analysis.engine import (BASELINE_DEFAULT, AnalysisResult,
                                   FileContext, Finding, Project, Rule,
                                   RULES, analyze_paths, apply_baseline,
                                   load_baseline, register, render_json,
                                   render_text, write_baseline)
import repro.analysis.rules  # noqa: F401  (populates RULES)

__all__ = [
    "AnalysisResult",
    "BASELINE_DEFAULT",
    "FileContext",
    "Finding",
    "Project",
    "RULES",
    "Rule",
    "analyze_paths",
    "apply_baseline",
    "load_baseline",
    "register",
    "render_json",
    "render_text",
    "write_baseline",
]
