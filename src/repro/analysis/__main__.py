"""branchlint CLI — ``python -m repro.analysis [options] paths...``

Exit status is the contract CI builds on: 0 when every finding is
suppressed or baselined, 1 when new findings exist (or a path failed
to parse), 2 on usage errors.

    python -m repro.analysis src tests
    python -m repro.analysis --format json src > lint.json
    python -m repro.analysis --baseline .branchlint-baseline.json src
    python -m repro.analysis --write-baseline .branchlint-baseline.json src
    python -m repro.analysis --rules BL001,BL004 src

When ``--baseline`` is not given and ``.branchlint-baseline.json``
exists in the working directory, it is used automatically — so local
runs and CI agree by default.
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path
from typing import List, Optional

from repro.analysis import (BASELINE_DEFAULT, RULES, analyze_paths,
                            apply_baseline, load_baseline, render_json,
                            render_text, write_baseline)


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="branchlint: the branch-context protocol checker")
    p.add_argument("paths", nargs="+",
                   help="files or directories to analyze")
    p.add_argument("--format", choices=("text", "json"), default="text",
                   help="output format (default: text)")
    p.add_argument("--baseline", type=Path, default=None, metavar="FILE",
                   help="accepted-findings file; new findings only fail "
                        f"(default: {BASELINE_DEFAULT} if present)")
    p.add_argument("--no-baseline", action="store_true",
                   help="ignore any baseline file, report everything")
    p.add_argument("--write-baseline", type=Path, default=None,
                   metavar="FILE",
                   help="write current findings as the new baseline "
                        "and exit 0")
    p.add_argument("--rules", default=None, metavar="CODES",
                   help="comma-separated rule codes to run "
                        f"(default: all of {','.join(sorted(RULES))})")
    return p


def main(argv: Optional[List[str]] = None) -> int:
    args = build_parser().parse_args(argv)

    rules: Optional[List[str]] = None
    if args.rules:
        rules = [c.strip().upper() for c in args.rules.split(",")
                 if c.strip()]
        unknown = [c for c in rules if c not in RULES]
        if unknown:
            print(f"unknown rule code(s): {', '.join(unknown)} "
                  f"(known: {', '.join(sorted(RULES))})", file=sys.stderr)
            return 2

    result = analyze_paths(args.paths, rules=rules)

    if args.write_baseline is not None:
        write_baseline(result.findings, args.write_baseline)
        print(f"wrote {len(result.findings)} finding(s) to "
              f"{args.write_baseline}")
        return 0

    baseline_path = args.baseline
    if baseline_path is None and not args.no_baseline and \
            BASELINE_DEFAULT.exists():
        baseline_path = BASELINE_DEFAULT
    if args.no_baseline:
        baseline_path = None

    if baseline_path is not None:
        try:
            entries = load_baseline(baseline_path)
        except (OSError, ValueError) as err:
            print(f"cannot read baseline {baseline_path}: {err}",
                  file=sys.stderr)
            return 2
        new, absorbed = apply_baseline(result.findings, entries)
    else:
        new, absorbed = list(result.findings), 0

    render = render_json if args.format == "json" else render_text
    print(render(result, new, absorbed))
    return 1 if (new or result.parse_errors) else 0


if __name__ == "__main__":
    sys.exit(main())
