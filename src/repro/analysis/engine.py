"""branchlint engine — findings, rule registry, suppressions, baseline.

The protocol checker's chassis.  Rules (``rules/``) are small AST
visitors registered by errno-style code (``BL001``..); the engine owns
everything around them:

* **Findings** are ``file:line:col  CODE  message`` records, stable
  enough to diff across runs: the baseline matches on
  ``(file, rule, source-line content)`` so unrelated edits above a
  baselined finding do not un-baseline it.
* **Suppressions** are per-line: ``# branchlint: ignore[BL002]`` on the
  offending line (or on a comment line directly above it) silences the
  listed rules; ``# branchlint: ignore`` silences every rule for that
  line.  Suppressions are for *false* positives — true positives get
  fixed, per the policy in DESIGN §15.
* **The baseline** (``.branchlint-baseline.json``) holds accepted
  pre-existing findings so CI can fail on *new* findings only.  An
  empty baseline is the healthy state; entries are debt.

Self-hosting is the point: ``python -m repro.analysis src`` must exit 0
on this repository, and the rules encode invariants the rest of the
codebase already promises (errno discipline, handle lifecycle, the
engine-thread boundary, span balance, metric grammar, flag validity).
"""

from __future__ import annotations

import ast
import json
import re
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

#: suppression comment grammar — "branchlint: ignore" after a hash,
#: optionally followed by a [BL001,BL004]-style rule list
_SUPPRESS_RE = re.compile(
    r"#\s*branchlint:\s*ignore(?:\[(?P<rules>[A-Z0-9,\s]+)\])?")

BASELINE_DEFAULT = Path(".branchlint-baseline.json")


@dataclass(frozen=True)
class Finding:
    """One rule violation, anchored to a source location."""

    file: str
    line: int
    col: int
    rule: str
    message: str
    snippet: str = ""

    def sort_key(self) -> Tuple[str, int, int, str]:
        return (self.file, self.line, self.col, self.rule)

    def to_json(self) -> Dict[str, object]:
        return {"file": self.file, "line": self.line, "col": self.col,
                "rule": self.rule, "message": self.message,
                "snippet": self.snippet}


class FileContext:
    """One parsed source file as the rules see it."""

    def __init__(self, path: Path, rel: str, source: str):
        self.path = path
        self.rel = rel
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=rel)
        #: line -> set of suppressed rule codes (None = all rules)
        self.suppressions: Dict[int, Optional[Set[str]]] = {}
        self._scan_suppressions()

    def _scan_suppressions(self) -> None:
        for lineno, text in enumerate(self.lines, start=1):
            m = _SUPPRESS_RE.search(text)
            if not m:
                continue
            rules = m.group("rules")
            codes: Optional[Set[str]] = None
            if rules:
                codes = {r.strip() for r in rules.split(",") if r.strip()}
            # a comment-only line suppresses the next source line too
            target = lineno
            if text.lstrip().startswith("#"):
                target = lineno + 1
            for ln in {lineno, target}:
                prev = self.suppressions.get(ln, set())
                if codes is None or prev is None:
                    self.suppressions[ln] = None
                else:
                    self.suppressions[ln] = prev | codes

    def suppressed(self, line: int, rule: str) -> bool:
        codes = self.suppressions.get(line, set())
        return codes is None or rule in (codes or ())

    def finding(self, node: ast.AST, rule: str, message: str) -> Finding:
        line = getattr(node, "lineno", 1)
        col = getattr(node, "col_offset", 0)
        snippet = self.lines[line - 1].strip() if line <= len(self.lines) \
            else ""
        return Finding(file=self.rel, line=line, col=col, rule=rule,
                       message=message, snippet=snippet)


class Rule:
    """Base rule: subclass, set ``code``/``title``, implement ``visit``.

    ``visit(ctx)`` runs per file; ``finalize(project)`` runs once after
    every file, for cross-file checks (metric kind collisions).
    """

    code: str = "BL000"
    title: str = ""
    rationale: str = ""

    def visit(self, ctx: FileContext) -> List[Finding]:
        return []

    def finalize(self, project: "Project") -> List[Finding]:
        return []


class Project:
    """Cross-file state handed to ``Rule.finalize``."""

    def __init__(self) -> None:
        self.files: List[FileContext] = []
        #: rule-owned scratch space keyed by rule code
        self.scratch: Dict[str, object] = {}


#: the registry: code -> rule instance (import rules/ to populate)
RULES: Dict[str, Rule] = {}


def register(cls: type) -> type:
    """Class decorator: instantiate and register a rule by its code."""
    inst = cls()
    if inst.code in RULES:
        raise ValueError(f"duplicate rule code {inst.code}")
    RULES[inst.code] = inst
    return cls


def iter_python_files(paths: Sequence[str]) -> Iterable[Path]:
    for raw in paths:
        p = Path(raw)
        if p.is_dir():
            yield from sorted(q for q in p.rglob("*.py")
                              if "__pycache__" not in q.parts)
        elif p.suffix == ".py":
            yield p


@dataclass
class AnalysisResult:
    findings: List[Finding] = field(default_factory=list)
    files_checked: int = 0
    suppressed: int = 0
    parse_errors: List[str] = field(default_factory=list)


def analyze_paths(paths: Sequence[str],
                  rules: Optional[Sequence[str]] = None) -> AnalysisResult:
    """Run the (selected) rules over every ``.py`` under ``paths``."""
    active = [RULES[c] for c in sorted(RULES)
              if rules is None or RULES[c].code in rules]
    project = Project()
    result = AnalysisResult()
    for path in iter_python_files(paths):
        rel = _relpath(path)
        try:
            ctx = FileContext(path, rel, path.read_text())
        except (SyntaxError, UnicodeDecodeError) as err:
            result.parse_errors.append(f"{rel}: {err}")
            continue
        project.files.append(ctx)
        result.files_checked += 1
        for rule in active:
            for f in rule.visit(ctx):
                if ctx.suppressed(f.line, f.rule):
                    result.suppressed += 1
                else:
                    result.findings.append(f)
    for rule in active:
        for f in rule.finalize(project):
            ctx = next((c for c in project.files if c.rel == f.file), None)
            if ctx is not None and ctx.suppressed(f.line, f.rule):
                result.suppressed += 1
            else:
                result.findings.append(f)
    result.findings.sort(key=Finding.sort_key)
    return result


def _relpath(path: Path) -> str:
    try:
        return path.resolve().relative_to(Path.cwd()).as_posix()
    except ValueError:
        return path.as_posix()


# ----------------------------------------------------------------------
# baseline
# ----------------------------------------------------------------------
def load_baseline(path: Path) -> List[Dict[str, object]]:
    data = json.loads(Path(path).read_text())
    entries = data.get("findings", [])
    if not isinstance(entries, list):
        raise ValueError(f"baseline {path} has no findings list")
    return entries


def write_baseline(findings: Sequence[Finding], path: Path) -> None:
    Path(path).write_text(json.dumps({
        "version": 1,
        "tool": "branchlint",
        "findings": [f.to_json() for f in sorted(findings,
                                                 key=Finding.sort_key)],
    }, indent=1) + "\n")


def apply_baseline(findings: Sequence[Finding],
                   baseline: Sequence[Dict[str, object]]
                   ) -> Tuple[List[Finding], int]:
    """Split findings into (new, n_baselined).

    Matching is content-anchored — ``(file, rule, snippet)`` — so a
    baselined finding survives line drift from unrelated edits; each
    baseline entry absorbs at most one finding (count-aware).
    """
    budget: Dict[Tuple[str, str, str], int] = {}
    for e in baseline:
        key = (str(e.get("file")), str(e.get("rule")),
               str(e.get("snippet", "")))
        budget[key] = budget.get(key, 0) + 1
    new: List[Finding] = []
    absorbed = 0
    for f in findings:
        key = (f.file, f.rule, f.snippet)
        if budget.get(key, 0) > 0:
            budget[key] -= 1
            absorbed += 1
        else:
            new.append(f)
    return new, absorbed


# ----------------------------------------------------------------------
# rendering
# ----------------------------------------------------------------------
def render_text(result: AnalysisResult, new: Sequence[Finding],
                baselined: int) -> str:
    lines = [f"{f.file}:{f.line}:{f.col}: {f.rule} {f.message}"
             for f in new]
    lines.append(
        f"branchlint: {len(new)} finding(s) "
        f"({baselined} baselined, {result.suppressed} suppressed) "
        f"in {result.files_checked} file(s)")
    for err in result.parse_errors:
        lines.append(f"parse error: {err}")
    return "\n".join(lines)


def render_json(result: AnalysisResult, new: Sequence[Finding],
                baselined: int) -> str:
    return json.dumps({
        "version": 1,
        "tool": "branchlint",
        "rules": {code: rule.title for code, rule in sorted(RULES.items())},
        "files_checked": result.files_checked,
        "suppressed": result.suppressed,
        "baselined": baselined,
        "parse_errors": result.parse_errors,
        "findings": [f.to_json() for f in new],
    }, indent=1)


__all__ = [
    "AnalysisResult",
    "BASELINE_DEFAULT",
    "FileContext",
    "Finding",
    "Project",
    "RULES",
    "Rule",
    "analyze_paths",
    "apply_baseline",
    "iter_python_files",
    "load_baseline",
    "register",
    "render_json",
    "render_text",
    "write_baseline",
]
