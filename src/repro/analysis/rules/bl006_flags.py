"""BL006 — flags validity: only declared BR_* flags, no namespace mixing.

Two flag words share the ``BR_`` prefix and *different bit layouts*
(the classic errno-style trap this rule exists for):

* the **API word** (``repro.api.flags``): ``BR_ISOLATE=1<<0``,
  ``BR_HOLD=1<<1``, ``BR_NESTED=1<<2``, ``BR_SPECULATIVE=1<<3``,
  ``BR_NONBLOCK=1<<4``, ``BR_TIERED=1<<5`` (stat-only), plus the
  ``BR_ALL`` mask;
* the **runtime word** (``repro.core.runtime_api``): op codes
  ``BR_CREATE/BR_COMMIT/BR_ABORT`` and create-flags ``BR_STATE=1<<0``,
  ``BR_KV=1<<1``, ``BR_ISOLATE=1<<2``, ``BR_CLOSE_FDS=1<<3``.

Note ``BR_ISOLATE`` exists in *both* with *different values* — OR-ing
an API flag into a runtime word (or vice versa) type-checks, runs, and
quietly sets the wrong bit.  Checks:

* **Unknown flag** — any ``BR_*`` identifier that is not declared in
  either namespace (typos like ``BR_SPECULATE`` silently become
  ``NameError`` at best, a mis-resolved import at worst).
* **Namespace mixing** — one ``|`` expression combining a flag that
  exists only in the API word with one that exists only in the runtime
  word.  (``BR_ISOLATE`` is in both, so it can't convict on its own.)
* **Ungated truncate** — ``session.truncate(hd, ...)`` is ``-EPERM``
  unless the branch was opened with ``BR_SPECULATIVE``
  (api/flags.py's license).  A callsite in a function that never
  mentions the flag is either dead-on-arrival or relying on a distant
  invariant; wrappers themselves named ``truncate`` are exempt (they
  *are* the documented pass-through surface).
"""

from __future__ import annotations

import ast
import re
from typing import List, Set

from repro.analysis.engine import FileContext, Finding, Rule, register
from repro.analysis.rules.common import (SESSION_NAMES, call_method,
                                         iter_functions, own_nodes,
                                         receiver_tail)

API_FLAGS = frozenset({"BR_ISOLATE", "BR_HOLD", "BR_NESTED",
                       "BR_SPECULATIVE", "BR_NONBLOCK", "BR_TIERED",
                       "BR_ALL"})
RT_FLAGS = frozenset({"BR_CREATE", "BR_COMMIT", "BR_ABORT", "BR_STATE",
                      "BR_KV", "BR_ISOLATE", "BR_CLOSE_FDS"})
DECLARED = API_FLAGS | RT_FLAGS
API_ONLY = API_FLAGS - RT_FLAGS
RT_ONLY = RT_FLAGS - API_FLAGS

_FLAG_RE = re.compile(r"^BR_[A-Z][A-Z_]*$")


def _flag_names(node: ast.AST) -> Set[str]:
    """Every BR_* identifier read in a subtree (Name loads + attrs)."""
    out: Set[str] = set()
    for sub in ast.walk(node):
        if isinstance(sub, ast.Name) and _FLAG_RE.match(sub.id) and \
                isinstance(sub.ctx, ast.Load):
            out.add(sub.id)
        elif isinstance(sub, ast.Attribute) and _FLAG_RE.match(sub.attr) \
                and isinstance(sub.ctx, ast.Load):
            out.add(sub.attr)
    return out


def _bitor_leaves(node: ast.BinOp) -> Set[str]:
    """BR_* names joined by one contiguous ``|`` expression."""
    names: Set[str] = set()
    stack = [node]
    while stack:
        n = stack.pop()
        if isinstance(n, ast.BinOp) and isinstance(n.op, ast.BitOr):
            stack.extend([n.left, n.right])
        else:
            names |= _flag_names(n)
    return names


def _func_source(ctx: FileContext, func: ast.AST) -> str:
    start = getattr(func, "lineno", 1) - 1
    end = getattr(func, "end_lineno", start + 1)
    return "\n".join(ctx.lines[start:end])


@register
class FlagsValidity(Rule):
    code = "BL006"
    title = "flags validity: declared BR_* only, no API/runtime word " \
            "mixing, truncate gated on BR_SPECULATIVE"
    rationale = ("the API and runtime flag words share a prefix but not "
                 "bit layouts; a mixed word sets the wrong bit silently")

    def visit(self, ctx: FileContext) -> List[Finding]:
        out: List[Finding] = []
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Name) and \
                    isinstance(node.ctx, ast.Load) and \
                    _FLAG_RE.match(node.id) and node.id not in DECLARED:
                out.append(ctx.finding(
                    node, self.code,
                    f"{node.id} is not a declared flag in either the "
                    "API word (repro.api.flags) or the runtime word "
                    "(repro.core.runtime_api)"))
            elif isinstance(node, ast.Attribute) and \
                    isinstance(node.ctx, ast.Load) and \
                    _FLAG_RE.match(node.attr) and \
                    node.attr not in DECLARED:
                out.append(ctx.finding(
                    node, self.code,
                    f"{node.attr} is not a declared flag in either the "
                    "API word or the runtime word"))
            elif isinstance(node, ast.BinOp) and \
                    isinstance(node.op, ast.BitOr):
                # only convict at the top of a | chain, once
                leaves = _bitor_leaves(node)
                api = sorted(leaves & API_ONLY)
                rt = sorted(leaves & RT_ONLY)
                if api and rt and not self._parent_is_bitor(ctx, node):
                    out.append(ctx.finding(
                        node, self.code,
                        f"one flag word mixes API flags {api} with "
                        f"runtime flags {rt}; the two namespaces have "
                        "different bit layouts — build each word from "
                        "its own module only"))
        out.extend(self._truncate_gates(ctx))
        return out

    # report each | chain once: precompute which BinOps are nested
    def _parent_is_bitor(self, ctx: FileContext, node: ast.BinOp) -> bool:
        cache = getattr(ctx, "_bl006_bitor_children", None)
        if cache is None:
            cache = set()
            for sub in ast.walk(ctx.tree):
                if isinstance(sub, ast.BinOp) and \
                        isinstance(sub.op, ast.BitOr):
                    for child in (sub.left, sub.right):
                        if isinstance(child, ast.BinOp) and \
                                isinstance(child.op, ast.BitOr):
                            cache.add(id(child))
            ctx._bl006_bitor_children = cache  # type: ignore[attr-defined]
        return id(node) in cache

    def _truncate_gates(self, ctx: FileContext) -> List[Finding]:
        out: List[Finding] = []
        for func, qual, _is_async in iter_functions(ctx.tree):
            if func.name == "truncate":
                continue        # the documented pass-through wrapper
            mentions_gate = "BR_SPECULATIVE" in _func_source(ctx, func)
            if mentions_gate:
                continue
            for node in own_nodes(func):
                if isinstance(node, ast.Call) and \
                        call_method(node) == "truncate" and \
                        receiver_tail(node) in SESSION_NAMES:
                    out.append(ctx.finding(
                        node, self.code,
                        f"{qual}() calls session.truncate() but never "
                        "references BR_SPECULATIVE; truncate is -EPERM "
                        "on non-speculative branches — open with "
                        "BR_SPECULATIVE or gate the call"))
        return out
