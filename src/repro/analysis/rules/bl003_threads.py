"""BL003 — thread boundary: asyncio code never dispatches on the engine.

The serving stack runs two worlds (DESIGN §13): the **engine thread**
owns every JAX dispatch and all ``BranchSession``/``ServeEngine``
mutation; the **asyncio event loop** owns sockets, futures, and queues.
The only legal crossings are:

* loop → engine: post a closure onto the command queue
  (``mux.call(fn)`` / ``mux.post(fn)``) and await the future;
* engine → loop: ``loop.call_soon_threadsafe(cb, ...)`` with a callback
  the loop will run (resolving a future, feeding an ``asyncio.Queue``).

Two anti-patterns cross the boundary bare:

* an ``async def`` body invoking a dispatching verb (``step``,
  ``open``, ``branch``, ``commit``...) directly on a session/scheduler/
  engine receiver — that runs JAX dispatch on the event-loop thread,
  racing the engine thread on the handle table and page pool;
* a synchronous (engine-side) function resolving asyncio primitives
  in-place (``fut.set_result``, ``queue.put_nowait``) instead of
  marshalling through ``call_soon_threadsafe`` — asyncio objects are
  not thread-safe and the wakeup is silently lost.

Closures defined *inside* an async body (nested ``def``/``lambda``) are
exempt from the first check — they are exactly the payloads shipped to
the engine via the command queue.  Sync callbacks whose *name* is
passed to ``call_soon_threadsafe`` in the same file are exempt from the
second — they run on the loop thread by construction.
"""

from __future__ import annotations

import ast
from typing import List, Set

from repro.analysis.engine import FileContext, Finding, Rule, register
from repro.analysis.rules.common import (call_method, calls_in,
                                         iter_functions, own_nodes,
                                         receiver_tail)

#: verbs that dispatch JAX work or mutate engine-owned state
DISPATCH_VERBS = frozenset({
    "step", "open", "adopt", "branch", "commit", "abort", "finish",
    "wait", "submit", "admit", "fork", "decode", "prefill", "verify",
    "spec_verify", "truncate", "resume", "pause", "hold", "unhold",
    "explore", "launch", "evict", "evict_all", "evict_parked",
    "kick_stalled", "set_sampling",
})

#: receivers that address the engine-thread-owned stack
ENGINE_RECEIVERS = frozenset({"session", "sess", "sched", "engine",
                              "driver"})

#: asyncio-primitive mutators that are not thread-safe
LOOP_ONLY_VERBS = frozenset({"set_result", "set_exception", "put_nowait"})

def _threadsafe_names(ctx: FileContext) -> Set[str]:
    """Names handed to ``call_soon_threadsafe`` anywhere in the file."""
    names: Set[str] = set()
    for call in calls_in(ctx.tree, "call_soon_threadsafe"):
        for arg in call.args:
            if isinstance(arg, ast.Name):
                names.add(arg.id)
    return names


@register
class ThreadBoundary(Rule):
    code = "BL003"
    title = "thread boundary: asyncio<->engine crossings go through the " \
            "command queue / call_soon_threadsafe"
    rationale = ("JAX dispatch belongs to the engine thread and asyncio "
                 "primitives to the loop thread; bare crossings race")

    def visit(self, ctx: FileContext) -> List[Finding]:
        out: List[Finding] = []
        exempt = _threadsafe_names(ctx)
        for func, qual, is_async in iter_functions(ctx.tree):
            own = own_nodes(func)
            if is_async:
                for node in own:
                    if not isinstance(node, ast.Call):
                        continue
                    verb = call_method(node)
                    if verb in DISPATCH_VERBS and \
                            receiver_tail(node) in ENGINE_RECEIVERS:
                        out.append(ctx.finding(
                            node, self.code,
                            f"async {qual}() dispatches "
                            f".{verb}() on the engine directly; post a "
                            "closure via the command queue (mux.call) "
                            "and await the future instead"))
            else:
                if func.name in exempt:
                    continue    # runs on the loop via call_soon_threadsafe
                for node in own:
                    if not isinstance(node, ast.Call):
                        continue
                    verb = call_method(node)
                    if verb in LOOP_ONLY_VERBS and \
                            not self._inside_threadsafe(node, func):
                        out.append(ctx.finding(
                            node, self.code,
                            f"sync {qual}() calls .{verb}() on an "
                            "asyncio primitive in-place; marshal through "
                            "loop.call_soon_threadsafe so the loop "
                            "thread performs the mutation"))
        return out

    @staticmethod
    def _inside_threadsafe(call: ast.Call, func: ast.AST) -> bool:
        """Whether ``call`` sits inside a call_soon_threadsafe(...) arg."""
        for outer in calls_in(func, "call_soon_threadsafe"):
            for arg in outer.args:
                if any(sub is call for sub in ast.walk(arg)):
                    return True
        return False
