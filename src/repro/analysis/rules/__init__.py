"""branchlint rules — importing this package populates the registry.

Each module defines one ``@register``-ed rule; the engine's ``RULES``
dict is the single source of truth afterwards.  Add a rule by dropping
a ``blNNN_*.py`` module here and importing it below (DESIGN §15 walks
through the full recipe).
"""

from repro.analysis.rules import (bl001_errno, bl002_handles,  # noqa: F401
                                  bl003_threads, bl004_spans,
                                  bl005_metrics, bl006_flags)
