"""BL004 — span balance: locally-managed spans close exactly once.

``Tracer.begin_span`` pushes onto a per-track stack; ``end_span`` pops
and returns False on an already-empty track (obs/tracer.py's
re-entrant close guard).  The runtime guard makes a double-close
*survivable*, not correct: the stray pop closes the **enclosing** span
early, silently mis-nesting every span above it in the Perfetto trace.
A missing pop is worse — the span stays open forever and the track is
ruined from that point on.

The repo's branch-long spans (opened at ``create_root``/``fork``,
closed at resolve) are managed across functions by the lifecycle
module, and no local rule can see that protocol.  So this rule checks
the *locally-managed* case only: a function that both begins **and**
ends spans must balance them on every exit path — including the
``raise`` paths, which is exactly what ``try/finally`` is for.  The
:mod:`repro.analysis.cfg` simulator enumerates the paths; the state is
the open-span depth.
"""

from __future__ import annotations

import ast
from typing import FrozenSet, Iterable, List, Set, Tuple

from repro.analysis.cfg import simulate
from repro.analysis.engine import FileContext, Finding, Rule, register
from repro.analysis.rules.common import calls_in, iter_functions

_BEGIN = "begin_span"
_END = "end_span"
_UNDER = "spans:-1"          # sticky fact: an end_span underflowed


def _net_spans(node: ast.AST) -> Tuple[int, List[ast.Call]]:
    """(net depth change, end_span calls in source order) for a stmt."""
    net = 0
    ends: List[ast.Call] = []
    for call in calls_in(node, _BEGIN, _END):
        name = call.func.attr if isinstance(call.func, ast.Attribute) \
            else call.func.id if isinstance(call.func, ast.Name) else ""
        if name == _BEGIN:
            net += 1
        else:
            net -= 1
            ends.append(call)
    return net, ends


@register
class SpanBalance(Rule):
    code = "BL004"
    title = "span balance: begin_span/end_span pair exactly once on " \
            "every exit path"
    rationale = ("an unmatched pop closes the enclosing span early and "
                 "mis-nests the trace; a missing pop ruins the track")

    def visit(self, ctx: FileContext) -> List[Finding]:
        out: List[Finding] = []
        for func, qual, _is_async in iter_functions(ctx.tree):
            has_begin = any(True for _ in calls_in(func, _BEGIN))
            has_end = any(True for _ in calls_in(func, _END))
            if not (has_begin and has_end):
                # branch-long spans are balanced cross-function by the
                # lifecycle protocol; only local management is checkable
                continue
            underflows: List[ast.Call] = []

            def transfer(node: ast.AST,
                         state: FrozenSet[str]) -> Iterable[FrozenSet[str]]:
                depth = next((int(f.split(":", 1)[1]) for f in state
                              if f.startswith("spans:") and f != _UNDER),
                             0)
                sticky = {f for f in state if f == _UNDER}
                net, ends = _net_spans(node)
                # worst-case ordering within one statement: pops first
                if ends and depth - len(ends) < 0:
                    underflows.extend(ends)
                    sticky = {_UNDER}
                depth = max(depth + net, 0)
                facts: Set[str] = set(sticky)
                if depth:
                    facts.add(f"spans:{depth}")
                return [frozenset(facts)]

            exits = simulate(list(func.body), frozenset(), transfer)
            reported: Set[int] = set()
            for ex in exits:
                depth = next((int(f.split(":", 1)[1]) for f in ex.state
                              if f.startswith("spans:") and f != _UNDER),
                             0)
                if depth > 0:
                    line = getattr(ex.node, "lineno", 0)
                    if line not in reported:
                        reported.add(line)
                        out.append(ctx.finding(
                            ex.node, self.code,
                            f"{qual}() can exit ({ex.kind}) with "
                            f"{depth} span(s) still open; close in a "
                            "finally so raise paths balance too"))
            for call in underflows:
                if id(call) in reported:
                    continue
                reported.add(id(call))
                out.append(ctx.finding(
                    call, self.code,
                    f"{qual}() can call end_span() with no span open "
                    "on some path; the stray pop closes the enclosing "
                    "span early and mis-nests the trace"))
        return out
