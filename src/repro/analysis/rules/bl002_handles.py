"""BL002 — handle lifecycle: acquired session handles must not leak.

The invariant (DESIGN §10): a handle returned by ``session.open()`` /
``session.branch()`` / ``session.adopt()`` owns table slots, page
reservations, and (for composites) a store subtree.  Within the
function that acquired it, every path to an exit must either

* **release** it — pass it to ``commit``/``abort``/``finish``/
  ``close``, or
* **escape** it — return/yield it, store it on an object or in a
  container, alias it, iterate it into per-element processing, or hand
  it to another callable that takes ownership.

A path that reaches ``return``/``raise``/fall-through while still
holding the handle orphans a live branch: its reservations never free,
and nobody can ever address it again (the slot index is lost).  This is
the static face of the PR 9 ``session.branch(n=k)`` mid-vector unwind
fix — the dynamic variant is tested in ``tests/test_api.py``.

The path walk is the :mod:`repro.analysis.cfg` simulator; read-only
session verbs (``seq_of``, ``tokens``, ``stat``...) deliberately do
NOT count as escapes, so "peeked at it, then bailed out early" is still
reported as the leak it is.
"""

from __future__ import annotations

import ast
from typing import Dict, FrozenSet, Iterable, List, Set, Tuple

from repro.analysis.cfg import ExitPath, simulate
from repro.analysis.engine import FileContext, Finding, Rule, register
from repro.analysis.rules.common import (SESSION_NAMES, call_method,
                                         iter_functions, name_used,
                                         receiver_tail)

#: verbs that create a handle the caller then owns
ACQUIRE_VERBS = frozenset({"open", "branch", "adopt"})

#: verbs that resolve/retire/release a handle (ownership consumed)
RELEASE_VERBS = frozenset({"commit", "abort", "finish", "close"})

#: session verbs that only *read* a handle — not an escape
READ_VERBS = frozenset({
    "seq_of", "req_id_of", "tokens", "stat", "events", "produced",
    "status", "state_of", "siblings", "tracked", "alive", "admitted",
    "result", "decode_target_met", "resume", "pause", "poll",
})

_COMPREHENSIONS = (ast.ListComp, ast.SetComp, ast.GeneratorExp,
                   ast.DictComp)


def _acquisitions(func: ast.AST) -> Dict[int, Tuple[str, ast.Assign]]:
    """id(assign-node) -> (var, node) for handle-producing assigns."""
    out: Dict[int, Tuple[str, ast.Assign]] = {}
    for node in ast.walk(func):
        if not isinstance(node, ast.Assign) or len(node.targets) != 1:
            continue
        target = node.targets[0]
        if not isinstance(target, ast.Name):
            continue
        value = node.value
        if not isinstance(value, ast.Call):
            continue
        if call_method(value) in ACQUIRE_VERBS and \
                receiver_tail(value) in SESSION_NAMES:
            out[id(node)] = (target.id, node)
    return out


def _iterated_exprs(func: ast.AST) -> Set[int]:
    """ids of expressions used as ``for ... in <expr>`` iterables."""
    return {id(node.iter) for node in ast.walk(func)
            if isinstance(node, (ast.For, ast.AsyncFor))}


def _is_read_call(node: ast.AST) -> bool:
    return isinstance(node, ast.Call) and \
        call_method(node) in READ_VERBS and \
        receiver_tail(node) in (SESSION_NAMES | {"self"})


def _uses_outside_reads(node: ast.AST, var: str) -> bool:
    """Whether ``var`` occurs in the subtree other than as an argument
    of a read-verb call (``BranchError(f"...{session.seq_of(hd)}")``
    only *reads* hd — the outer call must not count as an escape)."""
    if _is_read_call(node):
        return False
    if isinstance(node, ast.Name):
        return node.id == var
    return any(_uses_outside_reads(c, var)
               for c in ast.iter_child_nodes(node))


def _var_effect(node: ast.AST, var: str, iter_ids: Set[int]) -> str:
    """How ``node`` treats a held handle var: keep | release | escape."""
    if id(node) in iter_ids and name_used(node, var):
        return "escape"     # handle list iterated into per-element code
    effect = "keep"
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call):
            touched = \
                any(_uses_outside_reads(a, var) for a in sub.args) or \
                any(_uses_outside_reads(k.value, var)
                    for k in sub.keywords)
            if not touched:
                continue
            method = call_method(sub)
            if method in RELEASE_VERBS:
                return "release"
            if method in READ_VERBS and \
                    receiver_tail(sub) in (SESSION_NAMES | {"self"}):
                continue            # a read, not an ownership transfer
            effect = "escape"
        elif isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef,
                              ast.Lambda)):
            if sub is not node and name_used(sub, var):
                effect = "escape"   # captured by a closure
        elif isinstance(sub, _COMPREHENSIONS):
            if any(name_used(gen.iter, var) for gen in sub.generators):
                effect = "escape"   # comprehension over the handle list
    if effect == "escape":
        return effect
    if isinstance(node, (ast.Return, ast.Yield, ast.YieldFrom)) and \
            _uses_outside_reads(node, var):
        return "escape"
    if isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
        value = getattr(node, "value", None)
        if value is not None and _uses_outside_reads(value, var):
            return "escape"         # aliased or stored
    if isinstance(node, ast.Expr) and name_used(node, var) and \
            isinstance(node.value, (ast.Yield, ast.YieldFrom, ast.Await)):
        return "escape"
    if isinstance(node, ast.Delete) and name_used(node, var):
        return "escape"
    return effect


@register
class HandleLifecycle(Rule):
    code = "BL002"
    title = "handle lifecycle: session handles reach " \
            "commit/abort/finish/close or escape on every path"
    rationale = ("a dropped handle orphans a live branch: reservations "
                 "never free and the slot index is unrecoverable")

    def visit(self, ctx: FileContext) -> List[Finding]:
        out: List[Finding] = []
        for func, qual, _is_async in iter_functions(ctx.tree):
            acquisitions = _acquisitions(func)
            if not acquisitions:
                continue
            iter_ids = _iterated_exprs(func)
            held = "held:"

            def transfer(node: ast.AST,
                         state: FrozenSet[str]) -> Iterable[FrozenSet[str]]:
                s: Set[str] = set(state)
                for fact in list(s):
                    effect = _var_effect(node, fact[len(held):], iter_ids)
                    if effect in ("release", "escape"):
                        s.discard(fact)
                if id(node) in acquisitions:
                    s.add(held + acquisitions[id(node)][0])
                return [frozenset(s)]

            exits: List[ExitPath] = simulate(
                list(func.body), frozenset(), transfer)
            leaks: Dict[str, Set[Tuple[str, int]]] = {}
            for ex in exits:
                for fact in ex.state:
                    leaks.setdefault(fact[len(held):], set()).add(
                        (ex.kind, getattr(ex.node, "lineno", 0)))
            seen: Set[str] = set()
            for var, node in acquisitions.values():
                if var not in leaks or var in seen:
                    continue
                seen.add(var)
                ways = sorted(leaks[var])
                desc = ", ".join(f"{k} at line {ln}" for k, ln in ways)
                verb = call_method(node.value)
                out.append(ctx.finding(
                    node, self.code,
                    f"handle {var!r} from session.{verb}() in {qual}() "
                    f"may leak ({desc}): no commit/abort/finish/close "
                    "or escape on that path"))
        return out
