"""BL001 — errno discipline on the API/server error surfaces.

The invariant (DESIGN §10, core/errors.py): every failure crossing the
branch-context boundary carries a machine-readable ``Errno`` — either a
``BranchError`` subclass's ``default_errno`` or an explicit
``errno=`` override — so the front door can map it onto an HTTP status
(429/507/400) and clients can branch on the code.  Two anti-patterns
break that chain:

* **Silent broad catch** — ``except Exception: pass`` (or any handler
  that catches ``Exception``/``BaseException``/bare and neither
  re-raises nor uses the bound exception) swallows the errno on the
  very paths that were supposed to report it.  PR 8's front door
  shipped several of these on HTTP paths; this rule is why they cannot
  come back.
* **Errno-less raise** — ``raise RuntimeError(...)`` /
  ``raise Exception(...)`` on a protocol surface.  ``BranchError``
  *is* a ``RuntimeError``, so raising the generic class bypasses the
  errno vocabulary while still being caught by family handlers —
  the worst of both.

Scope: files under ``api/``/``server/`` path segments, plus any module
that imports the shared error vocabulary (mentions ``BranchError`` or
``Errno``).  ``ValueError``/``TypeError``/``KeyError`` raises stay
legal — they are Python-contract errors (bad arguments), not branch
protocol failures.
"""

from __future__ import annotations

import ast
from typing import List

from repro.analysis.engine import FileContext, Finding, Rule, register
from repro.analysis.rules.common import catches_broad, name_used

#: generic exception classes that carry no errno but overlap BranchError
_GENERIC_RAISES = frozenset({"Exception", "BaseException", "RuntimeError"})


def _in_scope(ctx: FileContext) -> bool:
    parts = set(ctx.rel.split("/"))
    if {"api", "server"} & parts:
        return True
    return "BranchError" in ctx.source or "Errno" in ctx.source


def _swallows(handler: ast.ExceptHandler) -> bool:
    """True when the handler neither re-raises nor looks at the error."""
    for stmt in handler.body:
        for sub in ast.walk(stmt):
            if isinstance(sub, ast.Raise):
                return False
    if handler.name:
        return not any(name_used(stmt, handler.name)
                       for stmt in handler.body)
    return True


@register
class ErrnoDiscipline(Rule):
    code = "BL001"
    title = "errno discipline: no swallowed or errno-less errors on " \
            "API/server paths"
    rationale = ("every BranchError carries an Errno; broad silent "
                 "catches and generic raises break the errno->HTTP chain")

    def visit(self, ctx: FileContext) -> List[Finding]:
        if not _in_scope(ctx):
            return []
        out: List[Finding] = []
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.ExceptHandler) and \
                    catches_broad(node) and _swallows(node):
                what = "bare except" if node.type is None else \
                    "except Exception"
                out.append(ctx.finding(
                    node, self.code,
                    f"{what} silently swallows errors (and their errno) "
                    "on a protocol surface; catch the specific "
                    "BranchError family (or narrow OS errors) instead"))
            elif isinstance(node, ast.Raise) and node.exc is not None:
                exc = node.exc
                name = None
                if isinstance(exc, ast.Call) and \
                        isinstance(exc.func, ast.Name):
                    name = exc.func.id
                elif isinstance(exc, ast.Name):
                    name = exc.id
                if name in _GENERIC_RAISES:
                    out.append(ctx.finding(
                        node, self.code,
                        f"raise {name} carries no Errno; raise a "
                        "BranchError subclass (or BranchError with "
                        "errno=) so callers can map the failure"))
        return out
