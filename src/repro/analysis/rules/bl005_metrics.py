"""BL005 — metric hygiene: names, kinds, and no gauge-sets in closures.

The :mod:`repro.obs.metrics` registry is get-or-create by name and a
name binds to exactly one kind — ``counter("x")`` after ``gauge("x")``
raises ``TypeError`` at runtime, *in whoever asks second*, which may be
a benchmark harness three modules away from the collision.  This rule
moves the whole contract to lint time:

* **Grammar** — literal instrument names must be dotted
  ``component.metric`` (``sched.submitted``, ``kv.cow_faults``):
  lowercase, at least one dot, no uppercase/dashes/leading digits.
  Undotted names don't group in ``format()``'s procfs-style block and
  collide across components.  Non-literal names (f-strings, variables)
  are skipped — the grammar is only checkable for constants.
* **Kind collisions** — ``finalize`` joins every literal registration
  across all analyzed files and reports a name claimed as two kinds,
  pointing at the second claimant (the one that would raise).
* **Closure gauges** — ``gauge(...).set(...)`` inside a ``lambda`` or
  nested def captures the registry (and whatever the closure also
  holds: an engine, a pool) for as long as the callback lives, and
  races the mutation-site updates.  Per the metrics module's own
  design note, gauges are set at the mutation site only.
"""

from __future__ import annotations

import ast
import re
from typing import Dict, List, Tuple

from repro.analysis.engine import (FileContext, Finding, Project, Rule,
                                   register)
from repro.analysis.rules.common import calls_in

#: registration verbs -> instrument kind
_KINDS = {"counter": "counter", "gauge": "gauge", "histogram": "histogram"}

#: component.metric grammar (underscored lowercase segments, >=1 dot)
_NAME_RE = re.compile(r"^[a-z][a-z0-9_]*(\.[a-z0-9_]+)+$")

def _literal_name(call: ast.Call) -> str:
    """The constant string name a registration call uses, or ''."""
    args = call.args
    if args and isinstance(args[0], ast.Constant) and \
            isinstance(args[0].value, str):
        return args[0].value
    return ""


def _kind_of(call: ast.Call) -> str:
    func = call.func
    name = func.attr if isinstance(func, ast.Attribute) else \
        func.id if isinstance(func, ast.Name) else ""
    return _KINDS.get(name, "")


def _registrations(ctx: FileContext) -> List[Tuple[str, str, int]]:
    """Every literal-name (name, kind, line) registration in a file."""
    out = []
    for call in calls_in(ctx.tree, *_KINDS):
        name = _literal_name(call)
        if name:
            out.append((name, _kind_of(call), getattr(call, "lineno", 0)))
    return out


@register
class MetricHygiene(Rule):
    code = "BL005"
    title = "metric hygiene: dotted names, one kind per name, no gauge " \
            "mutation from closures"
    rationale = ("kind collisions raise at the second claimant at "
                 "runtime; undotted names break format() grouping; "
                 "closure gauges pin objects and race mutation sites")

    def visit(self, ctx: FileContext) -> List[Finding]:
        out: List[Finding] = []
        for call in calls_in(ctx.tree, *_KINDS):
            name = _literal_name(call)
            if not name:
                continue        # dynamic name: grammar not checkable
            if not _NAME_RE.match(name):
                out.append(ctx.finding(
                    call, self.code,
                    f"metric name {name!r} is not component.metric "
                    "grammar (lowercase dotted segments); undotted "
                    "names collide across components and break "
                    "format() grouping"))
        # gauge mutation from inside a closure (a lambda, or a def
        # nested inside another function — module-level functions and
        # methods ARE the mutation sites and stay legal)
        for closure in self._closures(ctx.tree):
            for call in calls_in(closure, "set", "add"):
                func = call.func
                if isinstance(func, ast.Attribute) and \
                        isinstance(func.value, ast.Call) and \
                        _kind_of(func.value) == "gauge":
                    out.append(ctx.finding(
                        call, self.code,
                        "gauge mutated from inside a closure; set "
                        "gauges at the mutation site so retained "
                        "callbacks never pin the registry or race it"))
        return out

    @staticmethod
    def _closures(tree: ast.AST) -> List[ast.AST]:
        seen: dict = {}
        for node in ast.walk(tree):
            if isinstance(node, ast.Lambda):
                seen[id(node)] = node
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for sub in ast.walk(node):
                    if sub is not node and \
                            isinstance(sub, (ast.FunctionDef,
                                             ast.AsyncFunctionDef)):
                        seen[id(sub)] = sub
        return list(seen.values())

    def finalize(self, project: Project) -> List[Finding]:
        registry: Dict[str, List[Tuple[str, str, int]]] = {}
        for ctx in project.files:
            for name, kind, line in _registrations(ctx):
                registry.setdefault(name, []).append(
                    (kind, ctx.rel, line))
        out: List[Finding] = []
        for name, claims in sorted(registry.items()):
            first_kind, first_file, _ = claims[0]
            for kind, rel, line in claims[1:]:
                if kind == first_kind:
                    continue
                ctx = next((c for c in project.files if c.rel == rel),
                           None)
                out.append(Finding(
                    file=rel, line=line, col=0, rule=self.code,
                    message=(f"metric {name!r} registered as {kind} "
                             f"here but as {first_kind} in "
                             f"{first_file}; a name binds to exactly "
                             "one kind (the second claimant raises "
                             "TypeError at runtime)"),
                    snippet=(ctx.lines[line - 1].strip()
                             if ctx and line <= len(ctx.lines) else "")))
        return out
