"""Shared AST helpers for the branchlint rules."""

from __future__ import annotations

import ast
from typing import Iterator, List, Optional, Tuple

#: receiver names that address the session/scheduler/engine stack
SESSION_NAMES = frozenset({"session", "sess"})


def dotted(node: ast.AST) -> Optional[List[str]]:
    """``self.session.open`` -> ``["self", "session", "open"]``."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return list(reversed(parts))
    return None


def call_method(call: ast.Call) -> Optional[str]:
    """The method/function name a Call invokes, if syntactically plain."""
    if isinstance(call.func, ast.Attribute):
        return call.func.attr
    if isinstance(call.func, ast.Name):
        return call.func.id
    return None


def receiver_tail(call: ast.Call) -> Optional[str]:
    """The name immediately left of the method: ``a.b.open()`` -> ``b``."""
    if not isinstance(call.func, ast.Attribute):
        return None
    value = call.func.value
    if isinstance(value, ast.Attribute):
        return value.attr
    if isinstance(value, ast.Name):
        return value.id
    return None


def iter_functions(tree: ast.AST) -> Iterator[Tuple[ast.AST, str, bool]]:
    """Yield ``(func_node, qualname, is_async)`` for every def, outermost
    first; nested defs are yielded too (each analyzed on its own)."""

    def walk(node: ast.AST, prefix: str) -> Iterator:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                qual = f"{prefix}{child.name}"
                yield (child, qual,
                       isinstance(child, ast.AsyncFunctionDef))
                yield from walk(child, qual + ".")
            elif isinstance(child, ast.ClassDef):
                yield from walk(child, f"{prefix}{child.name}.")
            else:
                yield from walk(child, prefix)

    yield from walk(tree, "")


def calls_in(node: ast.AST, *methods: str) -> Iterator[ast.Call]:
    """Every Call in the subtree whose plain method name is in ``methods``."""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call) and call_method(sub) in methods:
            yield sub


def own_nodes(func: ast.AST) -> List[ast.AST]:
    """Walk ``func``'s body but stop at nested def/lambda boundaries, so
    a node is attributed to its *innermost* enclosing function only."""
    out: List[ast.AST] = []
    stack: List[ast.AST] = list(ast.iter_child_nodes(func))
    while stack:
        node = stack.pop()
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            continue
        out.append(node)
        stack.extend(ast.iter_child_nodes(node))
    return out


def name_used(node: ast.AST, name: str) -> bool:
    return any(isinstance(sub, ast.Name) and sub.id == name
               for sub in ast.walk(node))


def catches_broad(handler: ast.ExceptHandler) -> bool:
    """Whether an except clause catches Exception/BaseException/bare."""

    def broad(t: ast.expr) -> bool:
        return isinstance(t, ast.Name) and \
            t.id in ("Exception", "BaseException")

    t = handler.type
    if t is None:
        return True
    if isinstance(t, ast.Tuple):
        return any(broad(e) for e in t.elts)
    return broad(t)


__all__ = [
    "SESSION_NAMES",
    "calls_in",
    "call_method",
    "catches_broad",
    "dotted",
    "iter_functions",
    "name_used",
    "own_nodes",
    "receiver_tail",
]
