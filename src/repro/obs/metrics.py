"""Typed metrics registry — counters, gauges, log-bucketed histograms.

Zero-dependency and process-local.  Every instrument is get-or-create
by name through one :class:`Metrics` registry; a name is bound to
exactly one kind (asking for ``counter("x")`` after ``gauge("x")`` is a
programming error and raises).  The registry is what
``ServeEngine.stats()`` / ``BranchSession.stat(metrics=True)`` /
``benchmarks/run.py`` snapshot, and what the ad-hoc serving counters
(``cow_dispatches`` et al.) became views over.

Design points
-------------
* **Counters** only go up (``inc``).  **Gauges** are set to the latest
  value (``set``); pool-utilization style gauges are updated at the
  mutation site, never via closures over the owning object, so a
  retained ``Metrics`` never pins an engine or a device pool alive.
* **Histograms** use *fixed log-spaced buckets*: bucket ``i`` holds
  observations ``<= lo * growth**i``, plus one overflow bucket.  With
  the defaults (``lo=1.0, growth=2.0, n=40``) the range covers 1 unit
  to ~5.5e11 units — microsecond latencies from sub-µs to ~6 days.
  Percentiles are read from the cumulative bucket counts (upper-bound
  estimate), which is exact enough for p50/p90/p99 trend lines and
  costs O(n_buckets) only at snapshot time; ``observe`` is one bisect
  plus four scalar updates.
* ``snapshot()`` returns plain dicts (JSON-ready for BENCH_*.json);
  ``format()`` returns the procfs-style text block used by
  ``session.format_tree(metrics=True)`` and the ``--trace`` demos.
"""

from __future__ import annotations

import threading
from bisect import bisect_left
from typing import Dict, List, Union

Number = Union[int, float]


class Counter:
    """Monotonically increasing count (events, faults, dispatches)."""

    __slots__ = ("name", "_value")

    def __init__(self, name: str):
        self.name = name
        self._value = 0

    def inc(self, n: Number = 1) -> None:
        self._value += n

    @property
    def value(self) -> Number:
        return self._value


class Gauge:
    """Last-set value (pool levels, reservation ledgers, byte totals)."""

    __slots__ = ("name", "_value")

    def __init__(self, name: str):
        self.name = name
        self._value = 0

    def set(self, v: Number) -> None:
        self._value = v

    def add(self, d: Number) -> None:
        self._value += d

    @property
    def value(self) -> Number:
        return self._value


class Histogram:
    """Fixed log-spaced buckets: bucket ``i`` counts ``v <= lo*growth**i``.

    One extra overflow bucket catches everything beyond the last bound.
    ``percentile(p)`` returns the upper bound of the bucket containing
    the p-th observation (``max`` for the overflow bucket), from the
    cumulative counts — no per-observation storage.
    """

    __slots__ = ("name", "bounds", "counts", "count", "sum", "min", "max")

    def __init__(self, name: str, *, lo: float = 1.0, growth: float = 2.0,
                 buckets: int = 40):
        if lo <= 0 or growth <= 1.0 or buckets < 1:
            raise ValueError("need lo > 0, growth > 1, buckets >= 1")
        self.name = name
        self.bounds: List[float] = [lo * growth ** i for i in range(buckets)]
        self.counts: List[int] = [0] * (buckets + 1)   # +1 overflow
        self.count = 0
        self.sum = 0.0
        self.min = float("inf")
        self.max = 0.0

    def observe(self, v: Number) -> None:
        self.counts[bisect_left(self.bounds, v)] += 1
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    def percentile(self, p: float) -> float:
        """Upper-bound estimate of the p-th percentile, p in [0, 100]."""
        if self.count == 0:
            return 0.0
        target = max(1, -(-self.count * p // 100))   # ceil
        seen = 0
        for i, c in enumerate(self.counts):
            seen += c
            if seen >= target:
                if i >= len(self.bounds):
                    return self.max
                # bucket upper bound, capped at the true max so the
                # p50 <= p99 <= max ordering always holds
                return min(self.bounds[i], self.max)
        return self.max

    def snapshot(self) -> dict:
        snap = {
            "count": self.count,
            "sum": round(self.sum, 3),
            "min": 0.0 if self.count == 0 else round(self.min, 3),
            "max": round(self.max, 3),
            "p50": self.percentile(50),
            "p90": self.percentile(90),
            "p99": self.percentile(99),
        }
        nonzero = {f"{self.bounds[i]:g}" if i < len(self.bounds) else "inf": c
                   for i, c in enumerate(self.counts) if c}
        if nonzero:
            snap["buckets"] = nonzero
        return snap


class Metrics:
    """Get-or-create instrument registry with JSON + procfs export."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    def _get(self, table: dict, others: List[dict], name: str, make):
        with self._lock:
            inst = table.get(name)
            if inst is None:
                if any(name in o for o in others):
                    raise TypeError(
                        f"metric {name!r} already registered as a "
                        "different kind")
                inst = table[name] = make()
            return inst

    def counter(self, name: str) -> Counter:
        return self._get(self._counters, [self._gauges, self._histograms],
                         name, lambda: Counter(name))

    def gauge(self, name: str) -> Gauge:
        return self._get(self._gauges, [self._counters, self._histograms],
                         name, lambda: Gauge(name))

    def histogram(self, name: str, *, lo: float = 1.0, growth: float = 2.0,
                  buckets: int = 40) -> Histogram:
        return self._get(
            self._histograms, [self._counters, self._gauges], name,
            lambda: Histogram(name, lo=lo, growth=growth, buckets=buckets))

    # ------------------------------------------------------------------
    # merge / export
    # ------------------------------------------------------------------
    def absorb(self, other: "Metrics") -> None:
        """Fold another registry into this one (cross-hub aggregation).

        Counters and histograms are additive; gauges take the other's
        value (last-writer-wins — per-pool levels do not sum
        meaningfully across engines, so ``merged_snapshot`` documents
        gauges as per-hub latest).
        """
        with other._lock:
            counters = list(other._counters.values())
            gauges = list(other._gauges.values())
            histograms = list(other._histograms.values())
        for c in counters:
            self.counter(c.name).inc(c.value)
        for g in gauges:
            self.gauge(g.name).set(g.value)
        for h in histograms:
            mine = self.histogram(h.name)
            if mine.bounds != h.bounds:      # geometry mismatch: refit
                for i, c in enumerate(h.counts):
                    if c:
                        v = h.bounds[i] if i < len(h.bounds) else h.max
                        for _ in range(c):
                            mine.observe(v)
                continue
            for i, c in enumerate(h.counts):
                mine.counts[i] += c
            mine.count += h.count
            mine.sum += h.sum
            if h.count:
                mine.min = min(mine.min, h.min)
                mine.max = max(mine.max, h.max)

    def snapshot(self) -> dict:
        """JSON-ready dict: the metrics block of ``BENCH_*.json``."""
        with self._lock:
            return {
                "counters": {n: c.value
                             for n, c in sorted(self._counters.items())},
                "gauges": {n: g.value
                           for n, g in sorted(self._gauges.items())},
                "histograms": {n: h.snapshot()
                               for n, h in sorted(self._histograms.items())},
            }

    def format(self) -> str:
        """Procfs-style text block (one instrument per line)."""
        snap = self.snapshot()
        lines = []
        for n, v in snap["counters"].items():
            lines.append(f"counter {n} {v}")
        for n, v in snap["gauges"].items():
            lines.append(f"gauge   {n} {v:g}" if isinstance(v, float)
                         else f"gauge   {n} {v}")
        for n, h in snap["histograms"].items():
            lines.append(
                f"hist    {n} count={h['count']} sum={h['sum']:g} "
                f"p50={h['p50']:g} p90={h['p90']:g} p99={h['p99']:g} "
                f"max={h['max']:g}")
        return "\n".join(lines)


__all__ = ["Counter", "Gauge", "Histogram", "Metrics"]
