"""repro.obs — zero-dependency tracing + metrics for the branch stack.

One :class:`Observability` hub bundles a :class:`~repro.obs.Metrics`
registry and a :class:`~repro.obs.Tracer`.  Every instrumented object
(`ServeEngine`, `KVBranchManager`, `BranchFS`) creates its **own** hub
by default and shares it downward (engine → KV manager → branch tree
tracer), so tests and concurrent engines never see each other's
counters; pass ``obs=`` to share a hub across layers explicitly, or
``Observability(trace=True)`` to turn span recording on (disabled
tracing is one predicted branch per site).

Process-wide aggregation (``benchmarks/run.py``'s metrics block) goes
through :func:`merged_snapshot`: live hubs are tracked with weak
references — the registry never extends an engine's lifetime — and a
dying hub's final counters are folded into a retired-hub accumulator
via ``weakref.finalize``, so short-lived benchmark engines still show
up in the merged view.  Counters and histograms merge additively;
gauges are last-writer-wins (pool levels don't sum across engines).
"""

from __future__ import annotations

import weakref

from repro.obs.metrics import Counter, Gauge, Histogram, Metrics
from repro.obs.tracer import ENGINE_TRACK, NULL_TRACER, Span, Tracer

_LIVE_HUBS: "weakref.WeakSet" = weakref.WeakSet()
_RETIRED = Metrics()


class Observability:
    """Metrics registry + tracer, shared down one engine/manager stack."""

    def __init__(self, *, trace: bool = False):
        self.metrics = Metrics()
        self.tracer = Tracer(enabled=trace)
        _LIVE_HUBS.add(self)
        weakref.finalize(self, _RETIRED.absorb, self.metrics)


def merged_snapshot() -> dict:
    """Snapshot of every hub this process ever created (live + retired)."""
    acc = Metrics()
    acc.absorb(_RETIRED)
    for hub in list(_LIVE_HUBS):
        acc.absorb(hub.metrics)
    return acc.snapshot()


__all__ = [
    "ENGINE_TRACK",
    "NULL_TRACER",
    "Counter",
    "Gauge",
    "Histogram",
    "Metrics",
    "Observability",
    "Span",
    "Tracer",
    "merged_snapshot",
]
