"""Tracer — monotonic-clock spans + instant events, Chrome-trace export.

The span model mirrors the branch tree: every branch gets one **track**
(trace ``tid`` = branch id) carrying one long-lived ``explore`` span
from fork to resolution, and the resolution kind is the span's
``status`` (``committed`` / ``aborted`` / ``invalidated``).  Tracks are
grouped into a **process** per exploration (trace ``pid`` = the root
branch id of the subtree, propagated at fork), so a best-of-N run
renders in Perfetto as one process with N+1 rows and a visible
first-commit-wins cascade.  Engine-wide telemetry (decode steps) lands
on the reserved :data:`ENGINE_TRACK`.

Overhead discipline: the hot-path guard is ONE branch — every recording
method starts with ``if not self.enabled: return`` and allocates
nothing in the disabled case (tests probe this with a counting clock).
The :data:`NULL_TRACER` singleton is what instrumented objects hold
when no tracer was supplied, so instrumentation sites never need a
None check.

Re-entrant close guard: :meth:`end_span` *pops*; if a track has no open
span it returns ``False`` and records nothing.  Lifecycle code uses the
return value to fire resolution instants ("commit", "invalidated")
exactly once per branch, even when a scheduler purge, a lazy -ESTALE
discovery, and an abort-after-ESTALE all race to close the same span.
"""

from __future__ import annotations

import json
import threading
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Dict, List, Optional

#: reserved track for engine-wide events (decode steps); branch ids are >= 0
ENGINE_TRACK = -1


@dataclass
class Span:
    track: int                     # trace tid (branch id, or ENGINE_TRACK)
    name: str
    start_ns: int
    group: int = 0                 # trace pid (exploration root branch id)
    parent: Optional[int] = None   # parent *track* (branch lineage)
    end_ns: Optional[int] = None
    status: str = "open"
    args: Dict[str, Any] = field(default_factory=dict)

    @property
    def duration_ns(self) -> int:
        end = self.end_ns if self.end_ns is not None else self.start_ns
        return end - self.start_ns


@dataclass
class Instant:
    track: int
    name: str
    ts_ns: int
    group: int = 0
    args: Dict[str, Any] = field(default_factory=dict)


class Tracer:
    """Per-track span stacks + instant events on one monotonic clock."""

    def __init__(self, enabled: bool = False, *, clock=time.perf_counter_ns):
        self.enabled = enabled
        self._clock = clock
        self._lock = threading.Lock()
        self._t0 = clock() if enabled else 0
        self._open: Dict[int, List[Span]] = {}
        self._spans: List[Span] = []
        self._instants: List[Instant] = []
        self._group: Dict[int, int] = {}    # track -> pid it belongs to

    # ------------------------------------------------------------------
    # recording (hot path: one branch when disabled)
    # ------------------------------------------------------------------
    def begin_span(self, track: int, name: str, *,
                   parent: Optional[int] = None,
                   group: Optional[int] = None, **args) -> Optional[Span]:
        if not self.enabled:
            return None
        with self._lock:
            if group is None:
                # inherit the exploration process from the parent track;
                # a parentless track roots a new process
                group = self._group.get(parent, track if parent is None
                                        else parent)
            span = Span(track=track, name=name, start_ns=self._clock(),
                        group=group, parent=parent, args=args)
            self._open.setdefault(track, []).append(span)
            self._group[track] = group
            return span

    def end_span(self, track: int, status: str = "ok", **args) -> bool:
        """Close the innermost open span on ``track``.

        Returns ``False`` (recording nothing) when no span is open —
        the re-entrancy guard lifecycle code keys one-shot resolution
        events off.
        """
        if not self.enabled:
            return False
        with self._lock:
            stack = self._open.get(track)
            if not stack:
                return False
            span = stack.pop()
            span.end_ns = self._clock()
            span.status = status
            if args:
                span.args.update(args)
            self._spans.append(span)
            return True

    def instant(self, track: int, name: str, **args) -> None:
        if not self.enabled:
            return
        with self._lock:
            self._instants.append(Instant(
                track=track, name=name, ts_ns=self._clock(),
                group=self._group.get(track, track), args=args))

    # ------------------------------------------------------------------
    # introspection
    # ------------------------------------------------------------------
    def group_of(self, track: int, default: Optional[int] = None):
        return self._group.get(track, default)

    def has_open(self, track: int) -> bool:
        with self._lock:
            return bool(self._open.get(track))

    @property
    def spans(self) -> List[Span]:
        """Closed spans, in close order."""
        with self._lock:
            return list(self._spans)

    @property
    def open_spans(self) -> List[Span]:
        with self._lock:
            return [s for stack in self._open.values() for s in stack]

    @property
    def instants(self) -> List[Instant]:
        with self._lock:
            return list(self._instants)

    def lineage(self) -> Dict[int, Optional[int]]:
        """track -> parent track, over every span ever recorded."""
        with self._lock:
            out: Dict[int, Optional[int]] = {}
            for s in self._spans:
                out.setdefault(s.track, s.parent)
            for stack in self._open.values():
                for s in stack:
                    out.setdefault(s.track, s.parent)
            return out

    # ------------------------------------------------------------------
    # Chrome/Perfetto export
    # ------------------------------------------------------------------
    def export_chrome_trace(self, path=None) -> dict:
        """Write (and return) a Chrome Trace Event JSON object.

        ``pid`` = exploration group (root branch id), ``tid`` = branch
        id, so chrome://tracing / https://ui.perfetto.dev render one
        process per exploration with one row per branch.  Still-open
        spans are flushed with status ``open`` so a mid-run export is
        valid JSON.  Timestamps are microseconds relative to tracer
        construction.
        """
        with self._lock:
            spans = list(self._spans)
            for stack in self._open.values():
                for s in stack:
                    spans.append(Span(
                        track=s.track, name=s.name, start_ns=s.start_ns,
                        group=s.group, parent=s.parent,
                        end_ns=self._clock(), status="open",
                        args=dict(s.args)))
            instants = list(self._instants)
            t0 = self._t0

        def us(ns: int) -> float:
            return round((ns - t0) / 1000.0, 3)

        events: List[dict] = []
        pids = sorted({s.group for s in spans}
                      | {i.group for i in instants})
        tracks = sorted({(s.group, s.track) for s in spans}
                        | {(i.group, i.track) for i in instants})
        for pid in pids:
            events.append({"ph": "M", "name": "process_name", "pid": pid,
                           "args": {"name": "engine" if pid == ENGINE_TRACK
                                    else f"exploration {pid}"}})
        for pid, tid in tracks:
            events.append({"ph": "M", "name": "thread_name", "pid": pid,
                           "tid": tid,
                           "args": {"name": "engine" if tid == ENGINE_TRACK
                                    else f"branch {tid}"}})
        for s in spans:
            args = {"status": s.status, **s.args}
            if s.parent is not None:
                args["parent"] = s.parent
            events.append({
                "ph": "X", "cat": "branch", "name": s.name,
                "pid": s.group, "tid": s.track,
                "ts": us(s.start_ns),
                "dur": round(s.duration_ns / 1000.0, 3),
                "args": args,
            })
        for i in instants:
            events.append({
                "ph": "i", "s": "t", "cat": "branch", "name": i.name,
                "pid": i.group, "tid": i.track, "ts": us(i.ts_ns),
                "args": i.args,
            })
        trace = {"traceEvents": events, "displayTimeUnit": "ms"}
        if path is not None:
            Path(path).write_text(json.dumps(trace, indent=1))
        return trace


#: shared disabled tracer — what instrumented objects hold by default,
#: so every site is `tracer.enabled`-guarded rather than None-checked.
NULL_TRACER = Tracer(enabled=False)


__all__ = ["ENGINE_TRACK", "Instant", "NULL_TRACER", "Span", "Tracer"]
