"""``repro.api`` — the one syscall-faithful public surface.

The paper's central proposal is a *single* ``branch()`` syscall with
flag-controlled semantics, kernel-enforced sibling isolation, and
first-commit-wins coordination.  This package is that surface for the
serving stack:

* :class:`BranchSession` — handle table (generation-counted, ``-EBADF``
  on stale use), ``open``/``branch``/``commit``/``abort``/``wait``/
  ``poll``/``stat``/``tree``/``finish``/``close`` verbs, vectorized
  ``branch(parent, n=k)`` (one ledger transaction, one fused CoW
  dispatch), atomic multi-domain composition.
* :mod:`flags <repro.api.flags>` — the ``branch()`` flags word:
  ``BR_ISOLATE | BR_HOLD | BR_NESTED | BR_SPECULATIVE | BR_NONBLOCK``.
* :mod:`events <repro.api.events>` — unified eventing: ``EV_*`` bits
  and the epoll-like :class:`Waiter`.
* :class:`Errno` / :class:`BranchError` — one errno discipline shared
  with every lower layer (re-exported from :mod:`repro.core.errors`).

Everything else (``BranchRuntime``'s opcode dispatcher, raw
``Scheduler`` verbs, ``explore_ctx.BranchContext``) is either a thin
deprecated shim over this package or sugar built on top of it — see
DESIGN.md §10 for the syscall ↔ API mapping and the migration table.
"""

from repro.core.errors import (
    AdmissionDenied,
    BadHandleError,
    BranchError,
    BranchStateError,
    Errno,
    FrozenOriginError,
    PoolExhausted,
    StaleBranchError,
)

from repro.api.events import (
    EV_ADMITTED,
    EV_ANY,
    EV_COMMITTED,
    EV_FINISHED,
    EV_INVALIDATED,
    EV_PRODUCED,
    EV_RESOLVED,
    Waiter,
    event_names,
)
from repro.api.flags import (
    BR_ALL,
    BR_HOLD,
    BR_ISOLATE,
    BR_NESTED,
    BR_NONBLOCK,
    BR_SPECULATIVE,
    flag_names,
)
from repro.api.session import BranchSession

__all__ = [
    # the session (the branch() syscall surface)
    "BranchSession",
    # flags word
    "BR_ALL",
    "BR_HOLD",
    "BR_ISOLATE",
    "BR_NESTED",
    "BR_NONBLOCK",
    "BR_SPECULATIVE",
    "flag_names",
    # unified eventing
    "EV_ADMITTED",
    "EV_ANY",
    "EV_COMMITTED",
    "EV_FINISHED",
    "EV_INVALIDATED",
    "EV_PRODUCED",
    "EV_RESOLVED",
    "Waiter",
    "event_names",
    # errno discipline
    "AdmissionDenied",
    "BadHandleError",
    "BranchError",
    "BranchStateError",
    "Errno",
    "FrozenOriginError",
    "PoolExhausted",
    "StaleBranchError",
]
