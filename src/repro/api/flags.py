"""The ``branch()`` flags word (paper Listing 1, realized for serving).

One integer, OR-able, controlling the semantics of a single
:meth:`BranchSession.branch <repro.api.BranchSession.branch>` call —
exactly the shape of ``clone(2)``'s flags argument:

=================  ======================================================
flag               semantics
=================  ======================================================
``BR_ISOLATE``     kernel-enforced sibling isolation: the handle table
                   refuses to resolve a sibling's handles from an
                   isolated branch (``siblings()`` raises ``-EPERM``)
``BR_HOLD``        children are created *parked*: they keep their page
                   reservations but never decode until ``resume()`` —
                   the exploration driver's pacing primitive
``BR_NESTED``      required to fork a branch that is itself a branch
                   (fork-of-fork, Tree-of-Thoughts); forking a non-root
                   without it is ``-EINVAL``
``BR_SPECULATIVE`` marks the children as speculative drafts: they may
                   be ``truncate()``d to a verified prefix before
                   commit; truncating a non-speculative branch is
                   ``-EPERM``
``BR_NONBLOCK``    page-budget denial returns ``-EAGAIN`` immediately
                   instead of blocking (stepping the scheduler) until
                   other work frees pages
``BR_TIERED``      *stat-only*: reported by ``stat()`` for a branch
                   whose KV is checkpointed out of the device pool
                   (``session.checkpoint``); never accepted by
                   ``branch()`` — tiering is a runtime state, not a
                   creation mode
=================  ======================================================

These are session-level flags and intentionally a *different* namespace
from the low-level :mod:`repro.core.runtime_api` domain flags
(``BR_STATE``/``BR_KV``): the session always forks every attached
domain atomically, so the caller only ever chooses *behaviour*, never
which domains stay consistent.
"""

from __future__ import annotations

BR_ISOLATE = 1 << 0
BR_HOLD = 1 << 1
BR_NESTED = 1 << 2
BR_SPECULATIVE = 1 << 3
BR_NONBLOCK = 1 << 4
BR_TIERED = 1 << 5

_NAMES = {
    BR_ISOLATE: "BR_ISOLATE",
    BR_HOLD: "BR_HOLD",
    BR_NESTED: "BR_NESTED",
    BR_SPECULATIVE: "BR_SPECULATIVE",
    BR_NONBLOCK: "BR_NONBLOCK",
    BR_TIERED: "BR_TIERED",
}

# BR_TIERED is stat-only, so it is deliberately NOT part of BR_ALL (the
# mask of flags branch() accepts).
BR_ALL = BR_ISOLATE | BR_HOLD | BR_NESTED | BR_SPECULATIVE | BR_NONBLOCK


def flag_names(flags: int) -> list:
    """Symbolic names of every set flag (procfs-style ``stat()`` output)."""
    return [name for bit, name in _NAMES.items() if flags & bit]


__all__ = [
    "BR_ALL",
    "BR_HOLD",
    "BR_ISOLATE",
    "BR_NESTED",
    "BR_NONBLOCK",
    "BR_SPECULATIVE",
    "BR_TIERED",
    "flag_names",
]
