"""Unified eventing — an epoll over branch handles.

Every earlier surface had its own blocking model: ``Scheduler.wait``
spun on one request, the exploration driver hand-rolled four wait
classes, and ``BranchRuntime`` had none at all.  This module is the one
replacement: a handle becomes *ready* when the lifecycle kernel, the
scheduler, or the session resolves it, and a :class:`Waiter`
multiplexes any number of handles the way ``epoll_wait(2)`` multiplexes
fds — register interest, poll a ready set, or block (step the
scheduler) until something fires.

Event bits (OR-able, edge-accumulated per handle):

==================  =====================================================
``EV_ADMITTED``     the root request left the FIFO: it has a sequence,
                    pages reserved, and a bound state-domain subtree
``EV_COMMITTED``    this branch won its exclusive group's
                    first-commit-wins race
``EV_INVALIDATED``  this branch lost — a sibling committed (``-ESTALE``),
                    an ancestor aborted, or it was aborted/evicted
``EV_FINISHED``     the root request can produce no more tokens; its
                    result is claimable via ``result()``
``EV_PRODUCED``     a :class:`Waiter` produced-target was met (only
                    reported when a target was registered)
==================  =====================================================
"""

from __future__ import annotations

from typing import TYPE_CHECKING, Dict, Iterable, Optional, Tuple

if TYPE_CHECKING:   # pragma: no cover - import cycle guard
    from repro.api.session import BranchSession

EV_ADMITTED = 1 << 0
EV_COMMITTED = 1 << 1
EV_INVALIDATED = 1 << 2
EV_FINISHED = 1 << 3
EV_PRODUCED = 1 << 4

#: the branch resolved one way or the other
EV_RESOLVED = EV_COMMITTED | EV_INVALIDATED
EV_ANY = EV_ADMITTED | EV_COMMITTED | EV_INVALIDATED | EV_FINISHED

_NAMES = {
    EV_ADMITTED: "EV_ADMITTED",
    EV_COMMITTED: "EV_COMMITTED",
    EV_INVALIDATED: "EV_INVALIDATED",
    EV_FINISHED: "EV_FINISHED",
    EV_PRODUCED: "EV_PRODUCED",
}


def event_names(events: int) -> list:
    """Symbolic names of every set event bit."""
    return [name for bit, name in _NAMES.items() if events & bit]


class Waiter:
    """Readiness multiplexer over session handles (the epoll analogue).

    ``add`` registers interest in a handle — an event mask, optionally a
    *produced target* (ready once the branch has generated that many
    tokens past its fork point, or can never reach it because its
    request budget ran out or it resolved).  ``poll`` returns the ready
    map without side effects; ``wait`` steps the session's scheduler
    until the ready set is non-empty (or every registered handle is
    ready, with ``require_all``), so decode work from everything else
    registered on the same engine keeps flowing while one caller blocks.

    A handle closed underneath the waiter (its exploration finished and
    recycled the slot) reports ``EV_INVALIDATED`` rather than raising —
    exactly how epoll reports ``EPOLLHUP`` instead of failing the wait.
    """

    def __init__(self, session: "BranchSession"):
        self.session = session
        self._interest: Dict[int, Tuple[int, Optional[int]]] = {}

    # ------------------------------------------------------------------
    def add(self, hd: int, events: int = EV_ANY, *,
            produced: Optional[int] = None) -> "Waiter":
        """Register interest; returns self so registrations chain."""
        self._interest[hd] = (events, produced)
        return self

    def remove(self, hd: int) -> None:
        self._interest.pop(hd, None)

    def handles(self) -> Iterable[int]:
        return tuple(self._interest)

    # ------------------------------------------------------------------
    def poll(self) -> Dict[int, int]:
        """The ready map ``{handle: events}`` right now (non-blocking)."""
        from repro.core.errors import BadHandleError

        ready: Dict[int, int] = {}
        for hd, (mask, target) in self._interest.items():
            try:
                got = self.session.events(hd) & (mask | EV_RESOLVED)
                if target is not None and \
                        self.session.decode_target_met(hd, target):
                    got |= EV_PRODUCED
            except BadHandleError:
                got = EV_INVALIDATED   # slot recycled: the branch is gone
            if got:
                ready[hd] = got
        return ready

    def wait(self, timeout_steps: int = 1000, *, require_all: bool = False,
             **decode_kw) -> Dict[int, int]:
        """Block (stepping the scheduler) until the ready set is usable.

        Returns the ready map — possibly empty if ``timeout_steps``
        scheduler rounds elapse first, mirroring ``epoll_wait``'s
        0-return on timeout rather than raising.

        ``session.close()`` wakes every blocked waiter: a closed
        session cannot make further progress, so the wait returns the
        ready-set-so-far immediately instead of stepping a drained
        scheduler until the timeout — the unblock path a serving front
        door's graceful shutdown relies on.
        """
        for _ in range(max(timeout_steps, 1)):
            ready = self.poll()
            if ready and (not require_all
                          or len(ready) == len(self._interest)):
                return ready
            if self.session.closed:
                return ready   # woken by close(): report what fired
            self.session.step(**decode_kw)
        return self.poll()


__all__ = [
    "EV_ADMITTED",
    "EV_ANY",
    "EV_COMMITTED",
    "EV_FINISHED",
    "EV_INVALIDATED",
    "EV_PRODUCED",
    "EV_RESOLVED",
    "Waiter",
    "event_names",
]
