"""BranchSession — the syscall-faithful public surface of branchx.

The paper proposes *one* ``branch()`` syscall; after PR 1/PR 2 this
repro had four public entry surfaces (``BranchRuntime.__call__`` opcode
dispatch, raw ``Scheduler`` verbs, ``explore_ctx.BranchContext`` sugar,
and ``ServeEngine`` itself), each with its own error convention and
blocking model.  ``BranchSession`` replaces all of them:

* **One verb set** — ``open`` (admit a request), ``branch`` (vectorized
  fork with a flags word), ``commit`` / ``abort``, ``wait`` / ``poll``
  (unified eventing), ``stat`` / ``tree`` (procfs-style introspection),
  ``finish`` / ``result`` (retirement), ``close``.
* **A real handle table** — handles are fd-like ints packing a table
  index with a **generation counter**; a handle kept across ``close``
  (slot reuse bumps the generation) fails with ``-EBADF``
  (:class:`~repro.core.errors.BadHandleError`) instead of silently
  addressing the slot's new occupant.
* **One errno discipline** — every failure raises a
  :class:`~repro.core.errors.BranchError` carrying a code from the
  shared :class:`~repro.core.errors.Errno` enum; no ``None`` returns,
  no ad-hoc exception vocabularies.
* **Vectorized fork** — ``branch(parent, n=k)`` admits all ``k``
  siblings under one reservation-ledger transaction and hoists their
  shared-tail CoW into a single fused ``_copy_pages`` device dispatch
  (``KVBranchManager.fork_batch``); ``k`` sequential forks pay ``k``
  dispatches and ``k`` ledger transactions for the same state.
* **Atomic multi-domain composition** — a session constructed with a
  ``store`` forks/commits the host pytree domain and the device KV
  domain through :class:`~repro.core.runtime_api.BranchRuntime`, so no
  call ever half-creates a branch set.

Minimal usage (the paper's Listing 2, serving edition)::

    session = BranchSession(engine)
    root = session.open(prompt, max_new_tokens=16)
    kids = session.branch(root, n=4)          # one txn, one CoW dispatch
    session.wait(kids, produced=8)            # epoll-style readiness
    best = max(kids, key=score)
    session.commit(best)                      # losers -ESTALE, pages freed
    print(session.wait([root], events=EV_FINISHED) and session.result(root))
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core.branch import BranchContext as StateContext
from repro.core.branch import root_context
from repro.core.errors import (
    AdmissionDenied,
    BadHandleError,
    BranchError,
    BranchStateError,
    Errno,
    StaleBranchError,
)
from repro.core.lifecycle import BranchStatus
from repro.core.runtime_api import BR_KV, BR_STATE, BranchHandle, BranchRuntime
from repro.core.runtime_api import BR_ISOLATE as RT_ISOLATE
from repro.core.store import BranchStore
from repro.runtime.scheduler import Scheduler, SchedulerConfig
from repro.runtime.serve_loop import ServeEngine

from repro.api.events import (
    EV_ADMITTED,
    EV_COMMITTED,
    EV_FINISHED,
    EV_INVALIDATED,
    EV_ANY,
    Waiter,
    event_names,
)
from repro.api.flags import (
    BR_HOLD,
    BR_ISOLATE,
    BR_NESTED,
    BR_NONBLOCK,
    BR_SPECULATIVE,
    BR_TIERED,
    flag_names,
)

# handle = (slot index << _GEN_BITS) | generation.  16 generation bits
# mean a slot must be recycled 65536 times before a stale handle could
# collide — and collision needs the *same* slot too.
_GEN_BITS = 16
_GEN_MASK = (1 << _GEN_BITS) - 1


@dataclass
class _Entry:
    """One handle-table slot: the session's view of a branch."""

    hd: int
    gen: int
    req_id: Optional[int]
    root_hd: int
    parent_hd: Optional[int]
    flags: int
    depth: int = 0
    seq: Optional[int] = None          # None until the root is admitted
    group: Tuple[int, ...] = ()
    state: Optional[StateContext] = None
    rt_handle: Optional[BranchHandle] = None
    fork_len: int = 0
    events: int = 0                    # edge-accumulated event bits
    resolved: Optional[str] = None     # "committed" | "aborted" | "stale"
    result: Optional[List[int]] = None
    result_claimed: bool = False


class BranchSession:
    """The one public entry surface: handles, flags, errno, events."""

    def __init__(self, engine: Any, *, store: Optional[BranchStore] = None,
                 max_batch: int = 8, seed: int = 0):
        if isinstance(engine, Scheduler):
            self.sched = engine
        elif isinstance(engine, ServeEngine):
            self.sched = Scheduler(
                engine, SchedulerConfig(max_batch=max_batch, seed=seed))
        else:
            raise BranchError(
                f"BranchSession needs a ServeEngine or Scheduler, got "
                f"{type(engine).__name__}", errno=Errno.EINVAL)
        self.engine = self.sched.engine
        # the engine stack's observability hub (metrics registry +
        # tracer); build the engine with Observability(trace=True) to
        # record spans, then session.trace(path) exports the timeline
        self.obs = self.engine.obs
        self.store = store
        # Composite sessions fork the store domain and the KV domain
        # atomically; the KV fork goes through scheduler admission with
        # eager fused CoW — the vectorized-fork hot path.
        self.runtime: Optional[BranchRuntime] = None
        self._state_root: Optional[StateContext] = None
        if store is not None:
            self.runtime = BranchRuntime(
                store, self.engine.kv,
                kv_fork=lambda seq, n: self.sched.fork(seq, n,
                                                       eager_cow=True))
            self._state_root = root_context(store)
        self._slots: List[Optional[_Entry]] = []
        self._gens: List[int] = []     # per-slot generation counters
        self._free: List[int] = []
        self._closed = False

    # ------------------------------------------------------------------
    # handle table
    # ------------------------------------------------------------------
    def _new_entry(self, **kw: Any) -> _Entry:
        if self._free:
            idx = self._free.pop()
        else:
            idx = len(self._slots)
            self._slots.append(None)
            self._gens.append(1)       # gen starts at 1: handle 0 never valid
        gen = self._gens[idx]
        hd = (idx << _GEN_BITS) | gen
        entry = _Entry(hd=hd, gen=gen, **kw)
        self._slots[idx] = entry
        return entry

    def _entry(self, hd: int) -> _Entry:
        idx, gen = hd >> _GEN_BITS, hd & _GEN_MASK
        if not 0 <= idx < len(self._slots):
            raise BadHandleError(f"unknown branch handle {hd:#x} (-EBADF)")
        entry = self._slots[idx]
        if entry is None or entry.gen != gen:
            raise BadHandleError(
                f"stale branch handle {hd:#x}: slot {idx} is "
                f"{'closed' if entry is None else 'reused'} (-EBADF)")
        return entry

    def close(self, hd: Optional[int] = None) -> None:
        """Free a handle slot; any later use of ``hd`` is ``-EBADF``.

        Closing never resolves the branch (mirror of ``close(2)`` not
        killing the process an fd pointed at) — commit/abort/finish
        first if the branch should not stay live.

        ``close()`` with **no handle** closes the *session*: no new
        requests are accepted (``open`` raises ``-EINVAL``), ``step``
        becomes a no-op, and every blocked :class:`~repro.api.events.
        Waiter` (and therefore ``session.wait``) wakes on its next poll
        instead of stepping a drained scheduler forever — the wake/
        close path a serving front door needs for graceful shutdown.
        Idempotent; existing handles stay readable (``tokens``,
        ``stat``) so late readers can still collect results.
        """
        if hd is None:
            self._closed = True
            return
        entry = self._entry(hd)
        idx = hd >> _GEN_BITS
        self._slots[idx] = None
        self._gens[idx] = (entry.gen + 1) & _GEN_MASK or 1
        self._free.append(idx)

    @property
    def closed(self) -> bool:
        """Whether ``close()`` shut the session down (no more stepping)."""
        return self._closed

    def open_handles(self) -> List[int]:
        return [e.hd for e in self._slots if e is not None]

    # ------------------------------------------------------------------
    # request entry (open/adopt) and admission binding
    # ------------------------------------------------------------------
    def open(self, prompt: Sequence[int], max_new_tokens: int = 16,
             flags: int = 0) -> int:
        """Admit a new request; returns its *root* branch handle.

        Queues behind the scheduler's worst-case page-reservation FIFO;
        admission is asynchronous and observable as ``EV_ADMITTED``
        (``open`` itself never blocks).  A request that can *never* fit
        raises ``AdmissionDenied`` with ``Errno.ENOSPC`` up front.
        ``BR_HOLD`` parks the root in the admission transaction itself,
        so an exploration policy sees exactly the prompt — never a
        scheduler-paced token.
        """
        if self._closed:
            raise BranchStateError(
                "session is closed; no new requests (-EINVAL)")
        req_id = self.sched.submit(list(prompt), max_new_tokens,
                                   hold=bool(flags & BR_HOLD))
        entry = self._new_entry(req_id=req_id, root_hd=0,
                                parent_hd=None, flags=flags)
        entry.root_hd = entry.hd
        entry.group = (entry.hd,)
        self.sched.admit()             # admit eagerly if pages allow
        self._try_bind(entry)
        return entry.hd

    def adopt(self, req_id: int, flags: int = BR_HOLD) -> int:
        """Wrap an already-submitted scheduler request in a root handle
        (migration aid for code that still calls ``Scheduler.submit``)."""
        entry = self._new_entry(req_id=req_id, root_hd=0,
                                parent_hd=None, flags=flags)
        entry.root_hd = entry.hd
        entry.group = (entry.hd,)
        self._try_bind(entry)
        return entry.hd

    def _try_bind(self, entry: _Entry) -> bool:
        """Bind an admitted root to its sequence + state subtree."""
        if entry.seq is not None:
            return True
        try:
            seq = self.sched.seq_of(entry.req_id)
        except BranchError:
            return False               # still waiting in the FIFO
        entry.seq = seq
        entry.fork_len = len(self.engine.tokens(seq))
        if self._state_root is not None:
            # each request explores inside its own store subtree, so
            # concurrent requests never race each other's epoch CAS
            (entry.state,) = self._state_root.fork(1)
        entry.events |= EV_ADMITTED
        return True

    def admitted(self, hd: int) -> bool:
        return self._try_bind(self._entry(hd))

    def admit(self) -> List[int]:
        """Run one admission round (``wait``/``step`` do this for you)."""
        if self._closed:
            return []
        return self.sched.admit()

    # ------------------------------------------------------------------
    # branch(): the syscall
    # ------------------------------------------------------------------
    def branch(self, parent: int, flags: int = 0, n: int = 1, *,
               max_steps: int = 500) -> List[int]:
        """Fork ``n`` sibling branches of ``parent`` in one transaction.

        The paper's ``branch()``: every attached state domain (KV pages,
        token tails, and — in composite sessions — the pytree store)
        forks atomically or not at all, all ``n`` siblings are admitted
        under ONE reservation-ledger transaction, and their shared-tail
        CoW is fused into ONE ``_copy_pages`` device dispatch.  Flag
        semantics are documented in :mod:`repro.api.flags`; blocking
        behaviour: denial under page pressure retries (stepping the
        scheduler so other work can free pages) unless ``BR_NONBLOCK``
        is set, in which case ``AdmissionDenied`` (``-EAGAIN``) raises
        immediately.
        """
        entry = self._entry(parent)
        if n < 1:
            raise BranchError("branch() needs n >= 1", errno=Errno.EINVAL)
        self._refresh(entry)   # pick up admission / sibling invalidation
        if entry.resolved is not None:
            raise BranchStateError(
                f"handle {parent:#x} is resolved ({entry.resolved})")
        if entry.parent_hd is None and entry.req_id is not None \
                and self.sched.finished(entry.req_id):
            raise BranchStateError(
                f"handle {parent:#x}'s request already finished; "
                "nothing left to fork")
        if entry.seq is not None and not self.sched.is_tracked(entry.seq):
            raise BranchStateError(
                f"handle {parent:#x} is no longer schedulable "
                "(retired or evicted)")
        if entry.parent_hd is not None and not flags & BR_NESTED:
            raise BranchError(
                "forking a non-root branch requires BR_NESTED (-EINVAL)",
                errno=Errno.EINVAL)

        if flags & BR_NONBLOCK:
            made = self._fork_domains(entry, n, flags)
        else:
            made = self._fork_blocking(entry, n, flags, max_steps)

        kids: List[_Entry] = []
        try:
            for seq, state, rt_handle in made:
                kid = self._new_entry(
                    req_id=entry.req_id, root_hd=entry.root_hd,
                    parent_hd=parent, flags=flags, depth=entry.depth + 1)
                kids.append(kid)
                kid.seq = seq
                kid.state = state
                kid.rt_handle = rt_handle
                kid.fork_len = len(self.engine.tokens(seq))
                # the flags word is authoritative: children of a held
                # parent inherit the scheduler-level hold, so an unset
                # BR_HOLD must actively release them into the batch
                if flags & BR_HOLD:
                    self.sched.hold(seq)
                else:
                    self.sched.unhold(seq)
        except BranchError:
            self._unwind_vector(made, kids)
            raise
        group = tuple(k.hd for k in kids)
        for k in kids:
            k.group = group
        return list(group)

    def _unwind_vector(
        self, made: Sequence[Tuple[int, Any, Any]],
        kids: Sequence[_Entry],
    ) -> None:
        """Mid-vector failure: no half-created sibling group survives.

        ``branch(n=k)`` promises all-or-nothing; a failure while the
        kid entries were being wired (e.g. a scheduler verb racing an
        eviction) must not orphan the siblings already created — their
        slots would hold the table's last reference to live branches
        nobody can address again, and their page reservations would
        never free.  Abort every forked domain, then release every
        handle slot.  (The static face of this invariant is branchlint
        BL002; the dynamic face is tested in tests/test_api.py.)
        """
        for seq, _state, rt_handle in made:
            try:
                if rt_handle is not None:
                    self.runtime.abort(rt_handle)
                elif seq in self.engine.kv.tree and \
                        self.engine.kv.is_live(seq):
                    self.engine.abort(seq)
            except BranchError:
                pass        # already resolved/reaped by the failure
        for kid in kids:
            kid.resolved = "aborted"
            kid.events |= EV_INVALIDATED
            self.close(kid.hd)

    def _fork_domains(
        self, entry: _Entry, n: int, flags: int
    ) -> List[Tuple[int, Optional[StateContext], Optional[BranchHandle]]]:
        """One atomic multi-domain fork attempt (raises AdmissionDenied)."""
        if entry.seq is None and not self._try_bind(entry):
            # still in the admission FIFO: backpressure, not an error —
            # the blocking path keeps stepping until admission happens
            raise AdmissionDenied(
                f"handle {entry.hd:#x} is not admitted yet (-EAGAIN)")
        if self.runtime is not None and entry.state is not None:
            # check the cheap reservation ledger BEFORE forking the
            # store domain: a backpressure retry loop must not churn
            # (fork + unwind) store nodes every round
            if not self.sched.can_fork(entry.seq, n):
                raise AdmissionDenied(
                    f"branch({entry.seq}, n={n}) exceeds the page budget "
                    "(-EAGAIN)")
            rt_flags = BR_STATE | BR_KV
            if flags & BR_ISOLATE:
                rt_flags |= RT_ISOLATE
            handles = self.runtime.create(entry.state, n, flags=rt_flags,
                                          kv_seqs=[entry.seq])
            return [(h.kv_seqs[entry.seq], h.state, h) for h in handles]
        seqs = self.sched.fork(entry.seq, n, eager_cow=True)
        return [(s, None, None) for s in seqs]

    def _fork_blocking(self, entry: _Entry, n: int, flags: int,
                       max_steps: int) -> List[Tuple[int, Any, Any]]:
        """Retry the fork while scheduler progress can still free pages."""
        stalled = 0
        for _ in range(max(max_steps, 1)):
            try:
                return self._fork_domains(entry, n, flags)
            except AdmissionDenied as err:
                if err.errno is not Errno.EAGAIN:
                    raise           # permanent: no retry can help
            st = self.step()
            if st["decoded"] or st["admitted"] or st["retired"]:
                stalled = 0
            else:
                stalled += 1
                if stalled >= 2:
                    break           # deterministic: nothing will change
        raise AdmissionDenied(
            f"branch({entry.seq}, n={n}) cannot be admitted and no other "
            "work can free pages (-EAGAIN)")

    # ------------------------------------------------------------------
    # commit / abort
    # ------------------------------------------------------------------
    def commit(self, hd: int) -> Optional[int]:
        """First-commit-wins into the parent; returns the parent handle.

        The winner's content (pages, token tail, store delta) replaces
        the parent's atomically across every domain; every live sibling
        subtree is invalidated (observable as ``EV_INVALIDATED`` via
        ``poll``).  Losers of the race get ``StaleBranchError``
        (``-ESTALE``); committing a root is ``-EINVAL``.
        """
        entry = self._entry(hd)
        self._refresh(entry)
        if entry.resolved == "stale":
            raise StaleBranchError(
                f"handle {hd:#x} was invalidated by a sibling commit "
                "(-ESTALE)")
        if entry.resolved is not None:
            raise BranchStateError(f"handle {hd:#x} already resolved "
                                   f"({entry.resolved})")
        if entry.parent_hd is None:
            raise BranchStateError(
                "root branch cannot commit; finish() retires a request")
        try:
            if entry.rt_handle is not None:
                self.runtime.commit(entry.rt_handle)
            else:
                self.engine.commit(entry.seq)
        except StaleBranchError:
            entry.resolved = "stale"
            entry.events |= EV_INVALIDATED
            raise
        entry.resolved = "committed"
        entry.events |= EV_COMMITTED
        for sib_hd in entry.group:
            if sib_hd == hd:
                continue
            try:
                sib = self._entry(sib_hd)
            except BadHandleError:
                continue
            if sib.resolved is None:
                sib.resolved = "stale"
                sib.events |= EV_INVALIDATED
        return entry.parent_hd

    def abort(self, hd: int) -> None:
        """Discard this branch's subtree in every domain; siblings stay
        valid; a frozen origin with no other live children resumes."""
        entry = self._entry(hd)
        if entry.resolved is not None:
            return
        if entry.rt_handle is not None:
            self.runtime.abort(entry.rt_handle)
        elif entry.seq is not None and entry.seq in self.engine.kv.tree \
                and self.engine.kv.is_live(entry.seq):
            self.engine.abort(entry.seq)
        entry.resolved = "aborted"
        entry.events |= EV_INVALIDATED

    def truncate(self, hd: int, n_generated: int) -> None:
        """Keep only the first ``n_generated`` tokens generated on this
        branch — the speculative-decode verified-prefix primitive.
        Requires the branch to have been created ``BR_SPECULATIVE``
        (``-EPERM`` otherwise): only a declared draft may rewrite its
        own history before committing it.
        """
        entry = self._entry(hd)
        if not entry.flags & BR_SPECULATIVE:
            raise BranchError(
                f"handle {hd:#x} was not created BR_SPECULATIVE; "
                "truncation is reserved for declared drafts (-EPERM)",
                errno=Errno.EPERM)
        self.engine.truncate(entry.seq, entry.fork_len + n_generated)

    def verify(self, hd: int,
               drafts: Sequence[Sequence[int]]) -> List[List[int]]:
        """Score draft continuations of this branch in ONE fused device
        dispatch (the speculative-verify fast path).

        Each draft is k proposed next tokens; the returned row is the
        target's greedy token at every draft position (teacher-forced),
        so ``lcp(draft, row)`` is exactly what a sequential greedy
        verifier branch would have accepted — k decode dispatches
        collapsed into one, with no KV writes and no new branches.
        Works on a frozen fork origin (the usual caller: a policy whose
        drafts are live children of ``hd``).
        """
        entry = self._entry(hd)
        self._refresh(entry)
        if entry.resolved is not None:
            raise BranchStateError(
                f"handle {hd:#x} is resolved ({entry.resolved})")
        if entry.seq is None or not self.sched.is_tracked(entry.seq):
            raise BranchStateError(
                f"handle {hd:#x} is not schedulable; nothing to verify "
                "against")
        return self.sched.verify(entry.seq, drafts)

    # ------------------------------------------------------------------
    # eventing: poll / wait
    # ------------------------------------------------------------------
    def events(self, hd: int) -> int:
        """Current event mask of a handle (edge bits accumulate)."""
        entry = self._entry(hd)
        self._refresh(entry)
        return entry.events

    def _refresh(self, entry: _Entry) -> None:
        if entry.seq is None:
            self._try_bind(entry)
        if entry.parent_hd is None and entry.req_id is not None \
                and self.sched.finished(entry.req_id):
            if not entry.result_claimed:
                try:
                    entry.result = self.sched.result(entry.req_id)
                except BranchError:
                    entry.result = None   # evicted unfinished
                entry.result_claimed = True
            entry.events |= EV_FINISHED
        if entry.seq is not None and entry.resolved is None:
            tree = self.engine.kv.tree
            if entry.seq not in tree:
                if entry.parent_hd is not None:
                    # reaped underneath us: an ancestor resolved
                    entry.resolved = "stale"
                    entry.events |= EV_INVALIDATED
            else:
                status = tree.status(entry.seq)
                if status is BranchStatus.COMMITTED:
                    entry.resolved = "committed"
                    entry.events |= EV_COMMITTED
                elif status in (BranchStatus.STALE, BranchStatus.ABORTED):
                    entry.resolved = "stale"
                    entry.events |= EV_INVALIDATED

    def poll(self, hds: Optional[Sequence[int]] = None) -> Dict[int, int]:
        """Ready map ``{handle: events}`` over ``hds`` (default: every
        open handle); handles with no events are omitted, epoll-style."""
        out: Dict[int, int] = {}
        for hd in (self.open_handles() if hds is None else hds):
            ev = self.events(hd)
            if ev:
                out[hd] = ev
        return out

    def wait(self, hds: Sequence[int], *, events: int = EV_ANY,
             produced: Optional[int] = None, timeout_steps: int = 1000,
             require_all: bool = False, **decode_kw: Any) -> Dict[int, int]:
        """Block (stepping the scheduler) until a handle is ready.

        Sugar over :class:`~repro.api.events.Waiter` for the common
        one-shot shape; build a ``Waiter`` directly to mix per-handle
        masks and produced targets.
        """
        w = Waiter(self)
        for hd in hds:
            w.add(hd, events, produced=produced)
        return w.wait(timeout_steps, require_all=require_all, **decode_kw)

    def decode_target_met(self, hd: int, target: int) -> bool:
        """Whether a branch produced ``target`` tokens past its fork
        point — or can never reach it (resolved, evicted, or its
        request's decode budget ran out first)."""
        entry = self._entry(hd)
        if entry.seq is None or not self.sched.is_tracked(entry.seq):
            return True
        if not self.engine.kv.is_live(entry.seq):
            return True
        req = self.sched.request_of(entry.seq)
        if req is None:
            return True
        produced = self.sched.produced(entry.seq)
        return produced >= target or produced >= req.max_new_tokens

    # ------------------------------------------------------------------
    # pacing + content
    # ------------------------------------------------------------------
    def resume(self, hd: int, *, greedy: Optional[bool] = None,
               temperature: Optional[float] = None) -> None:
        """Unpark a held branch (optionally pinning its sampling row).

        Demote-before-deny is transparent here: a branch the scheduler
        checkpointed out under page pressure is restored first (the
        token-identical promotion), so pacing callers never notice the
        round trip.  When the ledger cannot re-seat it *right now* the
        ``AdmissionDenied`` (``-EAGAIN``) surfaces to the caller as
        honest backpressure — retry after the pool drains.
        """
        entry = self._entry(hd)
        if entry.seq is None or not self.sched.is_tracked(entry.seq):
            return
        if greedy is not None or temperature is not None:
            self.sched.set_sampling(
                entry.seq,
                greedy=True if greedy is None else greedy,
                temperature=1.0 if temperature is None else temperature)
        if self.sched.is_checkpointed(entry.seq):
            self.sched.restore(entry.seq, unhold=True)
        else:
            self.sched.unhold(entry.seq)

    def pause(self, hd: int) -> None:
        """Park a branch: it keeps its reservations but stops decoding."""
        entry = self._entry(hd)
        if entry.seq is not None and self.sched.is_tracked(entry.seq):
            self.sched.hold(entry.seq)

    def checkpoint(self, hd: int) -> int:
        """Demote a branch's KV out of the device pool (session verb).

        Checkpoint implies :meth:`pause`: the branch is parked, its KV
        snapshot moves to the tier store (host RAM, spilling to disk),
        and its device pages return to the allocator — ``stat()``
        reports ``BR_TIERED`` until :meth:`restore`.  The branch stays
        live in the lifecycle tree; commit/abort/first-commit-wins
        semantics are untouched (a tiered loser's snapshot dies with its
        branch).  Returns the number of device pages freed.
        """
        entry = self._entry(hd)
        self._refresh(entry)
        if entry.seq is None or not self.sched.is_tracked(entry.seq):
            raise BranchStateError(
                f"handle {hd:#x} has no schedulable sequence to "
                "checkpoint")
        self.sched.hold(entry.seq)
        return self.sched.checkpoint(entry.seq)

    def restore(self, hd: int, *, resume: bool = False) -> None:
        """Promote a checkpointed branch back into device pages.

        Token-identical: the branch decodes exactly as if it had never
        left the device.  Admission discipline applies — ``-EAGAIN``
        (``AdmissionDenied``) when the ledger cannot re-seat the
        branch's reservation right now.  With ``resume`` the branch
        rejoins continuous batching immediately; otherwise it stays
        parked (the :meth:`pause` state checkpoint implied).
        """
        entry = self._entry(hd)
        self._refresh(entry)
        if entry.seq is None or not self.sched.is_tracked(entry.seq):
            raise BranchStateError(
                f"handle {hd:#x} has no schedulable sequence to restore")
        self.sched.restore(entry.seq, unhold=resume)

    def produced(self, hd: int) -> int:
        """Tokens generated past the owning request's prompt (0 if the
        branch no longer decodes)."""
        entry = self._entry(hd)
        if entry.seq is None or not self.sched.is_tracked(entry.seq):
            return 0
        return self.sched.produced(entry.seq)

    def tokens(self, hd: int) -> List[int]:
        """The branch's full token list (prompt + committed + own)."""
        entry = self._entry(hd)
        if entry.seq is not None and entry.seq in self.engine.token_domain:
            return self.engine.tokens(entry.seq)
        if entry.resolved == "committed" and entry.parent_hd is not None:
            return self.tokens(entry.parent_hd)
        if entry.parent_hd is None and entry.req_id is not None:
            if entry.result is not None:
                return list(entry.result)
            res = self.sched.peek_result(entry.req_id)
            if res is not None:
                return res
        raise BranchStateError(
            f"handle {hd:#x} has no token tail (invalidated and reaped)")

    def state_of(self, hd: int) -> Optional[StateContext]:
        """The branch's store-domain context (composite sessions)."""
        return self._entry(hd).state

    def seq_of(self, hd: int) -> Optional[int]:
        return self._entry(hd).seq

    def req_id_of(self, hd: int) -> Optional[int]:
        return self._entry(hd).req_id

    def tracked(self, hd: int) -> bool:
        """Whether the scheduler may still decode this branch."""
        entry = self._entry(hd)
        return entry.seq is not None and self.sched.is_tracked(entry.seq)

    def alive(self, hd: int) -> bool:
        entry = self._entry(hd)
        return entry.seq is not None and entry.seq in self.engine.kv.tree \
            and self.engine.kv.is_live(entry.seq)

    def status(self, hd: int) -> Optional[BranchStatus]:
        """Kernel status of the branch (None once reaped)."""
        entry = self._entry(hd)
        if entry.seq is None or entry.seq not in self.engine.kv.tree:
            return None
        return self.engine.kv.status(entry.seq)

    def siblings(self, hd: int) -> List[int]:
        """Every handle of this branch's exclusive commit group.

        The handle-table enforcement point of ``BR_ISOLATE``: an
        isolated branch cannot address its siblings — the one surface
        that exposes them refuses with ``-EPERM``.
        """
        entry = self._entry(hd)
        if entry.flags & BR_ISOLATE:
            raise BranchError(
                "BR_ISOLATE: sibling branch handles are not addressable "
                "(-EPERM)", errno=Errno.EPERM)
        return list(entry.group)

    # ------------------------------------------------------------------
    # stepping + retirement
    # ------------------------------------------------------------------
    @property
    def steps(self) -> int:
        return self.sched.steps

    @property
    def tp(self) -> int:
        """Tensor-parallel width of the underlying serving mesh (1 when
        single-device).  Handles, flags and errno semantics are
        identical either way — sharding is invisible above the engine."""
        return self.sched.tp

    def step(self, **decode_kw: Any) -> Dict[str, Any]:
        """One scheduling round (admission, batched decode, retirement).

        A closed session never steps: the call returns an idle record
        (``closed=True``) so retry loops observe zero progress and
        unwind instead of decoding against a shutting-down engine.
        """
        if self._closed:
            return {"admitted": 0, "batch": 0, "decoded": 0, "retired": 0,
                    "waiting": 0, "running": 0, "closed": True}
        return self.sched.step(**decode_kw)

    def finish(self, hd: int) -> Optional[List[int]]:
        """Retire the handle's request now and recycle its whole subtree.

        Force-retires the owning request (releasing pages, token tails
        and reservations across every domain), reaps the composite
        store subtree, closes **every** handle rooted at this request,
        and returns the final token list (``None`` if the request was
        evicted before finishing).  Idempotent: finishing a closed or
        already-finished handle returns ``None``.
        """
        try:
            entry = self._entry(hd)
        except BadHandleError:
            return None
        root_entry = entry
        if entry.root_hd != entry.hd:
            try:
                root_entry = self._entry(entry.root_hd)
            except BadHandleError:
                root_entry = entry
        if entry.req_id is not None:
            if not self.sched.finished(entry.req_id):
                self.sched.finish(entry.req_id)
            # the result record lives on the ROOT entry: refresh it so a
            # finish through a child handle still claims the one-shot
            # scheduler result instead of stranding it
            self._refresh(root_entry)
        tokens = root_entry.result
        if root_entry.state is not None and self.store is not None:
            state = root_entry.state
            try:
                if state.is_active:
                    state.abort()
            except BranchStateError:
                pass
            self.store.reap(state.branch_id)
            root_entry.state = None
        root_hd = entry.root_hd
        for idx, slot in enumerate(self._slots):
            if slot is not None and slot.root_hd == root_hd:
                self._slots[idx] = None
                self._gens[idx] = (slot.gen + 1) & _GEN_MASK or 1
                self._free.append(idx)
        return tokens

    def result(self, hd: int) -> Optional[List[int]]:
        """The finished request's final token list (claimed once from
        the scheduler, cached on the handle thereafter)."""
        entry = self._entry(hd)
        self._refresh(entry)
        return None if entry.result is None else list(entry.result)

    # ------------------------------------------------------------------
    # introspection: stat() / tree()
    # ------------------------------------------------------------------
    def stat(self, hd: Optional[int] = None, *,
             metrics: bool = False) -> Dict[str, Any]:
        """Procfs-style status (``/proc/<pid>/stat``).

        With a handle: that branch's view.  Without one
        (``session.stat(metrics=True)``): the whole-session ``tree()``
        view.  ``metrics=True`` attaches the obs-registry snapshot
        (counters/gauges/histograms) plus per-branch page footprints —
        the machine-readable face of ``format_tree(metrics=True)``.
        """
        if hd is None:
            out = self.tree()
        else:
            out = self._stat_one(hd)
        if metrics:
            out["metrics"] = self.obs.metrics.snapshot()
            out["footprints"] = self.engine.kv.footprints()
        return out

    def _stat_one(self, hd: int) -> Dict[str, Any]:
        entry = self._entry(hd)
        self._refresh(entry)
        status = self.status(hd)
        in_tree = entry.seq is not None and entry.seq in self.engine.kv.tree
        tiered = in_tree and self.engine.kv.is_tiered(entry.seq)
        return {
            "hd": entry.hd,
            "seq": entry.seq,
            "req_id": entry.req_id,
            "parent": entry.parent_hd,
            "depth": entry.depth,
            # BR_TIERED is a runtime state, not a creation flag: it
            # appears here while the branch is checkpointed out
            "flags": flag_names(entry.flags | (BR_TIERED if tiered else 0)),
            "events": event_names(entry.events),
            "status": status.value if status is not None else "reaped",
            "resolved": entry.resolved,
            "group_size": len(entry.group),
            "produced": self.produced(hd),
            "pages": (len(self.engine.kv.block_table(entry.seq))
                      if in_tree else 0),
            "reserved_pages": (self.sched.reserved_pages(entry.seq)
                               if entry.seq is not None else 0),
            "held": (entry.seq is not None
                     and self.sched.is_held(entry.seq)),
            "tiered": tiered,
        }

    def tree(self) -> Dict[str, Any]:
        """Procfs-style view of the whole session: the lifecycle forest,
        page-pool/ledger utilization, and handle-table occupancy."""
        st = self.sched.stats()
        pool_total = st["pages_total"]
        return {
            "branches": self.engine.kv.tree.snapshot(),
            "pool": {
                "pages_total": pool_total,
                "pages_free": st["pages_free"],
                "pages_shared": st["pages_shared"],
                "pages_reserved": st["pages_reserved"],
                "utilization": 1.0 - st["pages_free"] / max(pool_total, 1),
            },
            "scheduler": {
                "steps": st["steps"],
                "tokens_generated": st["tokens_generated"],
                "waiting": st["waiting"],
                "running": st["running"],
                "held": st["held"],
                "checkpointed": st.get("checkpointed", 0),
                "tp": st.get("tp", 1),
            },
            "handles": {
                "open": len(self.open_handles()),
                "table_size": len(self._slots),
            },
        }

    def trace(self, path) -> dict:
        """Export the session's Chrome/Perfetto timeline to ``path``.

        Only meaningful when the engine was built with
        ``Observability(trace=True)``; an untraced session writes a
        valid-but-empty trace.  Open the file at
        https://ui.perfetto.dev or chrome://tracing.
        """
        return self.obs.tracer.export_chrome_trace(path)

    def format_tree(self, metrics: bool = False) -> str:
        """Human-readable ``tree()`` (the ``cat /proc/branches`` view).

        ``metrics=True`` appends the obs registry as a procfs-style
        block — the ``--metrics``/``--trace`` one-screen summary.
        """
        view = self.tree()
        lines: List[str] = []

        def walk(node: Dict[str, Any], indent: int) -> None:
            lines.append("  " * indent +
                         f"seq {node['id']} [{node['status']}]"
                         f" group={node['group']} epoch={node['epoch']}")
            for child in node["children"]:
                walk(child, indent + 1)

        for root in view["branches"]:
            walk(root, 0)
        pool = view["pool"]
        lines.append(
            f"pool: {pool['pages_free']}/{pool['pages_total']} free, "
            f"{pool['pages_reserved']} reserved, "
            f"{pool['pages_shared']} shared "
            f"({pool['utilization']:.0%} used); "
            f"handles: {view['handles']['open']} open")
        if metrics:
            lines.append("metrics:")
            lines.extend("  " + ln
                         for ln in self.obs.metrics.format().splitlines())
        return "\n".join(lines)


__all__ = ["BranchSession"]
