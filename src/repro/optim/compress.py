"""Gradient compression for cross-pod reductions, with error feedback.

At 1000+ nodes the ``pod`` axis all-reduce is the collective-roofline
term that grows with cluster size (DESIGN §5).  Two standard compressors:

* **int8 per-tensor quantization** — 4× volume reduction on bf16/f32
  gradients; scale = max|g| per leaf.
* **top-k sparsification** — keep the k largest-|g| entries per leaf.

Both keep an **error-feedback** residual (Karimireddy et al.): the
compression error is added back into the next step's gradient, preserving
convergence.  ``compressed_gradients`` is dtype/shape-preserving so it
drops into the train step around the cross-pod ``psum``.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class ErrorFeedbackState(NamedTuple):
    residual: Any  # pytree like grads, fp32


def ef_init(grads_like: Any) -> ErrorFeedbackState:
    return ErrorFeedbackState(
        residual=jax.tree_util.tree_map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)
    )


# ---------------------------------------------------------------------------
# int8 quantization
# ---------------------------------------------------------------------------

def int8_compress(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x.astype(jnp.float32))) / 127.0 + 1e-30
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    return q, scale


def int8_decompress(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


# ---------------------------------------------------------------------------
# top-k sparsification
# ---------------------------------------------------------------------------

def topk_compress(x: jax.Array, frac: float = 0.01
                  ) -> Tuple[jax.Array, jax.Array]:
    """Returns (values, flat indices) of the k largest-|x| entries."""
    flat = x.astype(jnp.float32).reshape(-1)
    k = max(1, int(flat.shape[0] * frac))
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    return flat[idx], idx


def topk_decompress(vals: jax.Array, idx: jax.Array, shape
                    ) -> jax.Array:
    n = 1
    for d in shape:
        n *= d
    return jnp.zeros((n,), jnp.float32).at[idx].set(vals).reshape(shape)


# ---------------------------------------------------------------------------
# error-feedback wrapper around a (possibly collective) reduction
# ---------------------------------------------------------------------------

def compressed_gradients(
    grads: Any,
    ef: ErrorFeedbackState,
    *,
    method: str = "int8",
    topk_frac: float = 0.01,
) -> Tuple[Any, ErrorFeedbackState]:
    """Compress+decompress grads with error feedback.

    The returned gradients are what the *receiving* side reconstructs;
    the residual carries this step's quantization error into the next
    step.  In the distributed train step this wraps the cross-pod psum:
    each pod compresses its gradient contribution, the (4×-smaller)
    payload is reduced, and decompression happens before the optimizer.
    """

    def one(g, r):
        g32 = g.astype(jnp.float32) + r
        if method == "int8":
            q, scale = int8_compress(g32)
            recon = int8_decompress(q, scale)
        elif method == "topk":
            vals, idx = topk_compress(g32, topk_frac)
            recon = topk_decompress(vals, idx, g32.shape)
        elif method == "none":
            recon = g32
        else:
            raise ValueError(method)
        return recon.astype(g.dtype), (g32 - recon)

    flat = jax.tree_util.tree_map(one, grads, ef.residual)
    is_t = lambda x: isinstance(x, tuple)
    out = jax.tree_util.tree_map(lambda t: t[0], flat, is_leaf=is_t)
    res = jax.tree_util.tree_map(lambda t: t[1], flat, is_leaf=is_t)
    return out, ErrorFeedbackState(residual=res)


def compression_ratio(method: str, dtype=jnp.bfloat16,
                      topk_frac: float = 0.01) -> float:
    """Payload bytes ratio vs uncompressed (for the roofline model)."""
    bits = jnp.dtype(dtype).itemsize * 8
    if method == "int8":
        return 8.0 / bits
    if method == "topk":
        return topk_frac * (32 + 32) / bits
    return 1.0
