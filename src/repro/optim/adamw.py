"""AdamW with fp32 moments (params may be bf16 — moments are the master
precision, the standard large-model configuration)."""

from __future__ import annotations

from typing import Any, Callable, Union

import jax
import jax.numpy as jnp

from repro.optim.base import Optimizer

Schedule = Callable[[jax.Array], jax.Array]


def adamw(
    lr: Union[float, Schedule],
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: jnp.float32(lr))

    def init(params: Any) -> Any:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "mu": jax.tree_util.tree_map(zeros, params),
            "nu": jax.tree_util.tree_map(zeros, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(grads: Any, state: Any, params: Any):
        step = state["step"] + 1
        lr_t = lr_fn(step)
        b1c = 1.0 - b1 ** step.astype(jnp.float32)
        b2c = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(g, mu, nu, p):
            g = g.astype(jnp.float32)
            mu = b1 * mu + (1 - b1) * g
            nu = b2 * nu + (1 - b2) * jnp.square(g)
            mu_hat = mu / b1c
            nu_hat = nu / b2c
            u = -lr_t * (mu_hat / (jnp.sqrt(nu_hat) + eps)
                         + weight_decay * p.astype(jnp.float32))
            return u, mu, nu

        flat = jax.tree_util.tree_map(upd, grads, state["mu"], state["nu"],
                                      params)
        updates = jax.tree_util.tree_map(lambda t: t[0], flat,
                                         is_leaf=lambda x: isinstance(x, tuple))
        mu = jax.tree_util.tree_map(lambda t: t[1], flat,
                                    is_leaf=lambda x: isinstance(x, tuple))
        nu = jax.tree_util.tree_map(lambda t: t[2], flat,
                                    is_leaf=lambda x: isinstance(x, tuple))
        return updates, {"mu": mu, "nu": nu, "step": step}

    return Optimizer(init=init, update=update)
