"""Optimizer interface: (init, update) pairs over pytrees."""

from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    # update(grads, state, params) -> (updates, new_state)
    update: Callable[[Any, Any, Any], Any]


def apply_updates(params: Any, updates: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda p, u: (p + u.astype(p.dtype)).astype(p.dtype), params, updates
    )
