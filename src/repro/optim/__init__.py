"""Optimizers, schedules, clipping, gradient compression — from scratch
in pure JAX (no optax)."""

from repro.optim.adamw import adamw
from repro.optim.sgd import sgd_momentum
from repro.optim.schedules import constant, cosine_warmup, linear_warmup
from repro.optim.clip import clip_by_global_norm, global_norm
from repro.optim.base import Optimizer, apply_updates
from repro.optim.compress import (
    ErrorFeedbackState,
    compressed_gradients,
    int8_compress,
    int8_decompress,
    topk_compress,
)

__all__ = [
    "adamw", "sgd_momentum", "constant", "cosine_warmup", "linear_warmup",
    "clip_by_global_norm", "global_norm", "Optimizer", "apply_updates",
    "ErrorFeedbackState", "compressed_gradients", "int8_compress",
    "int8_decompress", "topk_compress",
]
