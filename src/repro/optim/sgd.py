"""SGD with (Nesterov) momentum."""

from __future__ import annotations

from typing import Any, Callable, Union

import jax
import jax.numpy as jnp

from repro.optim.base import Optimizer


def sgd_momentum(lr: Union[float, Callable], momentum: float = 0.9,
                 nesterov: bool = False) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: jnp.float32(lr))

    def init(params: Any) -> Any:
        return {
            "velocity": jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(grads: Any, state: Any, params: Any):
        step = state["step"] + 1
        lr_t = lr_fn(step)

        def upd(g, v):
            g = g.astype(jnp.float32)
            v = momentum * v + g
            d = g + momentum * v if nesterov else v
            return -lr_t * d, v

        flat = jax.tree_util.tree_map(upd, grads, state["velocity"])
        is_t = lambda x: isinstance(x, tuple)
        updates = jax.tree_util.tree_map(lambda t: t[0], flat, is_leaf=is_t)
        vel = jax.tree_util.tree_map(lambda t: t[1], flat, is_leaf=is_t)
        return updates, {"velocity": vel, "step": step}

    return Optimizer(init=init, update=update)
