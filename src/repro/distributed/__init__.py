"""Distribution substrate: mesh/axis conventions, sharding rules,
custom collectives (compression, overlap)."""

from repro.distributed.mesh import ParallelPlan, SINGLE_DEVICE
from repro.distributed.sharding import (
    batch_spec,
    param_shardings,
    shard_params,
    state_shardings,
)

__all__ = [
    "ParallelPlan", "SINGLE_DEVICE", "batch_spec", "param_shardings",
    "shard_params", "state_shardings",
]
