"""Distribution substrate: mesh/axis conventions, sharding rules,
custom collectives (compression, overlap)."""

from repro.distributed.compat import shard_map
from repro.distributed.mesh import (
    ParallelPlan,
    SINGLE_DEVICE,
    serving_mesh,
    serving_plan,
)
from repro.distributed.sharding import (
    batch_spec,
    kv_page_spec,
    param_shardings,
    serve_param_specs,
    shard_params,
    state_shardings,
)

__all__ = [
    "ParallelPlan", "SINGLE_DEVICE", "batch_spec", "kv_page_spec",
    "param_shardings", "serve_param_specs", "serving_mesh",
    "serving_plan", "shard_map", "shard_params", "state_shardings",
]
