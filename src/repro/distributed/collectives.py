"""Custom collectives: compressed cross-pod reduction and an explicit
ring all-reduce for overlap-scheduling experiments.

``compressed_psum_pod`` implements the cross-pod gradient reduction with
int8 quantization: each pod quantizes its contribution, the reduction
runs over the quantized payload, and scales travel alongside (tiny).  On
real hardware the int8 payload is what crosses the DCN/ICI links — the
4× collective-term saving is applied analytically in the roofline model
(``optim.compress.compression_ratio``) and the numerics here are exactly
what the cluster computes.

``ring_allreduce`` is a ppermute-based reduce-scatter + all-gather whose
per-hop structure XLA can overlap with compute — used by the §Perf
hillclimb to compare against the single fused all-reduce the partitioner
emits by default.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.distributed.compat import shard_map
from repro.optim.compress import int8_compress, int8_decompress


def psum_quantized(x: jax.Array, axis_name: str) -> jax.Array:
    """int8-quantized psum (call inside shard_map/pjit with the axis).

    Each participant quantizes; int32 accumulation cannot overflow for
    axis sizes < 2^23; the max-scale is reduced alongside.
    """
    q, scale = int8_compress(x)
    scale_max = jax.lax.pmax(scale, axis_name)
    # requantize against the shared scale so the sum is coherent
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale_max), -127, 127
                 ).astype(jnp.int32)
    total = jax.lax.psum(q, axis_name)
    return (total.astype(jnp.float32) * scale_max).astype(x.dtype)


def ring_allreduce(x: jax.Array, axis_name: str, axis_size: int
                   ) -> jax.Array:
    """Bandwidth-optimal ring all-reduce via collective_permute.

    reduce-scatter phase: N-1 hops, each adding a rotated shard;
    all-gather phase: N-1 hops broadcasting the reduced shards.  Written
    so each hop is an independent ppermute the scheduler can overlap.
    """
    n = axis_size
    if n == 1:
        return x
    idx = jax.lax.axis_index(axis_name)
    lead = x.shape[0]
    pad = (-lead) % n
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,) + x.shape[1:], x.dtype)])
    chunks = x.reshape((n, -1) + x.shape[1:])
    perm = [(i, (i + 1) % n) for i in range(n)]

    def take(c):
        return jnp.take(chunks, c % n, axis=0)

    # reduce-scatter: at step s, rank d receives the running sum of chunk
    # (d - s - 1) mod n from rank d-1 and adds its own copy
    acc = take(idx)
    for s in range(n - 1):
        acc = jax.lax.ppermute(acc, axis_name, perm)
        acc = acc + take(idx - s - 1)
    # rank d now owns the fully-reduced chunk (d + 1) mod n
    # all-gather phase: after k hops rank d holds chunk (d + 1 - k) mod n
    out = [acc]
    cur = acc
    for _ in range(n - 1):
        cur = jax.lax.ppermute(cur, axis_name, perm)
        out.append(cur)
    stacked = jnp.stack(out)                       # [n, chunk, ...]
    ranks = (idx + 1 - jnp.arange(n)) % n          # chunk id of out[k]
    onehot = jax.nn.one_hot(ranks, n, axis=0,
                            dtype=stacked.dtype)   # [n(chunk), n(k)]
    gathered = jnp.einsum("ok,k...->o...", onehot, stacked)
    flat = gathered.reshape((-1,) + x.shape[1:])
    return flat[:lead]


def allreduce_grads_over_pod(grads: Any, mesh: Mesh, *,
                             quantized: bool = True) -> Any:
    """Apply the compressed pod-axis reduction to a gradient pytree.

    Used when the train step is built with explicit cross-pod reduction
    (pod axis excluded from the batch spec); under the default plan the
    pod reduction is fused into XLA's reduce-scatter instead.
    """

    def local(g):
        if quantized:
            return psum_quantized(g, "pod") / mesh.shape["pod"]
        return jax.lax.pmean(g, "pod")

    def one(g):
        fn = shard_map(
            local, mesh=mesh,
            in_specs=P(*((None,) * g.ndim)),
            out_specs=P(*((None,) * g.ndim)),
            check_rep=False,
        )
        return fn(g)

    return jax.tree_util.tree_map(one, grads)
