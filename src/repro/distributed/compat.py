"""JAX API compatibility shims for the distribution substrate.

``shard_map`` moved twice across the JAX versions this repo targets:

* old releases expose ``jax.experimental.shard_map.shard_map`` with a
  ``check_rep=`` kwarg;
* new releases promote it to ``jax.shard_map`` and rename the
  replication check to ``check_vma=`` (the experimental module is
  removed).

Every ``shard_map`` call in this repo goes through :func:`shard_map`
below, which resolves the best available implementation once at import
time and translates the check kwarg — so model/collective code is
version-agnostic and new call sites cannot reintroduce a bare
``jax.shard_map`` dependency.
"""

from __future__ import annotations

import inspect
from typing import Any, Callable

import jax

_IMPL: Callable[..., Any]
try:                                     # new API: jax.shard_map
    _IMPL = jax.shard_map               # type: ignore[attr-defined]
except AttributeError:                   # old API: experimental module
    from jax.experimental.shard_map import shard_map as _IMPL

# the replication-check kwarg was renamed check_rep -> check_vma
_CHECK_KWARG = ("check_vma"
                if "check_vma" in inspect.signature(_IMPL).parameters
                else "check_rep")


def shard_map(f: Callable[..., Any], *, mesh: Any, in_specs: Any,
              out_specs: Any, check_rep: bool = True) -> Callable[..., Any]:
    """Version-agnostic ``shard_map``.

    Same contract as the underlying implementation; ``check_rep`` maps
    onto whichever replication-check kwarg the installed JAX spells
    (``check_rep`` or ``check_vma``).
    """
    return _IMPL(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                 **{_CHECK_KWARG: check_rep})


__all__ = ["shard_map"]
