"""Mesh/axis conventions.

Axis names:
  ``pod``   — cross-pod data parallelism (multi-pod meshes only)
  ``data``  — in-pod data parallelism + FSDP parameter sharding
  ``model`` — tensor parallelism (heads / d_ff / experts / vocab)

``ParallelPlan`` carries the mesh plus which axes exist, so model code can
be written once and run single-device (tests), single-pod, or multi-pod.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


@dataclass(frozen=True)
class ParallelPlan:
    mesh: Optional[Mesh] = None
    dp_axes: Tuple[str, ...] = ()
    tp_axis: Optional[str] = None

    @property
    def is_distributed(self) -> bool:
        return self.mesh is not None

    @property
    def dp(self) -> Optional[Tuple[str, ...]]:
        return self.dp_axes if self.dp_axes else None

    @property
    def dp_size(self) -> int:
        if not self.mesh:
            return 1
        n = 1
        for a in self.dp_axes:
            n *= self.mesh.shape[a]
        return n

    @property
    def tp_size(self) -> int:
        return self.mesh.shape[self.tp_axis] if (
            self.mesh and self.tp_axis) else 1

    def constrain(self, x, *spec):
        """with_sharding_constraint when distributed, identity otherwise."""
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(*spec))
        )

    def sharding(self, *spec) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, P(*spec))


SINGLE_DEVICE = ParallelPlan()


def plan_from_mesh(mesh: Mesh) -> ParallelPlan:
    """Build the standard plan from a mesh's axis names."""
    axes = mesh.axis_names
    dp = tuple(a for a in ("pod", "data") if a in axes)
    tp = "model" if "model" in axes else None
    if tp is None and "tp" in axes:
        tp = "tp"                      # serving meshes (see serving_mesh)
    return ParallelPlan(mesh=mesh, dp_axes=dp, tp_axis=tp)


# ---------------------------------------------------------------------------
# serving meshes
# ---------------------------------------------------------------------------

def serving_mesh(tp: int) -> Mesh:
    """A 1-D tensor-parallel mesh for the branch-serving hot loop.

    The axis is named ``tp``: serving shards only the per-token compute
    (attention heads / d_ff / experts / KV pages on the kv-head dim) —
    there is no data/FSDP axis because the decode batch is one
    continuous batch whose host-side branch bookkeeping (block tables,
    scheduler ledger, lifecycle tree) stays replicated and
    device-agnostic.
    """
    if tp < 1:
        raise ValueError(f"tp must be >= 1, got {tp}")
    if tp > len(jax.devices()):
        raise ValueError(
            f"tp={tp} exceeds the {len(jax.devices())} visible devices")
    return jax.make_mesh((tp,), ("tp",))


def serving_plan(mesh: Optional[Mesh]) -> ParallelPlan:
    """ParallelPlan for a serving mesh (``None`` -> single device).

    Accepts either a dedicated ``tp``-axis mesh from
    :func:`serving_mesh` or any mesh carrying a ``model`` axis (its
    tensor-parallel axis is reused; ``data``/``pod`` axes are ignored by
    serving, which keeps the batch replicated).
    """
    if mesh is None:
        return SINGLE_DEVICE
    if "tp" in mesh.axis_names:
        return ParallelPlan(mesh=mesh, dp_axes=(), tp_axis="tp")
    if "model" in mesh.axis_names:
        return ParallelPlan(mesh=mesh, dp_axes=(), tp_axis="model")
    raise ValueError(
        f"serving mesh needs a 'tp' or 'model' axis, got {mesh.axis_names}")
