"""Mesh/axis conventions.

Axis names:
  ``pod``   — cross-pod data parallelism (multi-pod meshes only)
  ``data``  — in-pod data parallelism + FSDP parameter sharding
  ``model`` — tensor parallelism (heads / d_ff / experts / vocab)

``ParallelPlan`` carries the mesh plus which axes exist, so model code can
be written once and run single-device (tests), single-pod, or multi-pod.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


@dataclass(frozen=True)
class ParallelPlan:
    mesh: Optional[Mesh] = None
    dp_axes: Tuple[str, ...] = ()
    tp_axis: Optional[str] = None

    @property
    def is_distributed(self) -> bool:
        return self.mesh is not None

    @property
    def dp(self) -> Optional[Tuple[str, ...]]:
        return self.dp_axes if self.dp_axes else None

    @property
    def dp_size(self) -> int:
        if not self.mesh:
            return 1
        n = 1
        for a in self.dp_axes:
            n *= self.mesh.shape[a]
        return n

    @property
    def tp_size(self) -> int:
        return self.mesh.shape[self.tp_axis] if (
            self.mesh and self.tp_axis) else 1

    def constrain(self, x, *spec):
        """with_sharding_constraint when distributed, identity otherwise."""
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(*spec))
        )

    def sharding(self, *spec) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, P(*spec))


SINGLE_DEVICE = ParallelPlan()


def plan_from_mesh(mesh: Mesh) -> ParallelPlan:
    """Build the standard plan from a mesh's axis names."""
    axes = mesh.axis_names
    dp = tuple(a for a in ("pod", "data") if a in axes)
    tp = "model" if "model" in axes else None
    return ParallelPlan(mesh=mesh, dp_axes=dp, tp_axis=tp)
