"""Sharding rules: map every parameter / activation / cache leaf to a
PartitionSpec on the (pod, data, model) mesh.

Strategy (DESIGN §5):
* FSDP: parameter matrices shard their *d_model-like* dim over ``data``
  (ZeRO-3: XLA all-gathers at use, reduce-scatters gradients).  Across
  pods parameters are **replicated** (hybrid sharding: FSDP in-pod, pure
  DP over ``pod`` — the cross-pod collective is one gradient all-reduce,
  the term gradient compression targets).
* TP: head / d_ff / expert / vocab dims shard over ``model``.  KV-head
  dims with fewer heads than the axis rely on XLA's padded uneven
  sharding (documented waste, see EXPERIMENTS §Roofline notes).
* Batch dims shard over ``(pod, data)``; KV caches shard batch over
  ``data`` and kv-heads over ``model``.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.distributed.mesh import ParallelPlan


def _leaf_name(path) -> str:
    return jax.tree_util.keystr((path[-1],)).strip("[]'\"")


def _axis_size(plan: ParallelPlan, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        n = 1
        for a in axis:
            n *= plan.mesh.shape[a]
        return n
    return plan.mesh.shape[axis]


def sanitize(plan: ParallelPlan, spec: P, shape: Tuple[int, ...]) -> P:
    """Drop axis assignments whose size does not divide the dim.

    ``jit`` in_shardings demand exact divisibility (unlike lazy GSPMD
    constraints), so e.g. 8 KV heads cannot shard over a 16-way model
    axis — the offending dim falls back to replicated.  Every drop is a
    documented memory/compute trade-off (EXPERIMENTS §Roofline notes).
    """
    out = []
    for dim, axis in zip(shape, tuple(spec) + (None,) * (len(shape)
                                                         - len(spec))):
        if axis is not None and dim % _axis_size(plan, axis) != 0:
            axis = None
        out.append(axis)
    return P(*out)


def _in_layers(path) -> bool:
    names = jax.tree_util.keystr(path)
    return "layers" in names


def spec_for_param(cfg: ArchConfig, path, shape: Tuple[int, ...]) -> P:
    """PartitionSpec for one parameter leaf (layer-stacked leaves have a
    leading L dim that stays unsharded)."""
    name = _leaf_name(path)
    lead = (None,) if _in_layers(path) else ()

    def with_lead(*spec):
        return P(*(lead + spec))

    if name == "embed":
        # vocab dim replicated: embedding gathers with a vocab-sharded
        # operand force SPMD "involuntary full rematerialization"
        # (observed in the dry-run HLO); d over data keeps it FSDP'd
        if len(shape) == 3:            # [cb, V, d]
            return P(None, None, "data")
        return P(None, "data")         # [V, d]
    if name == "lm_head":
        return P("data", "model")
    if name == "frontend_proj":
        return P("data", "model")
    if name == "final_norm":
        return P(None)
    if name == "w_concat":             # hybrid shared block [2d, d]
        return P("data", None)

    # attention
    if name == "wq":
        return with_lead("data", "model", None)
    if name in ("wk", "wv"):
        return with_lead("data", "model", None)   # kv heads: padded uneven
    if name == "wo":
        return with_lead("model", None, "data")
    if name in ("bq", "bk", "bv"):
        return with_lead("model", None)

    # dense MLP
    if name in ("wu", "wg", "wd"):
        if len(shape) - len(lead) == 3:            # MoE experts [E, d, f]
            if name == "wd":
                return with_lead("model", None, "data")
            return with_lead("model", "data", None)
        if name == "wd":                           # [f, d]
            return with_lead("model", "data")
        return with_lead("data", "model")          # [d, f]
    if name == "router":
        return with_lead("data", None)

    # mamba
    if name == "in_proj":
        return with_lead("data", "model")
    if name == "out_proj":
        return with_lead("model", "data")
    if name == "conv_w":
        return with_lead("model", None)
    if name == "conv_b":
        return with_lead("model")
    if name in ("A_log", "D", "dt_bias"):
        return with_lead("model")
    if name == "norm_w":
        return with_lead("model")
    if name in ("ln", "ln1", "ln2"):
        return with_lead(None)

    # fallback: replicate
    return P(*(lead + (None,) * (len(shape) - len(lead))))


def param_shardings(cfg: ArchConfig, plan: ParallelPlan, params: Any,
                    zero1: bool = False, drop_data: bool = False) -> Any:
    """NamedSharding tree matching ``params`` (works on ShapeDtypeStructs).

    Also correct for optimizer-state trees that mirror the param tree
    (adam mu/nu), since rules key off leaf names and ranks.  With
    ``zero1=True`` (or for mu/nu leaves on multi-pod meshes) the FSDP dim
    additionally shards over ``pod`` — ZeRO-1: once-per-step state pays
    one cross-pod gather of bf16 updates instead of resident replicas.
    """
    if plan.mesh is None:
        return jax.tree_util.tree_map(lambda _: None, params)

    has_pod = "pod" in plan.mesh.axis_names

    def one(path, leaf):
        spec = spec_for_param(cfg, path, leaf.shape)
        pathstr = jax.tree_util.keystr(path)
        if has_pod and (zero1 or "'mu'" in pathstr or "'nu'" in pathstr):
            spec = P(*tuple(
                ("pod", "data") if a == "data" else a for a in spec))
        if drop_data:
            # inference mode: TP-only residency — no per-step FSDP
            # all-gather; params replicate over the data axis
            spec = P(*tuple(None if a == "data" else a for a in spec))
        spec = sanitize(plan, spec, leaf.shape)
        return NamedSharding(plan.mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params)


def shard_params(cfg: ArchConfig, plan: ParallelPlan, params: Any) -> Any:
    """Device_put params onto their shardings (host -> mesh)."""
    sh = param_shardings(cfg, plan, params)
    return jax.tree_util.tree_map(jax.device_put, params, sh)


# ---------------------------------------------------------------------------
# activations / inputs / caches
# ---------------------------------------------------------------------------

def batch_spec(cfg: ArchConfig, plan: ParallelPlan, name: str,
               ndim: int) -> P:
    dp = plan.dp
    if name == "pos":
        return P(dp)
    # tokens/targets/frontend_embed: batch-major
    return P(*((dp,) + (None,) * (ndim - 1)))


def batch_shardings(cfg: ArchConfig, plan: ParallelPlan,
                    batch: Dict[str, Any]) -> Dict[str, Any]:
    if plan.mesh is None:
        return {k: None for k in batch}
    return {
        k: NamedSharding(
            plan.mesh,
            sanitize(plan, batch_spec(cfg, plan, k, len(v.shape)),
                     v.shape))
        for k, v in batch.items()
    }


def cache_spec(cfg: ArchConfig, plan: ParallelPlan, name: str,
               shape: Tuple[int, ...]) -> P:
    """Decode-cache leaves.

    KV caches shard **sequence over model** (flash-decode style: every
    model shard owns a slice of the context; the softmax reductions
    cross-shard as small psums) and batch over data.  None of the
    assigned archs has kv_heads divisible by 16, so sequence sharding is
    what keeps a 32k-context cache at ~2 GB/device instead of 37 GB.
    Recurrent SSM state shards heads over model.
    """
    if name in ("k", "v"):
        # [L_or_A, b, S, kv, hd]
        return P(None, "data", "model", None, None)
    if name == "conv":
        # [L, b, ck-1, conv_dim]
        return P(None, "data", None, "model")
    if name == "ssm":
        # [L, b, H, N, P]
        return P(None, "data", "model", None, None)
    return P(*(None,) * len(shape))


def state_shardings(cfg: ArchConfig, plan: ParallelPlan,
                    cache: Dict[str, Any]) -> Dict[str, Any]:
    if plan.mesh is None:
        return {k: None for k in cache}
    return {
        k: NamedSharding(
            plan.mesh,
            sanitize(plan, cache_spec(cfg, plan, k, v.shape), v.shape))
        for k, v in cache.items()
    }


# ---------------------------------------------------------------------------
# serving (tensor-parallel decode over paged KV)
# ---------------------------------------------------------------------------

def _retarget(spec: P, tp_axis: str) -> P:
    """Map the training rules onto a serving plan: the ``model`` axis
    becomes the plan's tp axis and the ``data``/``pod`` axes are dropped
    (inference is TP-only residency — no FSDP all-gather per step)."""
    def one(a):
        if a in ("data", "pod") or (isinstance(a, (tuple, list))):
            return None
        return tp_axis if a == "model" else a
    return P(*(one(a) for a in spec))


def serve_param_specs(cfg: ArchConfig, plan: ParallelPlan,
                      params: Any) -> Any:
    """PartitionSpec tree for the serving hot loop's ``shard_map``.

    Derived from the training rules (:func:`spec_for_param`) with the
    tensor-parallel axis retargeted onto ``plan.tp_axis`` and every
    data/FSDP assignment dropped — attention heads, kv heads, d_ff and
    experts shard over tp; norms, embeddings and the router replicate.
    Non-dividing dims fall back to replicated (``sanitize``); dims whose
    sharding a psum *depends on* (kv heads, d_ff, experts) are validated
    up front by :meth:`ServeEngine <repro.runtime.serve_loop.ServeEngine>`
    so the fallback can never silently break the reduction.
    """
    def one(path, leaf):
        spec = _retarget(spec_for_param(cfg, path, leaf.shape),
                         plan.tp_axis)
        return sanitize(plan, spec, leaf.shape)

    return jax.tree_util.tree_map_with_path(one, params)


def kv_page_spec(plan: ParallelPlan) -> P:
    """Spec for the paged KV pools ``[L, n_pages, page, kv, hd]``.

    Pages shard on the **kv-head dim**: a page id means the same thing
    on every shard, so the host-side block tables, refcounts and CoW
    plans stay device-agnostic — one fork/commit is still one metadata
    operation plus (at most) one fused ``_copy_pages`` dispatch, and
    each shard copies only its slice of the faulted page.
    """
    return P(None, None, None, plan.tp_axis, None)
