"""Shared transformer layers: norms, rotary, GQA attention, MLP variants.

Everything is a pure function over explicit parameter pytrees; layer
parameters are *stacked* along a leading ``[L, ...]`` axis so the model
stack is a single ``lax.scan`` — compile time is O(1) in depth, which is
what makes 94-layer × 512-device dry-runs tractable.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# initialization helpers
# ---------------------------------------------------------------------------

def dense_init(key: jax.Array, shape: Tuple[int, ...], dtype: Any,
               fan_in: Optional[int] = None) -> jax.Array:
    fan_in = fan_in if fan_in is not None else shape[-2] if len(shape) > 1 else shape[-1]
    std = 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, weight: jax.Array, eps: float) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32)).astype(dtype)


def gated_rms_norm(x: jax.Array, z: jax.Array, weight: jax.Array,
                   eps: float) -> jax.Array:
    """Mamba2's output norm: RMSNorm(x * silu(z))."""
    dtype = x.dtype
    x = x.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# rotary position embedding
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., seq, heads, head_dim]; positions: [..., seq]."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # [hd/2]
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [..., s, hd/2]
    cos = jnp.cos(angles)[..., :, None, :]              # [..., s, 1, hd/2]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (GQA, chunked-causal = flash-equivalent math, O(S·chunk) memory)
# ---------------------------------------------------------------------------

def init_attention(cfg: ArchConfig, key: jax.Array, dtype: Any) -> Params:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p: Params = {
        "wq": dense_init(ks[0], (d, h, hd), dtype, fan_in=d),
        "wk": dense_init(ks[1], (d, kv, hd), dtype, fan_in=d),
        "wv": dense_init(ks[2], (d, kv, hd), dtype, fan_in=d),
        "wo": dense_init(ks[3], (h, hd, d), dtype, fan_in=h * hd),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, hd), dtype)
        p["bk"] = jnp.zeros((kv, hd), dtype)
        p["bv"] = jnp.zeros((kv, hd), dtype)
    return p


def qkv_project(cfg: ArchConfig, p: Params, x: jax.Array,
                positions: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def chunked_causal_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                             *, chunk: int = 1024) -> jax.Array:
    """Causal GQA attention with O(S·chunk) score memory.

    q: [b, s, h, hd]; k, v: [b, s, kv, hd] with h = kv * group.
    Mathematically identical to full softmax attention (and to the
    flash_attention Pallas kernel's output) — scores are computed one
    query chunk at a time via ``lax.scan`` ("lax-flash").
    """
    b, s, h, hd = q.shape
    kvh = k.shape[2]
    group = h // kvh
    scale = 1.0 / math.sqrt(hd)
    chunk = min(chunk, s)
    if s % chunk:
        chunk = math.gcd(chunk, s)
    n_chunks = s // chunk

    qr = q.reshape(b, n_chunks, chunk, kvh, group, hd)
    qr = jnp.moveaxis(qr, 1, 0)                     # [nc, b, c, kv, g, hd]
    kpos = jnp.arange(s)

    # The score/prob tensors live in VMEM under the flash_attention
    # Pallas kernel (DESIGN §7); the tag lets the roofline parser separate
    # their would-be-HBM traffic out of the memory term.  jax.checkpoint
    # forces backward to RECOMPUTE them per chunk instead of stacking
    # S²-sized residuals across the scan — the flash-backward structure.
    @jax.checkpoint
    def _chunk_attn(q_c, c_idx, k_, v_):
        with jax.named_scope("vmem_resident"):
            scores = jnp.einsum("bqkgh,bskh->bkgqs", q_c, k_,
                                preferred_element_type=jnp.float32) * scale
            qpos = c_idx * chunk + jnp.arange(chunk)    # [c]
            mask = kpos[None, :] <= qpos[:, None]       # [c, s]
            scores = jnp.where(mask[None, None, None], scores, -jnp.inf)
            probs = jax.nn.softmax(scores, axis=-1)     # fp32
            return jnp.einsum("bkgqs,bskh->bqkgh",
                              probs.astype(v_.dtype), v_)

    def body(carry, q_c_and_idx):
        q_c, c_idx = q_c_and_idx                    # [b, c, kv, g, hd]
        return carry, _chunk_attn(q_c, c_idx, k, v)

    _, out = jax.lax.scan(body, None, (qr, jnp.arange(n_chunks)))
    out = jnp.moveaxis(out, 0, 1)                   # [b, nc, c, kv, g, hd]
    return out.reshape(b, s, h, hd)


def full_causal_attention(q: jax.Array, k: jax.Array, v: jax.Array
                          ) -> jax.Array:
    """Reference O(S²)-memory attention (small shapes / oracles only)."""
    return chunked_causal_attention(q, k, v, chunk=q.shape[1])


def decode_attention_dense(q: jax.Array, k_cache: jax.Array,
                           v_cache: jax.Array, lengths: jax.Array
                           ) -> jax.Array:
    """One-token decode attention against a dense [b, S, kv, hd] cache.

    q: [b, 1, h, hd]; lengths: [b] — number of valid cache positions
    (including the token just written).
    """
    b, _, h, hd = q.shape
    kvh = k_cache.shape[2]
    group = h // kvh
    scale = 1.0 / math.sqrt(hd)
    qr = q.reshape(b, kvh, group, hd)
    # scores stay in VMEM under the paged_attention Pallas kernel
    with jax.named_scope("vmem_resident"):
        scores = jnp.einsum("bkgh,bskh->bkgs", qr, k_cache,
                            preferred_element_type=jnp.float32) * scale
        pos = jnp.arange(k_cache.shape[1])
        mask = pos[None, :] < lengths[:, None]          # [b, S]
        scores = jnp.where(mask[:, None, None, :], scores, -jnp.inf)
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bkgs,bskh->bkgh", probs.astype(v_cache.dtype),
                         v_cache)
    return out.reshape(b, 1, h, hd)


def attention_block(cfg: ArchConfig, p: Params, x: jax.Array,
                    positions: jax.Array, *, chunk: int = 1024) -> jax.Array:
    q, k, v = qkv_project(cfg, p, x, positions)
    out = chunked_causal_attention(q, k, v, chunk=chunk)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def attention_decode_block(
    cfg: ArchConfig, p: Params, x: jax.Array, pos: jax.Array,
    k_cache: jax.Array, v_cache: jax.Array,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Decode: write this token's K/V at ``pos`` then attend.

    x: [b, 1, d].  ``pos`` is either [b] (per-sequence positions →
    scatter write) or a scalar (position-aligned batch, continuous-
    batching style → one dynamic_update_slice; §Perf shows the scatter
    path streams the whole cache through convert chains, the aligned
    path writes one token row).  Returns (out, new_k, new_v).
    """
    b = x.shape[0]
    if pos.ndim == 0:
        positions = pos.reshape(1, 1)
        q, k, v = qkv_project(cfg, p, x, positions)
        k_cache = jax.lax.dynamic_update_slice(
            k_cache, k, (0, pos.astype(jnp.int32), 0, 0))
        v_cache = jax.lax.dynamic_update_slice(
            v_cache, v, (0, pos.astype(jnp.int32), 0, 0))
        lengths = jnp.full((b,), pos + 1, jnp.int32)
    else:
        q, k, v = qkv_project(cfg, p, x, pos[:, None])
        # scatter the new token at per-sequence positions
        batch_idx = jnp.arange(b)
        k_cache = k_cache.at[batch_idx, pos].set(k[:, 0])
        v_cache = v_cache.at[batch_idx, pos].set(v[:, 0])
        lengths = pos + 1
    out = decode_attention_dense(q, k_cache, v_cache, lengths)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), k_cache, v_cache


# ---------------------------------------------------------------------------
# MLP variants
# ---------------------------------------------------------------------------

def init_mlp(cfg: ArchConfig, key: jax.Array, dtype: Any,
             d_ff: Optional[int] = None) -> Params:
    d = cfg.d_model
    f = d_ff if d_ff is not None else cfg.d_ff
    ks = jax.random.split(key, 3)
    p: Params = {
        "wu": dense_init(ks[0], (d, f), dtype),
        "wd": dense_init(ks[1], (f, d), dtype),
    }
    if cfg.mlp_activation in ("swiglu", "geglu"):
        p["wg"] = dense_init(ks[2], (d, f), dtype)
    return p


def mlp_block(cfg: ArchConfig, p: Params, x: jax.Array) -> jax.Array:
    act = cfg.mlp_activation
    up = x @ p["wu"]
    if act == "swiglu":
        h = jax.nn.silu(x @ p["wg"]) * up
    elif act == "geglu":
        h = jax.nn.gelu(x @ p["wg"]) * up
    elif act == "sqrelu":
        h = jnp.square(jax.nn.relu(up))
    else:
        raise ValueError(f"unknown activation {act}")
    return h @ p["wd"]
