"""Mixture-of-Experts: sort-based capacity dispatch with expert parallelism.

Design (TPU-native, no one-hot dispatch tensors):

* Router + top-k run on every shard (activations are replicated across the
  ``model`` axis between blocks, TP-style).
* Experts are sharded over the ``model`` axis (EP).  Each shard packs the
  token-assignments that target *its* experts into a dense
  ``[E_local, capacity, d]`` buffer via an argsort + gather (MXU-friendly,
  no scatter in the hot path), runs the expert FFNs as batched matmuls,
  and scatters gate-weighted results back to its tokens.
* The cross-shard combine is a single ``psum`` over ``model`` — the same
  collective a TP MLP needs, so EP adds **zero** extra collective volume
  over dense TP (this is the key roofline property; see DESIGN §5).

Capacity follows GShard: ``C = ceil(tokens·K/E · capacity_factor)``;
overflowing assignments are dropped (their gate weight contributes 0).
The load-balancing auxiliary loss is the standard ``E · Σ_e f_e·p_e``.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.distributed.compat import shard_map
from repro.models.layers import dense_init

Params = Dict[str, Any]


def init_moe(cfg: ArchConfig, key: jax.Array, dtype: Any) -> Params:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 4)
    p: Params = {
        "router": dense_init(ks[0], (d, e), jnp.float32, fan_in=d),
        "wu": dense_init(ks[1], (e, d, f), dtype, fan_in=d),
        "wd": dense_init(ks[2], (e, f, d), dtype, fan_in=f),
    }
    if cfg.mlp_activation in ("swiglu", "geglu"):
        p["wg"] = dense_init(ks[3], (e, d, f), dtype, fan_in=d)
    return p


def _capacity(n_tokens: int, cfg: ArchConfig) -> int:
    e, k = cfg.num_experts, cfg.experts_per_token
    return max(1, int(math.ceil(n_tokens * k / e * cfg.moe_capacity_factor)))


def moe_apply_local(
    cfg: ArchConfig,
    x: jax.Array,          # [n, d] local tokens
    router_w: jax.Array,   # [d, E] (replicated)
    wg: Optional[jax.Array],  # [E_loc, d, f]
    wu: jax.Array,
    wd: jax.Array,
    e0: jax.Array,         # first global expert id owned by this shard
) -> Tuple[jax.Array, jax.Array]:
    """Dispatch/compute/combine for the experts owned by one shard.

    Returns (partial y [n, d] — sum over shards recovers the full output —
    and the (shard-identical) aux loss).
    """
    n, d = x.shape
    e_total, k = cfg.num_experts, cfg.experts_per_token
    e_loc = wu.shape[0]
    cap = _capacity(n, cfg)
    nk = n * k

    # --- routing (full expert set; identical on every model shard) -----
    logits = x.astype(jnp.float32) @ router_w.astype(jnp.float32)  # [n, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert_ids = jax.lax.top_k(probs, k)                     # [n, K]
    gate = gate / jnp.sum(gate, axis=-1, keepdims=True)            # renorm

    # aux load-balance loss: E · Σ_e f_e p_e
    f_e = jnp.zeros((e_total,), jnp.float32).at[expert_ids.reshape(-1)].add(
        1.0 / nk
    )
    aux = e_total * jnp.sum(f_e * jnp.mean(probs, axis=0))

    # --- pack local assignments into [E_loc, cap] slots -----------------
    a_tok = jnp.repeat(jnp.arange(n, dtype=jnp.int32), k)          # [nK]
    a_exp = expert_ids.reshape(-1).astype(jnp.int32)
    a_gate = gate.reshape(-1)
    lexp = a_exp - e0
    is_local = (lexp >= 0) & (lexp < e_loc)
    sort_key = jnp.where(is_local, lexp, e_loc)                    # overflow bin
    order = jnp.argsort(sort_key)                                  # stable
    key_s = sort_key[order]
    counts = jnp.bincount(sort_key, length=e_loc + 1)
    starts = jnp.concatenate(
        [jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]]
    )
    pos_s = jnp.arange(nk, dtype=jnp.int32) - starts[key_s].astype(jnp.int32)
    keep_s = (pos_s < cap) & (key_s < e_loc)
    slot_s = jnp.where(keep_s, key_s * cap + pos_s, e_loc * cap)   # dump slot

    # slot -> token map (scatter once into the small slot table)
    slot_tok = jnp.full((e_loc * cap + 1,), n, jnp.int32)
    slot_tok = slot_tok.at[slot_s].set(a_tok[order])
    x_pad = jnp.concatenate([x, jnp.zeros((1, d), x.dtype)])
    xb = x_pad[slot_tok[:-1]].reshape(e_loc, cap, d)               # gather

    # --- expert FFNs as batched matmuls ---------------------------------
    up = jnp.einsum("ecd,edf->ecf", xb, wu)
    if cfg.mlp_activation == "swiglu":
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xb, wg)) * up
    elif cfg.mlp_activation == "geglu":
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", xb, wg)) * up
    else:  # sqrelu
        h = jnp.square(jax.nn.relu(up))
    yb = jnp.einsum("ecf,efd->ecd", h, wd).reshape(e_loc * cap, d)

    # --- combine: gather each assignment's result, weight, reduce over K.
    # einsum keeps the [n,K,d] operand in model dtype (never a fp32
    # materialization — §Perf iteration 3 on qwen3-moe) with fp32
    # accumulation inside the contraction only.
    slot_a = jnp.zeros((nk,), jnp.int32).at[order].set(slot_s)
    y_pad = jnp.concatenate([yb, jnp.zeros((1, d), yb.dtype)])
    y_a = y_pad[slot_a].reshape(n, k, d)                           # [n,K,d]
    w_a = jnp.where(slot_a < e_loc * cap, a_gate, 0.0).reshape(n, k)
    y = jnp.einsum("nkd,nk->nd", y_a, w_a.astype(y_a.dtype),
                   preferred_element_type=jnp.float32)
    return y.astype(x.dtype), aux


def moe_block(
    cfg: ArchConfig,
    p: Params,
    x: jax.Array,            # [b, s, d]
    *,
    mesh: Optional[jax.sharding.Mesh] = None,
    dp_axes: Tuple[str, ...] = (),
    tp_axis: Optional[str] = None,
) -> Tuple[jax.Array, jax.Array]:
    """MoE FFN block.  With a mesh: shard_map EP over ``tp_axis``."""
    b, s, d = x.shape
    wg = p.get("wg")

    if mesh is None or tp_axis is None:
        y, aux = moe_apply_local(
            cfg, x.reshape(-1, d), p["router"], wg, p["wu"], p["wd"],
            jnp.int32(0),
        )
        return y.reshape(b, s, d), aux

    tp_size = mesh.shape[tp_axis]
    e_loc = cfg.num_experts // tp_size
    assert e_loc * tp_size == cfg.num_experts, (
        f"{cfg.num_experts} experts must divide tp={tp_size}"
    )

    def local_fn(x_loc, rw, wg_loc, wu_loc, wd_loc):
        bl, sl, _ = x_loc.shape
        e0 = (jax.lax.axis_index(tp_axis) * e_loc).astype(jnp.int32)
        y, aux = moe_apply_local(
            cfg, x_loc.reshape(-1, d), rw,
            None if wg_loc is None else wg_loc, wu_loc, wd_loc, e0,
        )
        y = jax.lax.psum(y, tp_axis)         # EP combine == TP psum
        aux = jax.lax.pmean(aux, dp_axes + (tp_axis,))
        return y.reshape(bl, sl, d), aux

    dp = P(dp_axes if dp_axes else None)
    in_specs = (
        P(*(dp + (None, None))),             # x: batch over dp, replicated tp
        P(None, None),                       # router: replicated
        P(tp_axis, None, None),              # experts over tp
        P(tp_axis, None, None),
        P(tp_axis, None, None),
    )
    out_specs = (P(*(dp + (None, None))), P())
    fn = shard_map(
        partial(local_fn),
        mesh=mesh,
        in_specs=in_specs,
        out_specs=out_specs,
        check_rep=False,
    )
    if wg is None:
        wg = jnp.zeros((cfg.num_experts, 1, 1), x.dtype)  # placeholder
    return fn(x, p["router"], wg, p["wu"], p["wd"])
