"""Model facade: one object per architecture config.

Wraps init / loss / prefill / decode with a :class:`ParallelPlan`, so the
same code path serves CPU smoke tests, the single-pod mesh, and the
multi-pod mesh.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.mesh import ParallelPlan, SINGLE_DEVICE
from repro.models import decode as D
from repro.models import transformer as T

Params = Dict[str, Any]

# re-exports used by configs.shapes and the launch layer
decode_state_specs = D.decode_state_specs
init_decode_state = D.init_decode_state


def init_params(cfg: ArchConfig, key: jax.Array) -> Params:
    return T.init_transformer(cfg, key)


@dataclass
class Model:
    cfg: ArchConfig
    plan: ParallelPlan = field(default_factory=lambda: SINGLE_DEVICE)
    remat: bool = True
    attn_chunk: int = 1024
    loss_chunk: int = 512
    moe_aux_weight: float = 0.01

    def init(self, key: jax.Array) -> Params:
        return init_params(self.cfg, key)

    # -- training ---------------------------------------------------------
    def loss(self, params: Params, batch: Dict[str, jax.Array]
             ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        cfg = self.cfg
        attn_chunk = min(self.attn_chunk, batch["tokens"].shape[1])
        h, aux = T.forward(
            cfg, params, batch["tokens"],
            batch.get("frontend_embed"),
            plan=self.plan, remat=self.remat, attn_chunk=attn_chunk,
        )
        xent = T.token_loss(cfg, params, h, batch["targets"],
                            loss_chunk=min(self.loss_chunk,
                                           batch["tokens"].shape[1]),
                            plan=self.plan)
        total = xent + self.moe_aux_weight * aux
        return total, {"xent": xent, "moe_aux": aux}

    # -- serving ------------------------------------------------------------
    def prefill(self, params: Params, tokens: jax.Array,
                frontend_embed: Optional[jax.Array] = None,
                max_len: Optional[int] = None
                ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        return D.prefill(
            self.cfg, params, tokens, frontend_embed,
            max_len=max_len, plan=self.plan,
            attn_chunk=min(self.attn_chunk, tokens.shape[1]),
        )

    def decode_step(self, params: Params, cache: Dict[str, jax.Array],
                    tokens: jax.Array, pos: jax.Array
                    ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        return D.decode_step(self.cfg, params, cache, tokens, pos,
                             plan=self.plan)

    def init_decode_state(self, batch: int, max_len: int
                          ) -> Dict[str, jax.Array]:
        return D.init_decode_state(self.cfg, batch, max_len)
