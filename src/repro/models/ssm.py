"""Mamba2 (SSD — state-space duality) blocks, pure JAX.

The chunked SSD algorithm (Dao & Gu, arXiv:2405.21060) maps naturally to
the MXU: within-chunk terms are batched matmuls over ``[chunk, chunk]``
tiles, and the inter-chunk recurrence is a short ``lax.scan`` over
``seq/chunk`` steps carrying the ``[H, N, P]`` state.  Decode is an O(1)
state update — the recurrent state is *the* branchable device state for
SSM archs (DESIGN §6): a branch fork copies one small tensor.

Layout conventions:
  x:   [b, s, H, P]   (H = heads = d_inner/P, P = head dim)
  dt:  [b, s, H]      (post-softplus, fp32)
  A:   [H]            (negative, fp32)
  B,C: [b, s, N]      (single group, shared across heads)
  state: [b, H, N, P]
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import dense_init, gated_rms_norm

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# chunked SSD scan (training / prefill)
# ---------------------------------------------------------------------------

def ssd_chunked(
    x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array, C: jax.Array,
    chunk: int, initial_state: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y [b,s,H,P], final_state [b,H,N,P])."""
    b, s, H, Pd = x.shape
    N = B.shape[-1]
    chunk = min(chunk, s)
    if s % chunk:
        import math as _math

        chunk = _math.gcd(chunk, s)
    nc = s // chunk

    xr = x.reshape(b, nc, chunk, H, Pd)
    dtr = dt.reshape(b, nc, chunk, H).astype(jnp.float32)
    Br = B.reshape(b, nc, chunk, N)
    Cr = C.reshape(b, nc, chunk, N)

    dA = dtr * A.astype(jnp.float32)                 # [b,nc,q,H], negative
    cum = jnp.cumsum(dA, axis=2)                     # within-chunk cumsum

    # ---- intra-chunk (dual / attention-like form) ----------------------
    # the [Q,Q] decay/score tiles live in VMEM under the ssd_scan Pallas
    # kernel (DESIGN §7) — tagged for the roofline parser
    with jax.named_scope("vmem_resident"):
        cb = jnp.einsum("bcqn,bckn->bcqk", Cr, Br,
                        preferred_element_type=jnp.float32)
        diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # [b,nc,q,k,H]
        causal = jnp.tril(jnp.ones((chunk, chunk), bool))
        L = jnp.where(causal[None, None, :, :, None], jnp.exp(diff), 0.0)
        W = (cb[..., None] * L * dtr[:, :, None, :, :]).astype(x.dtype)
        y_diag = jnp.einsum("bcqkh,bckhp->bcqhp", W, xr)

    # ---- chunk boundary states -----------------------------------------
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)        # [b,nc,q,H]
    wk = (dtr * decay_to_end).astype(x.dtype)
    S = jnp.einsum("bckh,bckn,bckhp->bchnp", wk, Br, xr)   # [b,nc,H,N,P]

    # ---- inter-chunk recurrence ------------------------------------------
    chunk_decay = jnp.exp(cum[:, :, -1, :])                # [b,nc,H]
    in_decay = jnp.exp(cum).astype(x.dtype)                # [b,nc,q,H]
    h0 = (jnp.zeros((b, H, N, Pd), jnp.float32)
          if initial_state is None else initial_state.astype(jnp.float32))

    def body(h, per_chunk):
        S_c, cd_c, C_c, ind_c = per_chunk
        y_off = jnp.einsum("bqn,bhnp,bqh->bqhp",
                           C_c, h.astype(x.dtype), ind_c)
        h = cd_c[:, :, None, None] * h + S_c.astype(jnp.float32)
        return h, y_off

    xs = (
        jnp.moveaxis(S, 1, 0),
        jnp.moveaxis(chunk_decay, 1, 0),
        jnp.moveaxis(Cr, 1, 0),
        jnp.moveaxis(in_decay, 1, 0),
    )
    hT, y_off = jax.lax.scan(body, h0, xs)
    y = y_diag + jnp.moveaxis(y_off, 0, 1).reshape(b, nc, chunk, H, Pd)
    return y.reshape(b, s, H, Pd), hT


def ssd_decode_step(
    x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array, C: jax.Array,
    state: jax.Array,
) -> Tuple[jax.Array, jax.Array]:
    """One-token SSD update.  x:[b,H,P] dt:[b,H] B,C:[b,N] state:[b,H,N,P]."""
    dt = dt.astype(jnp.float32)
    dA = jnp.exp(dt * A.astype(jnp.float32))              # [b,H]
    upd = jnp.einsum("bh,bn,bhp->bhnp", dt, B.astype(jnp.float32),
                     x.astype(jnp.float32))
    state = state * dA[:, :, None, None] + upd
    y = jnp.einsum("bn,bhnp->bhp", C.astype(jnp.float32), state)
    return y.astype(x.dtype), state


# ---------------------------------------------------------------------------
# causal depthwise conv1d
# ---------------------------------------------------------------------------

def causal_conv1d(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """x: [b, s, c]; w: [c, ck]; depthwise causal conv + SiLU."""
    ck = w.shape[1]
    xp = jnp.pad(x, ((0, 0), (ck - 1, 0), (0, 0)))
    s = x.shape[1]
    y = sum(xp[:, i:i + s, :] * w[None, None, :, i] for i in range(ck))
    return jax.nn.silu(y + b)


def conv1d_decode(x: jax.Array, conv_state: jax.Array, w: jax.Array,
                  b: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x: [b, c] new element; conv_state: [b, ck-1, c].  Returns (y, state)."""
    window = jnp.concatenate([conv_state, x[:, None, :]], axis=1)  # [b,ck,c]
    y = jnp.einsum("bkc,ck->bc", window, w)
    return jax.nn.silu(y + b), window[:, 1:, :]


# ---------------------------------------------------------------------------
# Mamba2 block
# ---------------------------------------------------------------------------

def init_mamba(cfg: ArchConfig, key: jax.Array, dtype: Any) -> Params:
    d = cfg.d_model
    di, N, H = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads
    cdim, ck = cfg.ssm_conv_dim, cfg.ssm_conv_kernel
    dip = 2 * di + 2 * cfg.ssm_groups * N + H
    ks = jax.random.split(key, 4)
    return {
        "in_proj": dense_init(ks[0], (d, dip), dtype, fan_in=d),
        "conv_w": dense_init(ks[1], (cdim, ck), dtype, fan_in=ck),
        "conv_b": jnp.zeros((cdim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H, dtype=jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.logspace(-3, -1, H, dtype=jnp.float32))),
        "norm_w": jnp.ones((di,), dtype),
        "out_proj": dense_init(ks[2], (di, d), dtype, fan_in=di),
    }


def _split_proj(cfg: ArchConfig, zxbcdt: jax.Array):
    di, N = cfg.ssm_d_inner, cfg.ssm_state
    g = cfg.ssm_groups
    cdim = cfg.ssm_conv_dim
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di:di + cdim]
    dt = zxbcdt[..., di + cdim:]
    return z, xBC, dt


def _split_xbc(cfg: ArchConfig, xBC: jax.Array):
    di, N = cfg.ssm_d_inner, cfg.ssm_state
    xs = xBC[..., :di]
    B = xBC[..., di:di + N]
    C = xBC[..., di + N:]
    return xs, B, C


def mamba_block(cfg: ArchConfig, p: Params, x: jax.Array,
                ) -> jax.Array:
    """Training/prefill Mamba2 block.  x: [b, s, d]."""
    b, s, _ = x.shape
    di, H, Pd = cfg.ssm_d_inner, cfg.ssm_heads, cfg.ssm_head_dim
    z, xBC, dt = _split_proj(cfg, x @ p["in_proj"])
    xBC = causal_conv1d(xBC, p["conv_w"], p["conv_b"])
    xs, B, C = _split_xbc(cfg, xBC)
    xs = xs.reshape(b, s, H, Pd)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, _ = ssd_chunked(xs, dt, A, B, C, cfg.ssm_chunk)
    y = y + p["D"].astype(y.dtype)[None, None, :, None] * xs
    y = gated_rms_norm(y.reshape(b, s, di), z, p["norm_w"], cfg.norm_eps)
    return y @ p["out_proj"]


def mamba_decode_block(
    cfg: ArchConfig, p: Params, x: jax.Array,
    conv_state: jax.Array, ssm_state: jax.Array,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One-token Mamba2 step.  x: [b, 1, d].

    conv_state: [b, ck-1, conv_dim]; ssm_state: [b, H, N, P].
    """
    b = x.shape[0]
    di, H, Pd = cfg.ssm_d_inner, cfg.ssm_heads, cfg.ssm_head_dim
    z, xBC, dt = _split_proj(cfg, x[:, 0] @ p["in_proj"])
    xBC, conv_state = conv1d_decode(xBC, conv_state, p["conv_w"],
                                    p["conv_b"])
    xs, B, C = _split_xbc(cfg, xBC)
    xs = xs.reshape(b, H, Pd)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # [b,H]
    A = -jnp.exp(p["A_log"])
    y, ssm_state = ssd_decode_step(xs, dt, A, B, C, ssm_state)
    y = y + p["D"].astype(y.dtype)[None, :, None] * xs
    y = gated_rms_norm(y.reshape(b, di), z, p["norm_w"], cfg.norm_eps)
    return (y @ p["out_proj"])[:, None, :], conv_state, ssm_state
