"""Model definitions: composable pure-JAX layers covering every assigned
architecture family (dense / MoE / SSM / hybrid / VLM / audio)."""

from repro.models.model import (
    Model,
    decode_state_specs,
    init_params,
)

__all__ = ["Model", "decode_state_specs", "init_params"]
