"""The layer stack for every assigned family, as a single ``lax.scan``.

Families:
* dense / vlm / audio — pre-norm attention + MLP blocks.
* moe               — attention + shard_map EP MoE FFN.
* ssm               — Mamba2 (SSD) blocks, attention-free.
* hybrid (zamba2)   — Mamba2 backbone; ONE weight-shared attention+MLP
  block applied after every ``attn_every`` Mamba layers on
  ``concat([h, h0])`` (h0 = embedding output), Zamba-style.

Layer params are stacked ``[L, ...]`` so compile time is depth-independent;
remat (``jax.checkpoint``) wraps the scan body.  All functions are pure
and take an explicit :class:`ParallelPlan` for sharding constraints.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.mesh import ParallelPlan, SINGLE_DEVICE
from repro.models import layers as L
from repro.models.moe import init_moe, moe_block
from repro.models.ssm import (
    init_mamba,
    mamba_block,
    mamba_decode_block,
    ssd_chunked,
)

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# initialization
# ---------------------------------------------------------------------------

def init_transformer(cfg: ArchConfig, key: jax.Array) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, 8)
    p: Params = {}

    # embeddings
    if cfg.num_codebooks > 1:
        p["embed"] = L.dense_init(
            keys[0], (cfg.num_codebooks, cfg.vocab_size, cfg.d_model),
            dtype, fan_in=cfg.d_model)
    else:
        p["embed"] = L.dense_init(keys[0], (cfg.vocab_size, cfg.d_model),
                                  dtype, fan_in=cfg.d_model)
    if cfg.frontend == "vlm_stub":
        p["frontend_proj"] = L.dense_init(
            keys[1], (cfg.d_model, cfg.d_model), dtype)

    lkeys = jax.random.split(keys[2], cfg.num_layers)

    if cfg.family in ("dense", "vlm", "audio", "moe"):
        def one(k):
            ka, km = jax.random.split(k)
            lp = {
                "ln1": jnp.ones((cfg.d_model,), dtype),
                "ln2": jnp.ones((cfg.d_model,), dtype),
                "attn": L.init_attention(cfg, ka, dtype),
            }
            if cfg.is_moe:
                lp["moe"] = init_moe(cfg, km, dtype)
            else:
                lp["mlp"] = L.init_mlp(cfg, km, dtype)
            return lp

        p["layers"] = jax.vmap(one)(lkeys)
    elif cfg.family == "ssm":
        def one(k):
            return {
                "ln": jnp.ones((cfg.d_model,), dtype),
                "mamba": init_mamba(cfg, k, dtype),
            }

        p["layers"] = jax.vmap(one)(lkeys)
    elif cfg.family == "hybrid":
        def one(k):
            return {
                "ln": jnp.ones((cfg.d_model,), dtype),
                "mamba": init_mamba(cfg, k, dtype),
            }

        p["layers"] = jax.vmap(one)(lkeys)
        ks = jax.random.split(keys[3], 3)
        p["shared"] = {
            "w_concat": L.dense_init(ks[0], (2 * cfg.d_model, cfg.d_model),
                                     dtype),
            "ln1": jnp.ones((cfg.d_model,), dtype),
            "ln2": jnp.ones((cfg.d_model,), dtype),
            "attn": L.init_attention(cfg, ks[1], dtype),
            "mlp": L.init_mlp(cfg, ks[2], dtype),
        }
    else:
        raise ValueError(f"unknown family {cfg.family}")

    p["final_norm"] = jnp.ones((cfg.d_model,), dtype)
    if not cfg.tie_embeddings:
        p["lm_head"] = L.dense_init(
            keys[4], (cfg.d_model, cfg.num_codebooks * cfg.vocab_size),
            dtype, fan_in=cfg.d_model)
    return p


# ---------------------------------------------------------------------------
# embedding / head
# ---------------------------------------------------------------------------

def embed_tokens(cfg: ArchConfig, p: Params, tokens: jax.Array,
                 frontend_embed: Optional[jax.Array] = None) -> jax.Array:
    if cfg.num_codebooks > 1:
        # tokens: [b, s, cb] — sum per-codebook embeddings (musicgen)
        parts = [jnp.take(p["embed"][i], tokens[..., i], axis=0)
                 for i in range(cfg.num_codebooks)]
        h = sum(parts)
    else:
        h = jnp.take(p["embed"], tokens, axis=0)
    if cfg.frontend == "vlm_stub" and frontend_embed is not None:
        # stub frontend: precomputed patch embeddings occupy the prefix
        fe = frontend_embed.astype(h.dtype) @ p["frontend_proj"]
        h = jax.lax.dynamic_update_slice(h, fe, (0, 0, 0))
    return h


def lm_head(cfg: ArchConfig, p: Params, h: jax.Array) -> jax.Array:
    """h: [b, s, d] -> logits [b, s, V] (or [b, s, cb, V])."""
    if cfg.tie_embeddings:
        w = p["embed"].T  # [d, V]
        logits = h @ w
    else:
        logits = h @ p["lm_head"]
    if cfg.num_codebooks > 1:
        b, s, _ = h.shape
        logits = logits.reshape(b, s, cfg.num_codebooks, cfg.vocab_size)
    return logits


# ---------------------------------------------------------------------------
# forward (training / prefill)
# ---------------------------------------------------------------------------

def _attn_mlp_layer(cfg: ArchConfig, plan: ParallelPlan, h, lp, positions,
                    attn_chunk: int):
    dp = plan.dp
    h = plan.constrain(h, dp, None, None)
    a = L.attention_block(cfg, lp["attn"], L.rms_norm(h, lp["ln1"],
                                                      cfg.norm_eps),
                          positions, chunk=attn_chunk)
    h = h + a
    x = L.rms_norm(h, lp["ln2"], cfg.norm_eps)
    if cfg.is_moe:
        m, aux = moe_block(cfg, lp["moe"], x, mesh=plan.mesh,
                           dp_axes=plan.dp_axes, tp_axis=plan.tp_axis)
    else:
        m, aux = L.mlp_block(cfg, lp["mlp"], x), jnp.float32(0)
    return h + m, aux


def _shared_attn_block(cfg: ArchConfig, plan: ParallelPlan, h, h0, sp,
                       positions, attn_chunk: int):
    """Zamba-style shared block on concat([h, h0])."""
    x = jnp.concatenate([h, h0], axis=-1) @ sp["w_concat"]
    a = L.attention_block(cfg, sp["attn"],
                          L.rms_norm(x, sp["ln1"], cfg.norm_eps),
                          positions, chunk=attn_chunk)
    x = x + a
    m = L.mlp_block(cfg, sp["mlp"], L.rms_norm(x, sp["ln2"], cfg.norm_eps))
    return h + x + m


def forward(
    cfg: ArchConfig,
    p: Params,
    tokens: jax.Array,
    frontend_embed: Optional[jax.Array] = None,
    *,
    plan: ParallelPlan = SINGLE_DEVICE,
    remat: bool = True,
    attn_chunk: int = 1024,
) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence forward.  Returns (hidden [b,s,d], moe_aux scalar)."""
    h = embed_tokens(cfg, p, tokens, frontend_embed)
    b, s, _ = h.shape
    positions = jnp.arange(s)
    dp = plan.dp

    if cfg.family in ("dense", "vlm", "audio", "moe"):
        def body(carry, lp):
            h, aux = carry
            h, a = _attn_mlp_layer(cfg, plan, h, lp, positions, attn_chunk)
            return (h, aux + a), None

        body = jax.checkpoint(body) if remat else body
        (h, aux), _ = jax.lax.scan(body, (h, jnp.float32(0)), p["layers"])
    elif cfg.family == "ssm":
        def body(h, lp):
            h = plan.constrain(h, dp, None, None)
            h = h + mamba_block(cfg, lp["mamba"],
                                L.rms_norm(h, lp["ln"], cfg.norm_eps))
            return h, None

        body = jax.checkpoint(body) if remat else body
        h, _ = jax.lax.scan(body, h, p["layers"])
        aux = jnp.float32(0)
    elif cfg.family == "hybrid":
        h0 = h
        k = cfg.attn_every
        n_groups, tail = cfg.num_layers // k, cfg.num_layers % k
        main = jax.tree_util.tree_map(
            lambda x: x[: n_groups * k].reshape(n_groups, k, *x.shape[1:]),
            p["layers"])
        tail_layers = jax.tree_util.tree_map(
            lambda x: x[n_groups * k:], p["layers"])

        def mamba_one(h, lp):
            h = plan.constrain(h, dp, None, None)
            h = h + mamba_block(cfg, lp["mamba"],
                                L.rms_norm(h, lp["ln"], cfg.norm_eps))
            return h, None

        def group_body(h, glp):
            h, _ = jax.lax.scan(mamba_one, h, glp)
            h = _shared_attn_block(cfg, plan, h, h0, p["shared"],
                                   positions, attn_chunk)
            return h, None

        group_body = jax.checkpoint(group_body) if remat else group_body
        h, _ = jax.lax.scan(group_body, h, main)
        if tail:
            h, _ = jax.lax.scan(mamba_one, h, tail_layers)
        aux = jnp.float32(0)
    else:
        raise ValueError(cfg.family)

    h = plan.constrain(h, dp, None, None)
    return L.rms_norm(h, p["final_norm"], cfg.norm_eps), aux


# ---------------------------------------------------------------------------
# loss (sequence-chunked cross-entropy so fp32 logits never materialize
# for the full sequence at once)
# ---------------------------------------------------------------------------

def token_loss(cfg: ArchConfig, p: Params, h: jax.Array,
               targets: jax.Array, *, loss_chunk: int = 512,
               plan: ParallelPlan = SINGLE_DEVICE) -> jax.Array:
    b, s, d = h.shape
    loss_chunk = min(loss_chunk, s)
    assert s % loss_chunk == 0
    nc = s // loss_chunk
    hr = jnp.moveaxis(h.reshape(b, nc, loss_chunk, d), 1, 0)
    if cfg.num_codebooks > 1:
        tr = jnp.moveaxis(
            targets.reshape(b, nc, loss_chunk, cfg.num_codebooks), 1, 0)
    else:
        tr = jnp.moveaxis(targets.reshape(b, nc, loss_chunk), 1, 0)
    # VLM: no next-token loss on stub image-patch positions
    if cfg.frontend == "vlm_stub":
        valid = (jnp.arange(s) >= cfg.frontend_tokens).astype(jnp.float32)
    else:
        valid = jnp.ones((s,), jnp.float32)
    vr = jnp.moveaxis(valid.reshape(1, nc, loss_chunk), 1, 0)

    def body(acc, xs):
        h_c, t_c, v_c = xs
        logits = lm_head(cfg, p, h_c).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, t_c[..., None],
                                   axis=-1)[..., 0]
        nll = logz - gold                       # [b, c] or [b, c, cb]
        if cfg.num_codebooks > 1:
            nll = nll.mean(-1)
        return acc + jnp.sum(nll * v_c), None

    total, _ = jax.lax.scan(body, jnp.float32(0), (hr, tr, vr))
    denom = jnp.maximum(valid.sum() * b, 1.0)
    return total / denom
