"""Prefill + single-token decode paths (serve_step) for every family.

Caches are explicit pytrees of arrays (inputs AND outputs of the jitted
step, donated by the serving loop):

  dense/moe/vlm/audio: {"k","v": [L, b, S_max, kv, hd]}
  ssm:                 {"conv": [L, b, ck-1, conv_dim],
                        "ssm":  [L, b, H, N, P] fp32}
  hybrid:              ssm caches + {"k","v": [A, b, S_max, kv, hd]}
                       (A = one KV cache per shared-attn application —
                       weights are shared, KV is not)

``pos`` is the per-sequence write position ([b] int32); the engine owns
its increment.  The recurrent state of SSM archs is the branchable
BR_MEMORY domain (DESIGN §6): forking a generation branch copies one
small state tensor instead of KV pages.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.mesh import ParallelPlan, SINGLE_DEVICE
from repro.models import layers as L
from repro.models.moe import moe_block
from repro.models.ssm import (
    causal_conv1d,
    mamba_decode_block,
    ssd_chunked,
    _split_proj,
    _split_xbc,
)
from repro.models.transformer import (
    _shared_attn_block,
    embed_tokens,
    lm_head,
)

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# cache specs
# ---------------------------------------------------------------------------

def decode_state_specs(cfg: ArchConfig, batch: int, max_len: int
                       ) -> Dict[str, jax.ShapeDtypeStruct]:
    dt = jnp.dtype(cfg.dtype)
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    out: Dict[str, jax.ShapeDtypeStruct] = {}
    if cfg.family in ("dense", "vlm", "audio", "moe"):
        shape = (cfg.num_layers, batch, max_len, kv, hd)
        out["k"] = jax.ShapeDtypeStruct(shape, dt)
        out["v"] = jax.ShapeDtypeStruct(shape, dt)
    if cfg.family in ("ssm", "hybrid"):
        ck, cdim = cfg.ssm_conv_kernel, cfg.ssm_conv_dim
        H, N, Pd = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim
        out["conv"] = jax.ShapeDtypeStruct(
            (cfg.num_layers, batch, ck - 1, cdim), dt)
        out["ssm"] = jax.ShapeDtypeStruct(
            (cfg.num_layers, batch, H, N, Pd), jnp.float32)
    if cfg.family == "hybrid":
        n_apps = cfg.num_layers // cfg.attn_every
        shape = (n_apps, batch, max_len, kv, hd)
        out["k"] = jax.ShapeDtypeStruct(shape, dt)
        out["v"] = jax.ShapeDtypeStruct(shape, dt)
    return out


def init_decode_state(cfg: ArchConfig, batch: int, max_len: int
                      ) -> Dict[str, jax.Array]:
    return {k: jnp.zeros(v.shape, v.dtype)
            for k, v in decode_state_specs(cfg, batch, max_len).items()}


# ---------------------------------------------------------------------------
# decode step
# ---------------------------------------------------------------------------

def decode_step(
    cfg: ArchConfig,
    p: Params,
    cache: Dict[str, jax.Array],
    tokens: jax.Array,          # [b, 1] (or [b, 1, cb])
    pos: jax.Array,             # [b]
    *,
    plan: ParallelPlan = SINGLE_DEVICE,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One new token for every sequence.  Returns (logits, new_cache)."""
    h = embed_tokens(cfg, p, tokens)
    dp = plan.dp
    h = plan.constrain(h, dp, None, None)
    new_cache = dict(cache)

    if cfg.family in ("dense", "vlm", "audio", "moe"):
        def body(h, xs):
            lp, kc, vc = xs
            x = L.rms_norm(h, lp["ln1"], cfg.norm_eps)
            a, kc, vc = L.attention_decode_block(cfg, lp["attn"], x, pos,
                                                 kc, vc)
            h = h + a
            x = L.rms_norm(h, lp["ln2"], cfg.norm_eps)
            if cfg.is_moe:
                m, _ = moe_block(cfg, lp["moe"], x, mesh=plan.mesh,
                                 dp_axes=plan.dp_axes, tp_axis=plan.tp_axis)
            else:
                m = L.mlp_block(cfg, lp["mlp"], x)
            return h + m, (kc, vc)

        h, (k_new, v_new) = jax.lax.scan(
            body, h, (p["layers"], cache["k"], cache["v"]))
        new_cache["k"], new_cache["v"] = k_new, v_new

    elif cfg.family == "ssm":
        def body(h, xs):
            lp, conv, ssm = xs
            x = L.rms_norm(h, lp["ln"], cfg.norm_eps)
            y, conv, ssm = mamba_decode_block(cfg, lp["mamba"], x, conv, ssm)
            return h + y, (conv, ssm)

        h, (conv_new, ssm_new) = jax.lax.scan(
            body, h, (p["layers"], cache["conv"], cache["ssm"]))
        new_cache["conv"], new_cache["ssm"] = conv_new, ssm_new

    elif cfg.family == "hybrid":
        # h0 for the shared block: the embedding output of THIS token,
        # plus the engine-maintained running h0 convention: zamba feeds
        # the current token's embedding — use it directly.
        h0 = h
        k = cfg.attn_every
        n_groups = cfg.num_layers // k
        tail_n = cfg.num_layers % k

        def regroup(x):
            return x[: n_groups * k].reshape(n_groups, k, *x.shape[1:])

        main_lp = jax.tree_util.tree_map(regroup, p["layers"])
        tail_lp = jax.tree_util.tree_map(
            lambda x: x[n_groups * k:], p["layers"])
        main_conv, tail_conv = (regroup(cache["conv"]),
                                cache["conv"][n_groups * k:])
        main_ssm, tail_ssm = (regroup(cache["ssm"]),
                              cache["ssm"][n_groups * k:])

        def mamba_one(h, xs):
            lp, conv, ssm = xs
            x = L.rms_norm(h, lp["ln"], cfg.norm_eps)
            y, conv, ssm = mamba_decode_block(cfg, lp["mamba"], x, conv, ssm)
            return h + y, (conv, ssm)

        def group_body(h, xs):
            glp, gconv, gssm, kc, vc = xs
            h, (gconv, gssm) = jax.lax.scan(mamba_one, h,
                                            (glp, gconv, gssm))
            # shared attention with decode KV cache
            x = jnp.concatenate([h, h0], axis=-1) @ p["shared"]["w_concat"]
            xa = L.rms_norm(x, p["shared"]["ln1"], cfg.norm_eps)
            a, kc, vc = L.attention_decode_block(cfg, p["shared"]["attn"],
                                                 xa, pos, kc, vc)
            x = x + a
            m = L.mlp_block(cfg, p["shared"]["mlp"],
                            L.rms_norm(x, p["shared"]["ln2"], cfg.norm_eps))
            return h + x + m, (gconv, gssm, kc, vc)

        h, (g_conv, g_ssm, k_new, v_new) = jax.lax.scan(
            group_body, h, (main_lp, main_conv, main_ssm,
                            cache["k"], cache["v"]))
        conv_out = [g_conv.reshape(n_groups * k, *g_conv.shape[2:])]
        ssm_out = [g_ssm.reshape(n_groups * k, *g_ssm.shape[2:])]
        if tail_n:
            h, (tc, ts) = jax.lax.scan(mamba_one, h,
                                       (tail_lp, tail_conv, tail_ssm))
            conv_out.append(tc)
            ssm_out.append(ts)
        new_cache["conv"] = jnp.concatenate(conv_out, axis=0)
        new_cache["ssm"] = jnp.concatenate(ssm_out, axis=0)
        new_cache["k"], new_cache["v"] = k_new, v_new
    else:
        raise ValueError(cfg.family)

    h = L.rms_norm(h, p["final_norm"], cfg.norm_eps)
    return lm_head(cfg, p, h), new_cache


# ---------------------------------------------------------------------------
# prefill
# ---------------------------------------------------------------------------

def prefill(
    cfg: ArchConfig,
    p: Params,
    tokens: jax.Array,
    frontend_embed: Optional[jax.Array] = None,
    *,
    max_len: Optional[int] = None,
    plan: ParallelPlan = SINGLE_DEVICE,
    attn_chunk: int = 1024,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Process the prompt; returns (last-position logits, decode cache)."""
    b, s = tokens.shape[:2]
    max_len = max_len or s
    pad = max_len - s
    assert pad >= 0
    h = embed_tokens(cfg, p, tokens, frontend_embed)
    positions = jnp.arange(s)
    dp = plan.dp
    h = plan.constrain(h, dp, None, None)
    cache: Dict[str, jax.Array] = {}

    def pad_cache(x):  # [b, s, kv, hd] -> [b, max_len, kv, hd]
        return jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))

    if cfg.family in ("dense", "vlm", "audio", "moe"):
        def body(h, lp):
            x = L.rms_norm(h, lp["ln1"], cfg.norm_eps)
            q, k, v = L.qkv_project(cfg, lp["attn"], x, positions)
            a = L.chunked_causal_attention(q, k, v, chunk=attn_chunk)
            h = h + jnp.einsum("bshk,hkd->bsd", a, lp["attn"]["wo"])
            x = L.rms_norm(h, lp["ln2"], cfg.norm_eps)
            if cfg.is_moe:
                m, _ = moe_block(cfg, lp["moe"], x, mesh=plan.mesh,
                                 dp_axes=plan.dp_axes, tp_axis=plan.tp_axis)
            else:
                m = L.mlp_block(cfg, lp["mlp"], x)
            return h + m, (pad_cache(k), pad_cache(v))

        h, (ks, vs) = jax.lax.scan(body, h, p["layers"])
        cache["k"], cache["v"] = ks, vs

    elif cfg.family == "ssm":
        def body(h, lp):
            x = L.rms_norm(h, lp["ln"], cfg.norm_eps)
            y, conv, ssm = _mamba_prefill(cfg, lp["mamba"], x)
            return h + y, (conv, ssm)

        h, (convs, ssms) = jax.lax.scan(body, h, p["layers"])
        cache["conv"], cache["ssm"] = convs, ssms

    elif cfg.family == "hybrid":
        h0 = h
        k = cfg.attn_every
        n_groups = cfg.num_layers // k
        tail_n = cfg.num_layers % k
        main_lp = jax.tree_util.tree_map(
            lambda x: x[: n_groups * k].reshape(n_groups, k, *x.shape[1:]),
            p["layers"])
        tail_lp = jax.tree_util.tree_map(
            lambda x: x[n_groups * k:], p["layers"])

        def mamba_one(h, lp):
            x = L.rms_norm(h, lp["ln"], cfg.norm_eps)
            y, conv, ssm = _mamba_prefill(cfg, lp["mamba"], x)
            return h + y, (conv, ssm)

        def group_body(h, glp):
            h, (gconv, gssm) = jax.lax.scan(mamba_one, h, glp)
            x = jnp.concatenate([h, h0], axis=-1) @ p["shared"]["w_concat"]
            xa = L.rms_norm(x, p["shared"]["ln1"], cfg.norm_eps)
            q, kk, vv = L.qkv_project(cfg, p["shared"]["attn"], xa,
                                      positions)
            a = L.chunked_causal_attention(q, kk, vv, chunk=attn_chunk)
            x = x + jnp.einsum("bshk,hkd->bsd", a,
                               p["shared"]["attn"]["wo"])
            m = L.mlp_block(cfg, p["shared"]["mlp"],
                            L.rms_norm(x, p["shared"]["ln2"], cfg.norm_eps))
            return h + x + m, (gconv, gssm, pad_cache(kk), pad_cache(vv))

        h, (g_conv, g_ssm, ks, vs) = jax.lax.scan(group_body, h, main_lp)
        conv_out = [g_conv.reshape(n_groups * k, *g_conv.shape[2:])]
        ssm_out = [g_ssm.reshape(n_groups * k, *g_ssm.shape[2:])]
        if tail_n:
            h, (tc, ts) = jax.lax.scan(mamba_one, h, tail_lp)
            conv_out.append(tc)
            ssm_out.append(ts)
        cache["conv"] = jnp.concatenate(conv_out, axis=0)
        cache["ssm"] = jnp.concatenate(ssm_out, axis=0)
        cache["k"], cache["v"] = ks, vs
    else:
        raise ValueError(cfg.family)

    h = L.rms_norm(h, p["final_norm"], cfg.norm_eps)
    return lm_head(cfg, p, h[:, -1:, :]), cache


def _mamba_prefill(cfg: ArchConfig, lp: Params, x: jax.Array):
    """Mamba block that also returns (conv_state, ssm_state)."""
    b, s, _ = x.shape
    di, H, Pd = cfg.ssm_d_inner, cfg.ssm_heads, cfg.ssm_head_dim
    ck = cfg.ssm_conv_kernel
    z, xBC_pre, dt = _split_proj(cfg, x @ lp["in_proj"])
    # conv state = last ck-1 *pre-activation* conv inputs
    conv_state = xBC_pre[:, -(ck - 1):, :]
    xBC = causal_conv1d(xBC_pre, lp["conv_w"], lp["conv_b"])
    xs, B, C = _split_xbc(cfg, xBC)
    xs = xs.reshape(b, s, H, Pd)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + lp["dt_bias"])
    A = -jnp.exp(lp["A_log"])
    y, ssm_state = ssd_chunked(xs, dt, A, B, C, cfg.ssm_chunk)
    y = y + lp["D"].astype(y.dtype)[None, None, :, None] * xs
    from repro.models.layers import gated_rms_norm

    y = gated_rms_norm(y.reshape(b, s, di), z, lp["norm_w"], cfg.norm_eps)
    return y @ lp["out_proj"], conv_state, ssm_state
