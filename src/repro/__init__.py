"""branchx — branch contexts (fork/explore/commit) for JAX/TPU.

Implements *Fork, Explore, Commit: OS Primitives for Agentic
Exploration* (CS.OS 2026) as a production training/serving framework:

* :mod:`repro.core`      — branch contexts over pytrees, paged KV, and
  in-program exploration with first-commit-wins.
* :mod:`repro.fs`        — durable BranchFS (delta checkpoints).
* :mod:`repro.models`    — all 10 assigned architectures.
* :mod:`repro.kernels`   — Pallas TPU kernels (paged attention, flash
  attention, SSD scan) with jnp oracles.
* :mod:`repro.runtime`   — fault-tolerant training, branchable serving.
* :mod:`repro.launch`    — production meshes, multi-pod dry-run,
  roofline analysis.
"""

__version__ = "1.0.0"
