"""branchx — branch contexts (fork/explore/commit) for JAX/TPU.

Implements *Fork, Explore, Commit: OS Primitives for Agentic
Exploration* (CS.OS 2026) as a production training/serving framework:

* :mod:`repro.api`       — **the public surface**: ``BranchSession``
  (``branch()`` with a flags word, fd-style handles, errno discipline),
  epoll-like ``Waiter`` eventing, procfs-style introspection.
* :mod:`repro.core`      — the branch-lifecycle kernel and its state
  domains (pytree store, paged KV), in-program exploration with
  first-commit-wins, and the shared ``Errno`` vocabulary.
* :mod:`repro.fs`        — durable BranchFS (delta checkpoints).
* :mod:`repro.models`    — all 10 assigned architectures.
* :mod:`repro.kernels`   — Pallas TPU kernels (paged attention, flash
  attention, SSD scan) with jnp oracles.
* :mod:`repro.runtime`   — fault-tolerant training, branchable serving.
* :mod:`repro.explore_ctx` — exploration policies (best-of-N, beam,
  tree search, speculative decode) as sugar over ``repro.api``.
* :mod:`repro.server`    — multi-tenant async HTTP/SSE front door
  (quotas, priority preemption, one engine loop for every tenant).
* :mod:`repro.launch`    — production meshes, multi-pod dry-run,
  roofline analysis.
* :mod:`repro.analysis`  — branchlint, the self-hosted protocol
  checker (errno discipline, handle lifecycle, thread boundary, span
  balance, metric hygiene, flag validity).

Submodules are imported lazily (PEP 562) so ``import repro`` stays
cheap; ``__all__`` below is exactly the documented public surface, and
each name resolves on first attribute access.
"""

from importlib import import_module
from typing import Any

__version__ = "1.1.0"

#: the documented public namespace — everything here imports cleanly
__all__ = [
    "__version__",
    "analysis",
    "api",
    "checkpoint",
    "configs",
    "core",
    "data",
    "distributed",
    "explore_ctx",
    "fs",
    "kernels",
    "launch",
    "models",
    "obs",
    "optim",
    "runtime",
    "server",
]


def __getattr__(name: str) -> Any:
    if name in __all__:
        return import_module(f"repro.{name}")
    raise AttributeError(f"module 'repro' has no attribute {name!r}")


def __dir__() -> list:
    return sorted(__all__)
