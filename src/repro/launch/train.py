"""Cluster training entry point.

On a real TPU cluster every host runs::

    python -m repro.launch.train --arch granite-8b --batch 256 --seq 4096

jax.distributed is initialized from the standard TPU environment; the
mesh spans all global devices (multi-pod when the slice topology provides
it); each host's data shard comes from its process index.  On CPU this
runs single-process (useful with --smoke).
"""

from __future__ import annotations

import argparse
import dataclasses

import jax


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--seq", type=int, default=4096)
    ap.add_argument("--steps", type=int, default=1000)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/branchx-ckpt")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--compress-grads", default=None,
                    choices=[None, "int8", "topk"])
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config, CPU-sized")
    ap.add_argument("--distributed", action="store_true",
                    help="initialize jax.distributed (TPU pods)")
    args = ap.parse_args(argv)

    if args.distributed:
        jax.distributed.initialize()

    from repro.checkpoint import CheckpointManager
    from repro.configs import get_config, reduced
    from repro.data import SyntheticLMPipeline
    from repro.models.model import Model
    from repro.optim import adamw, cosine_warmup
    from repro.runtime.elastic import plan_mesh
    from repro.runtime.fault import FaultTolerantTrainer
    from repro.runtime.train_loop import build_train_step, init_train_state
    from repro.distributed.sharding import param_shardings, shard_params

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = dataclasses.replace(reduced(cfg), dtype="float32")
        args.batch, args.seq, args.steps = 2, 32, 10

    n_dev = len(jax.devices())
    plan = plan_mesh(jax.devices()) if n_dev > 1 else None
    model = Model(cfg, plan=plan) if plan else Model(
        cfg, attn_chunk=min(256, args.seq), loss_chunk=min(128, args.seq))

    opt = adamw(cosine_warmup(args.lr, max(args.steps // 20, 1),
                              args.steps))
    step = jax.jit(
        build_train_step(model, opt, accum_steps=args.accum,
                         compress=args.compress_grads),
        donate_argnums=(0,),
    )
    state = init_train_state(model, opt, jax.random.PRNGKey(0),
                             compress=args.compress_grads)
    if plan:
        state = state._replace(
            params=shard_params(cfg, plan, state.params),
            opt_state=jax.tree_util.tree_map(
                jax.device_put, state.opt_state,
                param_shardings(cfg, plan, state.opt_state)))

    shard = jax.process_index()
    data = SyntheticLMPipeline(
        cfg, batch=args.batch // max(jax.process_count(), 1),
        seq=args.seq, seed=7, shard=shard,
        num_shards=max(jax.process_count(), 1))

    trainer = FaultTolerantTrainer(
        step_fn=step, state=state, data=data,
        ckpt=CheckpointManager(args.ckpt_dir),
        ckpt_every=args.ckpt_every)
    trainer.run(args.steps)
    m = trainer.metrics_log[-1]
    print(f"done: step {trainer.steps_done} loss {m['loss']:.4f} "
          f"rollbacks {trainer.rollbacks}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
