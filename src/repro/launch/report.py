"""Generate the EXPERIMENTS.md §Dry-run / §Roofline tables from
``experiments/dryrun/*.json``.

Usage:  python -m repro.launch.report [--dir experiments/dryrun]
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path
from typing import Dict, List, Optional


def load(dir_: Path) -> List[Dict]:
    rows = []
    for f in sorted(dir_.glob("*.json")):
        rows.append(json.loads(f.read_text()))
    return rows


def fmt_bytes(b) -> str:
    if b is None:
        return "—"
    for unit in ("B", "KB", "MB", "GB", "TB", "PB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}EB"


def dryrun_table(rows: List[Dict], mesh: str) -> str:
    out = ["| arch | shape | status | bytes/device | lower+compile (s) | "
           "collectives (count) |",
           "|---|---|---|---|---|---|"]
    for r in rows:
        if r["mesh"] != mesh:
            continue
        if r["status"] == "skip":
            out.append(f"| {r['arch']} | {r['shape']} | SKIP | — | — | "
                       f"{r['reason'][:60]}… |")
            continue
        bpd = r.get("bytes_per_device")
        cc = r.get("coll_counts", {})
        cstr = " ".join(f"{k.split('-')[-1]}×{v}" for k, v in cc.items())
        out.append(
            f"| {r['arch']} | {r['shape']} | ok | {fmt_bytes(bpd)} | "
            f"{r.get('lower_s', 0)}+{r.get('compile_s', 0)} | {cstr} |")
    return "\n".join(out)


def cell_note(r: Dict) -> str:
    """One sentence: what would move the dominant term down."""
    kind = ("train" if r["shape"].startswith("train") else
            "prefill" if r["shape"].startswith("prefill") else "decode")
    b = r["bottleneck"]
    coll = r.get("coll_by_op", {})
    ag = coll.get("all-gather", 0)
    ar = coll.get("all-reduce", 0)
    if b == "collective" and ag >= ar:
        return ("FSDP weight re-gather dominates — fewer/larger "
                "microbatches or TP-resident weights")
    if b == "collective":
        return ("gradient all-reduce dominates — reduce-scatter layout "
                "+ int8 compression (4×) on the cross-pod hop")
    if b == "memory" and kind == "decode":
        return ("KV-cache streaming — paged Pallas kernel removes the "
                "per-layer slice rewrite; int8 KV would halve it")
    if b == "memory" and kind == "train":
        return ("activation traffic (remat recompute + fp32 casts) — "
                "tune accum; flash/SSD kernels keep score tiles in VMEM")
    if b == "memory":
        return ("attention score traffic — flash kernel VMEM residency; "
                "longer attn chunks amortize KV re-reads")
    return "compute-bound — causal block-skip halves attention FLOPs"


def decode_efficiency(r: Dict) -> Optional[float]:
    """Decode roofline: ideal (params+KV once) / achieved memory time."""
    from repro.configs import get_config
    from repro.configs.shapes import SHAPES
    from repro.launch.mesh import HBM_BW

    if not r["shape"].startswith(("decode", "long")):
        return None
    cfg = get_config(r["arch"])
    s = SHAPES[r["shape"]]
    n = (cfg.active_param_count() if cfg.is_moe else cfg.param_count())
    kv = cfg.kv_bytes_per_token() * s.seq_len * s.global_batch
    if cfg.family in ("ssm", "hybrid"):
        kv += (cfg.num_layers * s.global_batch * cfg.ssm_heads
               * cfg.ssm_state * cfg.ssm_head_dim * 4)
    ideal = (2 * n + kv) / (r["chips"] * HBM_BW)
    return ideal / r["t_memory_s"] if r["t_memory_s"] else None


def roofline_table(rows: List[Dict], mesh: str = "single") -> str:
    out = ["| arch | shape | compute s | memory s | collective s | "
           "bottleneck | useful-FLOPs | roofline | note |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["mesh"] != mesh or r["status"] != "ok":
            continue
        rf = r["roofline_fraction"]
        de = decode_efficiency(r)
        rf_str = (f"{rf:.4f}" if de is None
                  else f"{de:.4f} (mem-ideal)")
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.4f} | "
            f"{r['t_memory_s']:.4f} | {r['t_collective_s']:.4f} | "
            f"{r['bottleneck']} | {r['useful_flops_ratio']:.3f} | "
            f"{rf_str} | {cell_note(r)} |")
    return "\n".join(out)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    args = ap.parse_args(argv)
    rows = load(Path(args.dir))
    print("## Dry-run (single-pod 16×16 = 256 chips)\n")
    print(dryrun_table(rows, "single"))
    print("\n## Dry-run (multi-pod 2×16×16 = 512 chips)\n")
    print(dryrun_table(rows, "multi"))
    print("\n## Roofline (single-pod)\n")
    print(roofline_table(rows, "single"))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
