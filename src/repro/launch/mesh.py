"""Production meshes (assigned): 16×16 single pod, 2×16×16 multi-pod.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    # the dry-run host exposes 512 placeholder devices; the single-pod
    # mesh uses the first 256
    devices = jax.devices()[:n]
    return jax.make_mesh(shape, axes, devices=devices)


# TPU v5e hardware constants for the roofline model
PEAK_FLOPS_BF16 = 197e12        # FLOP/s per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link
