"""Serving entry point: scheduler-driven branchable paged-KV engine.

Demo mode pushes a stream of requests through the :class:`Scheduler`
(admission + continuous batching) with N-way agentic exploration per
prompt: fork (page-budget-aware), decode branches in the running batch,
score, first-commit-wins commit::

    python -m repro.launch.serve --arch paper-agentic --branches 3
"""

from __future__ import annotations

import argparse
import dataclasses

import jax
import numpy as np


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper-agentic")
    ap.add_argument("--branches", type=int, default=3)
    ap.add_argument("--tokens", type=int, default=8)
    ap.add_argument("--requests", type=int, default=2)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=2.0)
    args = ap.parse_args(argv)

    from repro.configs import get_config, reduced
    from repro.models.model import Model
    from repro.runtime.scheduler import (
        AdmissionDenied, Scheduler, SchedulerConfig)
    from repro.runtime.serve_loop import ServeEngine

    cfg = get_config(args.arch)
    if cfg.param_count() > 1e8:  # big archs run reduced on CPU demo
        cfg = reduced(cfg)
    cfg = dataclasses.replace(cfg, dtype="float32")
    model = Model(cfg, attn_chunk=8, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, num_pages=1024, page_size=8,
                         max_pages_per_seq=64)
    sched = Scheduler(engine, SchedulerConfig(max_batch=args.max_batch))

    key = jax.random.PRNGKey(1)
    roots = {}
    for r in range(args.requests):
        prompt = [int(t) for t in np.random.default_rng(r).integers(
            1, cfg.vocab_size, size=6)]
        # decode budget covers the exploration tokens; the scheduler
        # admits when the page pool can hold prompt + reserve
        rid = sched.submit(prompt, max_new_tokens=args.tokens + 1)
        roots[rid] = prompt
    sched.admit()

    for rid, prompt in roots.items():
        try:
            root = sched.seq_of(rid)
        except Exception as e:
            print(f"request {rid}: not admitted ({e}); skipped")
            continue
        try:
            branches = sched.fork(root, args.branches)
        except AdmissionDenied as e:
            print(f"request {rid}: fork denied ({e}); decoding unforked")
            branches = [root]
        for _ in range(args.tokens):
            key, k = jax.random.split(key)
            engine.decode(branches, greedy=False,
                          temperature=args.temperature, key=k)
        scores = [float(np.mean(engine.tokens(b)[len(prompt):]))
                  for b in branches]
        best = branches[int(np.argmax(scores))]
        if best != root:
            engine.commit(best)
        print(f"request {rid}: prompt {prompt} -> "
              f"{engine.tokens(root)[len(prompt):]} "
              f"(best of {len(branches)}, scores {scores})")
    print(f"scheduler stats: {sched.stats()}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
