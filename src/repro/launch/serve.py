"""Serving entry point: the ``repro.api`` surface end to end.

Demo mode pushes a stream of requests through the exploration driver
over one :class:`~repro.api.BranchSession`: every prompt runs a
concurrent best-of-N policy (vectorized ``branch()`` through page-budget
admission, decode branches in the shared continuous batch, score,
first-commit-wins commit; graceful unforked degradation under page
pressure), then prints the session's procfs-style ``tree()`` view::

    python -m repro.launch.serve --arch paper-agentic --branches 3

``--tp N`` runs the decode hot loop tensor-parallel over an N-device
serving mesh (DESIGN §11) — weights and KV pages shard, branch
bookkeeping stays host-side, and the served tokens are identical to
``--tp 1`` for the same seed.  On a CPU-only host, force devices with
``XLA_FLAGS=--xla_force_host_platform_device_count=N``.

``--serve host:port`` starts the multi-tenant HTTP/SSE front door
(DESIGN §14) instead of the demo: one engine loop serves every tenant's
``/v1/generate`` and ``/v1/explore`` traffic until SIGINT/SIGTERM, then
drains gracefully (in-flight decodes finish; parked reservations are
evicted) and exits 0.  ``--tenants name:max_concurrent:priority,...``
registers tenant classes::

    python -m repro.launch.serve --serve 127.0.0.1:8777 \\
        --tenants vip:16:3,batch:32:1
"""

from __future__ import annotations

import argparse
import dataclasses

import jax
import numpy as np


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper-agentic")
    ap.add_argument("--branches", type=int, default=3)
    ap.add_argument("--tokens", type=int, default=8)
    ap.add_argument("--requests", type=int, default=2)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=2.0)
    ap.add_argument("--tp", type=int, default=None,
                    help="tensor-parallel width of the serving mesh "
                         "(default: single-device)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="record per-branch lifecycle spans and write a "
                         "Chrome/Perfetto trace.json here on exit "
                         "(also prints the one-screen metrics summary)")
    ap.add_argument("--serve", default=None, metavar="HOST:PORT",
                    help="run the multi-tenant HTTP/SSE front door "
                         "instead of the demo (SIGINT/SIGTERM drains "
                         "gracefully)")
    ap.add_argument("--tenants", default=None,
                    metavar="NAME:MAX_CONCURRENT:PRIORITY,...",
                    help="tenant classes for --serve (unknown tenants "
                         "get the default class)")
    ap.add_argument("--num-pages", type=int, default=1024,
                    help="KV page-pool size (default 1024)")
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="disable cross-request KV prefix sharing "
                         "(on by default: identical prompt prefixes "
                         "share read-only CoW pages, so best-of-N from "
                         "N users costs one prefill)")
    args = ap.parse_args(argv)

    from repro.api import BranchSession
    from repro.configs import get_config, reduced
    from repro.explore_ctx import ExplorationDriver, best_of_n
    from repro.models.model import Model
    from repro.obs import Observability
    from repro.runtime.serve_loop import ServeEngine

    cfg = get_config(args.arch)
    if cfg.param_count() > 1e8:  # big archs run reduced on CPU demo
        cfg = reduced(cfg)
    cfg = dataclasses.replace(cfg, dtype="float32")
    model = Model(cfg, attn_chunk=8, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, num_pages=args.num_pages,
                         page_size=8, max_pages_per_seq=64, tp=args.tp,
                         prefix_cache=not args.no_prefix_cache,
                         obs=Observability(trace=args.trace is not None))
    session = BranchSession(engine, max_batch=args.max_batch, seed=1)
    if session.tp > 1:
        print(f"serving mesh: tp={session.tp} over "
              f"{len(jax.devices())} devices")
    if args.serve:
        return _serve_front_door(session, args)
    driver = ExplorationDriver(session)

    prompts = {}
    for r in range(args.requests):
        prompt = [int(t) for t in np.random.default_rng(r).integers(
            1, cfg.vocab_size, size=6)]
        exp = driver.explore(prompt, max_new_tokens=args.tokens + 1,
                             policy=best_of_n, n=args.branches,
                             tokens=args.tokens,
                             temperature=args.temperature,
                             name=f"request-{r}")
        prompts[exp] = prompt
    # an infeasible request fails only its own exploration: report it
    # per-request (as the pre-driver demo did) and serve the rest
    driver.run(raise_errors=False)

    for r, (exp, prompt) in enumerate(prompts.items()):
        if exp.error is not None:
            print(f"request {r}: not served ({exp.error}); skipped")
            continue
        res = exp.result
        scores = [f"{s:.1f}" for s in res.stats.get("scores", [])]
        note = " (degraded: page pressure)" if res.stats.get("degraded") \
            else ""
        print(f"request {r}: prompt {prompt} -> {res.generated} "
              f"(best of {res.stats.get('branches', 0)}, "
              f"scores {scores}){note}")
    print("session tree (procfs view):")
    print(session.format_tree(metrics=args.trace is not None))
    if args.trace:
        session.trace(args.trace)
        print(f"wrote {args.trace} — open at https://ui.perfetto.dev")
    return 0


def _parse_tenants(spec):
    """``name:max_concurrent:priority,...`` → TenantConfig list."""
    from repro.server import TenantConfig

    out = []
    for part in (spec or "").split(","):
        part = part.strip()
        if not part:
            continue
        fields = part.split(":")
        name = fields[0]
        max_conc = int(fields[1]) if len(fields) > 1 else 16
        priority = int(fields[2]) if len(fields) > 2 else 1
        out.append(TenantConfig(name, max_concurrent=max_conc,
                                priority=priority))
    return out


def _serve_front_door(session, args) -> int:
    import asyncio
    import signal

    from repro.server import FrontDoor

    host, _, port = args.serve.rpartition(":")
    host = host or "127.0.0.1"
    fd = FrontDoor(session, _parse_tenants(args.tenants))

    async def run() -> None:
        server = await fd.serve(host, int(port))
        addr = server.sockets[0].getsockname()
        print(f"serving on http://{addr[0]}:{addr[1]} "
              f"(tenants: {[t.name for t in fd.tenancy.tenants()]})",
              flush=True)
        stop = asyncio.Event()
        loop = asyncio.get_running_loop()
        for sig in (signal.SIGINT, signal.SIGTERM):
            loop.add_signal_handler(sig, stop.set)
        await stop.wait()
        print("draining...", flush=True)
        stats = await fd.shutdown(drain=True)
        print(f"drained cleanly ({stats['evicted']} parked/stale "
              "evicted)", flush=True)
        if args.trace:
            session.trace(args.trace)
            print(f"wrote {args.trace}", flush=True)

    asyncio.run(run())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
