import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

"""Per-op cost profile of one dry-run cell — the §Perf 'profiler'.

Prints the top contributors to the memory term (bytes by op × shape),
the compute term (flops by dot shape), and the collective term (bytes by
collective × shape), with trip-count multiplication.  Hypotheses in
EXPERIMENTS.md §Perf are formed against this output.

Usage:
  python -m repro.launch.profile_cell --arch granite-8b \
      --shape decode_32k --mesh single [--top 20] [--override '{...}']
"""

import argparse
import json
from collections import Counter


def profile(arch: str, shape: str, mesh: str, top: int = 20,
            overrides=None):
    from repro.launch import hlo_costs as H
    from repro.launch.dryrun import build_lowered

    lowered, mesh_obj, cfg, skip = build_lowered(arch, shape, mesh,
                                                 overrides)
    if lowered is None:
        print(f"SKIP: {skip}")
        return
    compiled = lowered.compile()
    comps = H.parse_hlo(compiled.as_text())
    entry = comps["__entry__"]

    bytes_by = Counter()
    flops_by = Counter()
    coll_by = Counter()

    def visit(comp, mult, depth=0):
        if depth > 24:
            return
        for inst in comp.instrs:
            shape0 = inst.out_shapes[0] if inst.out_shapes else ("?", ())
            tag = "VMEM/" if inst.vmem_tagged else ""
            key = f"{tag}{inst.op} {shape0[0]}{list(shape0[1])}"
            bytes_by[key] += H.inst_bytes(comps, comp, inst) * mult
            if inst.op == "dot":
                flops_by[key] += H._dot_flops(comp, inst) * mult
            opn = inst.op[:-6] if inst.op.endswith("-start") else inst.op
            if opn in H._COLLECTIVES:
                coll_by[key] += H._nbytes(inst.out_shapes) * mult
            if inst.op == "while" and inst.while_body in comps:
                visit(comps[inst.while_body],
                      mult * (inst.trip_count or 1), depth + 1)
    visit(entry, 1.0)

    print(f"=== {arch} × {shape} × {mesh} per-device profile ===")
    print(f"-- top {top} bytes (GB, per device per step) --")
    for k, v in bytes_by.most_common(top):
        print(f"  {v / 1e9:10.2f}  {k}")
    print(f"-- top {top} dot flops (GFLOP, per device) --")
    for k, v in flops_by.most_common(top):
        print(f"  {v / 1e9:10.2f}  {k}")
    print(f"-- top {top} collective bytes (GB, per device) --")
    for k, v in coll_by.most_common(top):
        print(f"  {v / 1e9:10.2f}  {k}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--top", type=int, default=20)
    ap.add_argument("--override", default="")
    args = ap.parse_args(argv)
    profile(args.arch, args.shape, args.mesh, args.top,
            json.loads(args.override) if args.override else None)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
