"""Roofline-term extraction from a compiled dry-run artifact.

Three terms per (arch × shape × mesh), all in seconds:

  compute    = HLO_FLOPs            / (chips × peak_FLOP/s)
  memory     = HLO_bytes_accessed   / (chips × HBM_bw)
  collective = collective_bytes     / (chips × link_bw)

``cost_analysis`` supplies FLOPs and bytes; collective bytes are NOT in
cost_analysis, so :func:`collective_bytes` parses the post-partitioning
HLO text and sums the operand sizes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute op.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

# e.g. "bf16[16,512,4096]{2,1,0}" or "f32[128]"
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")

_COLLECTIVE_OPS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# "%name = TYPE[SHAPE] op-name(", with optional leading spaces/ROOT
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)"
)


def _shape_bytes(shape_str: str) -> int:
    """Bytes of one 'dtype[d0,d1,...]' shape string (0 if not parseable)."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    bytes_by_op: Dict[str, int] = field(default_factory=dict)
    count_by_op: Dict[str, int] = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_op.values())


def collective_bytes(hlo_text: str) -> CollectiveStats:
    """Sum output-shape bytes of every collective in the (SPMD) HLO.

    The output shape of all-gather / all-to-all / permute equals the
    moved payload per participating device; for all-reduce and
    reduce-scatter the output is the standard accounting of the payload a
    device contributes.  'start' variants are counted; 'done' variants
    are skipped (same tensor, avoids double counting).
    """
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2)
        base = None
        for c in _COLLECTIVE_OPS:
            if op == c or op == c + "-start":
                base = c
                break
        if base is None:
            continue
        nbytes = _shape_bytes(shape_str)
        stats.bytes_by_op[base] = stats.bytes_by_op.get(base, 0) + nbytes
        stats.count_by_op[base] = stats.count_by_op.get(base, 0) + 1
    return stats


# ---------------------------------------------------------------------------


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float          # HBM-model bytes (kernel-resident removed)
    coll_bytes: float
    coll_by_op: Dict[str, int]
    model_flops: float
    bytes_per_device: Optional[float]
    hlo_bytes_raw: Optional[float] = None   # including kernel-resident
    bytes_vmem_tagged: Optional[float] = None

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / (self.chips * PEAK_FLOPS_BF16)

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / (self.chips * ICI_BW)

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """useful work / achievable step time: MODEL_FLOPS/(chips·peak)
        over the max roofline term — the score reported in §Perf."""
        t_use = self.model_flops / (self.chips * PEAK_FLOPS_BF16)
        t_step = max(self.t_compute, self.t_memory, self.t_collective)
        return t_use / t_step if t_step else 0.0

    def to_dict(self) -> Dict[str, Any]:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "hlo_flops": self.hlo_flops, "hlo_bytes": self.hlo_bytes,
            "hlo_bytes_raw": self.hlo_bytes_raw,
            "bytes_vmem_tagged": self.bytes_vmem_tagged,
            "coll_bytes": self.coll_bytes, "coll_by_op": self.coll_by_op,
            "model_flops": self.model_flops,
            "bytes_per_device": self.bytes_per_device,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def extract_cost(compiled) -> Tuple[float, float]:
    """(flops, bytes accessed) from compiled.cost_analysis()."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    # cpu backend reports 'bytes accessed'; some report per-space keys
    byts = float(ca.get("bytes accessed", 0.0))
    if byts == 0.0:
        byts = sum(float(v) for k, v in ca.items()
                   if k.startswith("bytes accessed"))
    return flops, byts


def extract_memory(compiled) -> Optional[float]:
    """Per-device bytes from memory_analysis(), if the backend reports it."""
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return None
    if ma is None:
        return None
    try:
        return float(ma.argument_size_in_bytes
                     + ma.output_size_in_bytes
                     + ma.temp_size_in_bytes
                     + ma.generated_code_size_in_bytes)
    except AttributeError:
        return None


def model_flops_for(cfg, shape_spec, kind: str) -> float:
    """MODEL_FLOPS = 6·N·D (train) or 2·N·D (inference), N = active params.

    D = tokens processed by the step: B·S for train/prefill, B for decode.
    """
    n = cfg.active_param_count() if cfg.is_moe else cfg.param_count()
    if kind == "train":
        d = shape_spec.global_batch * shape_spec.seq_len
        return 6.0 * n * d
    if kind == "prefill":
        d = shape_spec.global_batch * shape_spec.seq_len
        return 2.0 * n * d
    # decode: one token per sequence
    return 2.0 * n * shape_spec.global_batch
