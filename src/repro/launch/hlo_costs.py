"""Trip-count-aware cost accounting over optimized (post-SPMD) HLO text.

XLA's built-in ``cost_analysis()`` counts a ``while`` body ONCE, which
under-counts scan-over-layers programs by a factor of L — useless for a
roofline.  This parser builds a per-computation symbol table (operand
shapes are not inlined in optimized HLO), then walks the call graph with
multipliers:

* ``while`` bodies multiply by ``backend_config known_trip_count``;
* ``fusion``/``call``/``to_apply`` descend with multiplier 1 for FLOPs,
  but contribute bytes only at the callsite (fusion internals never touch
  HBM — the memory model a roofline wants);
* FLOPs = 2·prod(output dims)·prod(contracted dims) per ``dot`` (matmuls
  dominate; elementwise FLOPs are noise at roofline granularity);
* bytes accessed = operand bytes + output bytes per top-level
  instruction;
* collective bytes = output-shape bytes of every all-gather / all-reduce
  / reduce-scatter / all-to-all / collective-permute (-start variants
  counted, -done skipped).

All numbers are PER-DEVICE (the SPMD module is per-device); the roofline
formulas multiply by chip count where needed.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

Shape = Tuple[str, Tuple[int, ...]]

_SHAPE_RE = re.compile(r"\b([a-z]\w*)\[([0-9,]*)\]")
_COMP_HEAD_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=")
_OP_RE = re.compile(r"=\s*(.*?)\s+([\w\-]+)\(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*"?(\d+)"?')
_LHS_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_PARAM_RE = re.compile(r"%?([\w.\-]+)\s*:\s*")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shapes_in(s: str) -> List[Shape]:
    out = []
    for m in _SHAPE_RE.finditer(s):
        dt = m.group(1)
        if dt not in _DTYPE_BYTES:
            continue
        dims = tuple(int(d) for d in m.group(2).split(",")) \
            if m.group(2) else ()
        out.append((dt, dims))
    return out


def _nbytes(shapes: List[Shape]) -> int:
    total = 0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class _Instr:
    name: str
    op: str
    out_shapes: List[Shape]
    operands: List[str]
    attr_str: str
    calls: List[str]
    while_body: Optional[str] = None
    trip_count: Optional[int] = None
    vmem_tagged: bool = False  # would live in VMEM under the Pallas kernel


@dataclass
class _Computation:
    name: str
    symbols: Dict[str, List[Shape]] = field(default_factory=dict)
    instrs: List[_Instr] = field(default_factory=list)
    param_order: List[str] = field(default_factory=list)


def _parse_instr(line: str) -> Optional[_Instr]:
    core = line.split(" metadata=")[0]
    dm = _DEF_RE.match(core)
    if dm is None:
        return None
    name = dm.group(1)
    m = _OP_RE.search(core)
    if not m:
        return None
    out_str, op = m.group(1), m.group(2)
    out_shapes = _shapes_in(out_str)
    _, _, rhs = core.partition(f" {op}(")
    depth, end = 0, len(rhs)
    for i, ch in enumerate(rhs):
        if ch == "(":
            depth += 1
        elif ch == ")":
            if depth == 0:
                end = i
                break
            depth -= 1
    operand_str, attr_str = rhs[:end], rhs[end:]
    operands = _OPERAND_RE.findall(operand_str)

    inst = _Instr(name=name, op=op, out_shapes=out_shapes,
                  operands=operands, attr_str=attr_str,
                  calls=_CALLS_RE.findall(attr_str),
                  vmem_tagged="vmem_resident" in line)
    if op == "while":
        bm = _BODY_RE.search(attr_str)
        inst.while_body = bm.group(1) if bm else None
        tm = _TRIP_RE.search(line)
        if tm:
            inst.trip_count = int(tm.group(1))
    return inst


def parse_hlo(text: str) -> Dict[str, _Computation]:
    comps: Dict[str, _Computation] = {}
    cur: Optional[_Computation] = None
    entry = None
    for line in text.splitlines():
        head = _COMP_HEAD_RE.match(line)
        if head:
            cur = _Computation(name=head.group(2))
            comps[cur.name] = cur
            if head.group(1):
                entry = cur.name
            # header params: "name: shape, name: (tuple...)"
            params_str = head.group(3)
            for pm in _PARAM_RE.finditer(params_str):
                pname = pm.group(1)
                rest = params_str[pm.end():]
                # shape text until the next ", name:" boundary
                nxt = _PARAM_RE.search(rest)
                shape_txt = rest[: nxt.start()] if nxt else rest
                cur.symbols[pname] = _shapes_in(shape_txt)
                cur.param_order.append(pname)
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is not None:
            inst = _parse_instr(line)
            if inst is not None:
                cur.instrs.append(inst)
                cur.symbols[inst.name] = inst.out_shapes
    if entry is not None:
        comps["__entry__"] = comps[entry]
    return comps


@dataclass
class HloCost:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    bytes_vmem_tagged: float = 0.0  # traffic the Pallas kernels keep on-chip
    coll_bytes_by_op: Dict[str, int] = field(default_factory=dict)
    coll_count_by_op: Dict[str, int] = field(default_factory=dict)
    dot_count: int = 0

    @property
    def bytes_hbm_model(self) -> float:
        """Memory-term bytes with kernel-resident traffic removed."""
        return self.bytes_accessed - self.bytes_vmem_tagged

    @property
    def coll_bytes(self) -> float:
        return float(sum(self.coll_bytes_by_op.values()))

    def add_collective(self, op: str, nbytes: int, mult: float) -> None:
        self.coll_bytes_by_op[op] = (self.coll_bytes_by_op.get(op, 0)
                                     + int(nbytes * mult))
        self.coll_count_by_op[op] = (self.coll_count_by_op.get(op, 0)
                                     + int(round(mult)))


def _dot_flops(comp: _Computation, inst: _Instr) -> float:
    out_elems = 1
    for _, dims in inst.out_shapes:
        for d in dims:
            out_elems *= d
    lhs_shapes = comp.symbols.get(inst.operands[0], []) \
        if inst.operands else []
    lhs_dims = lhs_shapes[0][1] if lhs_shapes else ()
    contracted = 1
    cd = _LHS_CDIMS_RE.search(inst.attr_str)
    if cd and cd.group(1):
        for idx in cd.group(1).split(","):
            i = int(idx)
            if i < len(lhs_dims):
                contracted *= lhs_dims[i]
    return 2.0 * out_elems * contracted


# ops that move no data (metadata / aliasing only)
FREE_OPS = {"get-tuple-element", "tuple", "parameter", "bitcast",
            "after-all", "constant", "reshape", "optimization-barrier",
            "partition-id", "replica-id"}
# ops whose traffic is ~2× their OUTPUT (they touch a slice, not the
# whole operand)
SLICED_OPS = {"dynamic-slice", "slice", "gather", "iota", "broadcast",
              "pad", "concatenate", "copy", "transpose"}
_SLICE_FAMILY = {"dynamic-slice", "slice", "gather"}


def inst_bytes(comps: Dict[str, _Computation], comp: _Computation,
               inst: _Instr) -> int:
    """HBM-traffic model for one top-level instruction."""
    if inst.op in FREE_OPS:
        return 0
    out_b = _nbytes(inst.out_shapes)
    if inst.op in SLICED_OPS:
        return 2 * out_b
    if inst.op == "dynamic-update-slice":
        # read+write of the update region only
        return 2 * (_nbytes(comp.symbols.get(inst.operands[1], []))
                    if len(inst.operands) > 1 else out_b)
    if inst.op == "scatter":
        return 2 * (_nbytes(comp.symbols.get(inst.operands[2], []))
                    if len(inst.operands) > 2 else out_b)
    if inst.op == "fusion" and inst.calls and inst.calls[0] in comps:
        return _fusion_bytes(comps, comp, inst)
    operand_bytes = sum(
        _nbytes(comp.symbols.get(o, [])) for o in inst.operands)
    return out_b + operand_bytes


# ops that merely re-express a value inside a fusion (never HBM traffic)
_TRANSPARENT = {"convert", "bitcast", "reshape", "copy", "transpose",
                "broadcast"}


def _fusion_bytes(comps: Dict[str, _Computation], comp: _Computation,
                  inst: _Instr) -> int:
    """HBM traffic of a fusion = params read + output written, with:

    * params consumed only through slice-family ops (via transparent
      converts/reshapes) charged at the slice size — scan bodies slice
      one layer out of stacked weights/caches;
    * a root dynamic-update-slice (again through transparent wrappers)
      whose updated operand is a param ⇒ in-place update on TPU (scan-ys
      aliasing): charge 2× the update region instead of read+write of
      the whole buffer.

    Fusion internals never touch HBM by definition — only the boundary
    counts.
    """
    callee = comps[inst.calls[0]]
    defs = {i.name: i for i in callee.instrs}
    consumers: Dict[str, List[_Instr]] = {}
    for i in callee.instrs:
        for o in i.operands:
            consumers.setdefault(o, []).append(i)

    def slice_only_bytes(name: str, depth: int = 0) -> Optional[int]:
        """If every transitive use of ``name`` is a slice (through
        transparent ops), return summed slice-output bytes, else None."""
        if depth > 8:
            return None
        total = 0
        for u in consumers.get(name, []):
            if u.op in _SLICE_FAMILY and u.operands and \
                    u.operands[0] == name:
                total += _nbytes(u.out_shapes)
            elif u.op in _TRANSPARENT:
                sub = slice_only_bytes(u.name, depth + 1)
                if sub is None:
                    return None
                total += sub
            else:
                return None
        return total

    # root analysis: walk back through transparent ops to a DUS
    root = callee.instrs[-1] if callee.instrs else None
    dus_update_bytes = None
    dus_target_param = None
    node = root
    hops = 0
    while node is not None and node.op in _TRANSPARENT and hops < 8 \
            and node.operands:
        node = defs.get(node.operands[0])
        hops += 1
    if node is not None and node.op == "dynamic-update-slice" \
            and len(node.operands) > 1:
        dus_update_bytes = _nbytes(callee.symbols.get(node.operands[1],
                                                      []))
        # trace operand-0 back through transparent ops to a param
        tgt = defs.get(node.operands[0])
        hops = 0
        name0 = node.operands[0]
        while tgt is not None and tgt.op in _TRANSPARENT and hops < 8 \
                and tgt.operands:
            name0 = tgt.operands[0]
            tgt = defs.get(name0)
            hops += 1
        if name0 in callee.param_order:
            dus_target_param = name0

    if dus_update_bytes is not None and dus_target_param is not None:
        charge = 2 * dus_update_bytes      # in-place write+read of region
    else:
        charge = _nbytes(inst.out_shapes)

    for i, operand in enumerate(inst.operands):
        pname = (callee.param_order[i]
                 if i < len(callee.param_order) else None)
        if pname is not None and pname == dus_target_param:
            continue                        # in-place DUS target
        if pname is not None:
            sb = slice_only_bytes(pname)
            if sb is not None:
                charge += sb
                continue
        charge += _nbytes(comp.symbols.get(operand, []))
    return charge


def analyze_hlo(text: str) -> HloCost:
    comps = parse_hlo(text)
    cost = HloCost()
    entry = comps.get("__entry__")
    if entry is None:
        return cost

    def flops_of(comp: _Computation, mult: float, depth: int) -> None:
        if depth > 24:
            return
        for inst in comp.instrs:
            if inst.op == "dot":
                cost.flops += _dot_flops(comp, inst) * mult
                cost.dot_count += int(round(mult))
            if inst.op == "while" and inst.while_body in comps:
                trips = inst.trip_count or 1
                flops_of(comps[inst.while_body], mult * trips, depth + 1)
            else:
                for callee in inst.calls:
                    if callee in comps:
                        flops_of(comps[callee], mult, depth + 1)

    def visit(comp: _Computation, mult: float, depth: int) -> None:
        if depth > 24:
            return
        for inst in comp.instrs:
            if inst.op == "dot":
                cost.flops += _dot_flops(comp, inst) * mult
                cost.dot_count += int(round(mult))
            nb = inst_bytes(comps, comp, inst) * mult
            cost.bytes_accessed += nb
            if inst.vmem_tagged:
                cost.bytes_vmem_tagged += nb
            op = inst.op
            if op.endswith("-start"):
                op = op[: -len("-start")]
            if op in _COLLECTIVES:
                cost.add_collective(op, _nbytes(inst.out_shapes), mult)
            if inst.op == "while" and inst.while_body in comps:
                trips = inst.trip_count or 1
                visit(comps[inst.while_body], mult * trips, depth + 1)
            else:
                for callee in inst.calls:
                    if callee in comps:
                        # descend for FLOPs only: fusion internals do not
                        # touch HBM.  vmem-tagged fusions are kernel-
                        # resident: bucket their callsite traffic.
                        flops_of(comps[callee], mult, depth + 1)

    visit(entry, 1.0, 0)
    return cost
