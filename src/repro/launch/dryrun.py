import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

This is how the distribution config is proven coherent without hardware:
``jax.jit(step, in_shardings=…).lower(**ShapeDtypeStructs).compile()``
must succeed on the 16×16 single-pod mesh AND the 2×16×16 multi-pod mesh
for every assigned architecture × input shape.  The compiled artifact
yields ``memory_analysis()`` (fits-per-device proof) and
``cost_analysis()`` + the SPMD HLO (roofline terms, §Roofline).

Usage:
  python -m repro.launch.dryrun --arch granite-8b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--out experiments/dryrun]
"""

import argparse
import json
import subprocess
import sys
import time
import traceback
from functools import partial
from pathlib import Path


def build_lowered(arch: str, shape_name: str, mesh_kind: str,
                  overrides=None):
    """Build and lower the cell's step.  Imports happen here, after the
    XLA device-count env var is set."""
    import jax

    from repro.configs import get_config
    from repro.configs.shapes import SHAPES, cell_applicable, input_specs
    from repro.distributed.mesh import plan_from_mesh
    from repro.distributed.sharding import (
        batch_shardings,
        param_shardings,
        state_shardings,
    )
    from repro.launch.mesh import make_production_mesh
    from repro.models.model import Model, init_params
    from repro.optim import adamw, cosine_warmup
    from repro.runtime.train_loop import build_train_step, init_train_state

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, reason = cell_applicable(cfg, shape)
    if not ok:
        return None, None, None, reason

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    plan = plan_from_mesh(mesh)
    opts = dict(attn_chunk=1024, loss_chunk=512, remat=True)
    if overrides:
        opts.update(overrides)
    accum_override = opts.pop("accum_steps", None)
    aligned_decode = opts.pop("aligned_decode", False)
    param_mode = opts.pop("param_mode", "fsdp")
    model = Model(cfg, plan=plan, **opts)
    specs = input_specs(cfg, shape)
    if aligned_decode and "pos" in specs:
        # continuous-batching variant: one shared decode position
        specs["pos"] = jax.ShapeDtypeStruct((), specs["pos"].dtype)

    params_shapes = jax.eval_shape(
        partial(init_params, cfg), jax.random.PRNGKey(0))
    param_sh = param_shardings(cfg, plan, params_shapes,
                               drop_data=(param_mode == "tp"))

    if shape.kind == "train":
        opt = adamw(cosine_warmup(3e-4, 2000, 100_000))
        state_shapes = jax.eval_shape(
            partial(init_train_state, model, opt), jax.random.PRNGKey(0))
        state_sh = param_shardings(cfg, plan, state_shapes)
        batch_sh = batch_shardings(cfg, plan, specs)
        # grad accumulation keeps per-microbatch activations ≈ 2 seqs per
        # device live (94-layer models would otherwise hold the full
        # global batch's layer carries for backward)
        b_loc = shape.global_batch // plan.dp_size
        if accum_override is not None:
            accum = accum_override
        elif cfg.param_count() > 5e10:
            accum = max(1, b_loc)        # micro-batch 1/device: giants
        else:
            accum = max(1, b_loc // 2)   # micro-batch 2/device
        grad_sh = None
        if accum > 1 and "pod" in mesh.axis_names:
            grad_sh = param_shardings(cfg, plan, params_shapes, zero1=True)
        step = build_train_step(model, opt, accum_steps=accum,
                                grad_shardings=grad_sh)
        lowered = jax.jit(
            step,
            in_shardings=(state_sh, batch_sh),
            donate_argnums=(0,),
        ).lower(state_shapes, specs)
        return lowered, mesh, cfg, None

    if shape.kind == "prefill":
        batch_sh = batch_shardings(cfg, plan, specs)

        def prefill_step(params, inputs):
            return model.prefill(params, inputs["tokens"],
                                 inputs.get("frontend_embed"))

        lowered = jax.jit(
            prefill_step,
            in_shardings=(param_sh, batch_sh),
        ).lower(params_shapes, specs)
        return lowered, mesh, cfg, None

    # decode
    cache_specs = specs["cache"]
    cache_sh = state_shardings(cfg, plan, cache_specs)
    pos_spec = specs["pos"]
    tok_sh = batch_shardings(cfg, plan, {"tokens": specs["tokens"],
                                         "pos": pos_spec})

    def decode(params, cache, tokens, pos):
        return model.decode_step(params, cache, tokens, pos)

    lowered = jax.jit(
        decode,
        in_shardings=(param_sh, cache_sh, tok_sh["tokens"],
                      tok_sh["pos"]),
        donate_argnums=(1,),
    ).lower(params_shapes, cache_specs, specs["tokens"], specs["pos"])
    return lowered, mesh, cfg, None


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: Path,
             overrides=None, tag: str = "") -> dict:
    from repro.configs.shapes import SHAPES
    from repro.launch.hlo_costs import analyze_hlo
    from repro.launch.roofline import (
        RooflineReport,
        extract_cost,
        extract_memory,
        model_flops_for,
    )

    t0 = time.perf_counter()
    record = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
              "status": "ok"}
    lowered, mesh, cfg, skip_reason = build_lowered(
        arch, shape_name, mesh_kind, overrides)
    if lowered is None:
        record["status"] = "skip"
        record["reason"] = skip_reason
        out_dir.mkdir(parents=True, exist_ok=True)
        (out_dir / f"{arch}_{shape_name}_{mesh_kind}.json").write_text(
            json.dumps(record, indent=2))
        print(f"SKIP {arch} × {shape_name} × {mesh_kind}: {skip_reason}")
        return record
    t_lower = time.perf_counter() - t0

    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0 - t_lower

    mem = compiled.memory_analysis()
    print(f"memory_analysis: {mem}")        # proves it fits
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):   # some jax/XLA versions return
        cost = cost[0] if cost else {}    # one dict per program
    print(f"cost_analysis (xla, while-body-once, per-device): "
          f"flops={cost.get('flops', 0.0):.3e} "
          f"bytes={cost.get('bytes accessed', 0.0):.3e}")

    chips = 1
    for v in mesh.shape.values():
        chips *= v
    # trip-count-aware accounting over the SPMD HLO (per-device → ×chips)
    hlo = compiled.as_text()
    hcost = analyze_hlo(hlo)
    shape = SHAPES[shape_name]
    report = RooflineReport(
        arch=arch, shape=shape_name, mesh=mesh_kind, chips=chips,
        hlo_flops=hcost.flops * chips,
        hlo_bytes=hcost.bytes_hbm_model * chips,
        hlo_bytes_raw=hcost.bytes_accessed * chips,
        bytes_vmem_tagged=hcost.bytes_vmem_tagged * chips,
        coll_bytes=hcost.coll_bytes * chips,
        coll_by_op={k: v * chips for k, v in
                    hcost.coll_bytes_by_op.items()},
        model_flops=model_flops_for(cfg, shape, shape.kind),
        bytes_per_device=extract_memory(compiled),
    )
    record.update(report.to_dict())
    record["coll_counts"] = hcost.coll_count_by_op
    xla_flops, xla_bytes = extract_cost(compiled)
    record["xla_flops_per_device_body_once"] = xla_flops
    record["xla_bytes_per_device_body_once"] = xla_bytes
    record["hlo_bytes_len"] = len(hlo)
    record["lower_s"] = round(t_lower, 1)
    record["compile_s"] = round(t_compile, 1)
    if tag:
        record["tag"] = tag

    out_dir.mkdir(parents=True, exist_ok=True)
    name = f"{arch}_{shape_name}_{mesh_kind}" + (f"_{tag}" if tag else "")
    (out_dir / f"{name}.json").write_text(json.dumps(record, indent=2))
    print(f"OK {arch} × {shape_name} × {mesh_kind}: "
          f"compute={report.t_compute:.4f}s memory={report.t_memory:.4f}s "
          f"collective={report.t_collective:.4f}s "
          f"bottleneck={report.bottleneck} "
          f"roofline={report.roofline_fraction:.3f} "
          f"(lower {t_lower:.0f}s, compile {t_compile:.0f}s)")
    return record


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true",
                    help="run every (arch × shape) cell in subprocesses")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--tag", default="", help="variant tag for §Perf runs")
    ap.add_argument("--override", default="",
                    help="JSON dict of Model kwargs (perf experiments)")
    args = ap.parse_args(argv)
    out_dir = Path(args.out)

    if args.all:
        from repro.configs import ASSIGNED_ARCHS
        from repro.configs.shapes import SHAPES

        meshes = (["single", "multi"] if args.mesh == "both"
                  else [args.mesh])
        failures = []
        for arch in ASSIGNED_ARCHS:
            for shape in SHAPES:
                for mesh in meshes:
                    dest = out_dir / f"{arch}_{shape}_{mesh}.json"
                    if dest.exists():
                        print(f"cached {dest}")
                        continue
                    cmd = [sys.executable, "-m", "repro.launch.dryrun",
                           "--arch", arch, "--shape", shape,
                           "--mesh", mesh, "--out", str(out_dir)]
                    r = subprocess.run(cmd)
                    if r.returncode != 0:
                        failures.append((arch, shape, mesh))
        if failures:
            print(f"FAILED cells: {failures}")
            return 1
        print("all cells passed")
        return 0

    overrides = json.loads(args.override) if args.override else None
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    try:
        for mesh in meshes:
            run_cell(args.arch, args.shape, mesh, out_dir,
                     overrides=overrides, tag=args.tag)
    except Exception:
        traceback.print_exc()
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
