"""Device-side agentic exploration — fork/explore/commit inside one SPMD program.

On a TPU there is no process to signal: sibling branches live in a stacked
leading axis of the state pytree (optionally sharded over a mesh axis) and
first-commit-wins is a reduction.  This module provides the pure-JAX
primitives used by ``runtime/`` for speculative training, straggler
mitigation, and beam-style serving exploration:

* :func:`fork_stacked` — O(1)-per-branch broadcast fork (frozen origin is
  structural: the origin pytree is never written, JAX arrays are
  immutable).
* :func:`first_commit_wins` — deterministic winner selection.  "First" is
  the earliest ``commit_time`` among successful branches; in a
  synchronous SPMD step every branch finishes together, so ties break to
  the lowest branch index — the same total order the kernel's exclusive
  commit group imposes.
* :func:`select_branch` — the commit: gather the winner's leaves; sibling
  buffers are simply never read again (donation reclaims them), the
  SIGBUS/-ESTALE analogue.
* :func:`explore` — one fork/explore/commit round under ``vmap``.

Everything here is jit/pjit-compatible and used under ``shard_map`` with
the branch axis mapped onto a mesh axis for multi-slice exploration.
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


def fork_stacked(state: Any, n: int) -> Any:
    """Fork ``n`` sibling copies of ``state`` along a new leading axis.

    Uses ``broadcast_to`` so no HBM copy happens until a branch writes
    (XLA materializes on first mutation) — the CoW analogue.
    """
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (n,) + jnp.shape(x)), state
    )


def perturbed_fork(
    state: Any,
    n: int,
    perturb_fn: Callable[[Any, jax.Array, jax.Array], Any],
    key: jax.Array,
) -> Any:
    """Fork ``n`` branches, each perturbed by ``perturb_fn(state, key_i, i)``.

    This is the "explore" setup for speculative training: each branch gets
    an independent RNG stream and its branch index (e.g. to scale a
    hyperparameter).
    """
    keys = jax.random.split(key, n)
    idx = jnp.arange(n)
    return jax.vmap(lambda k, i: perturb_fn(state, k, i))(keys, idx)


def first_commit_wins(
    success: jax.Array,
    commit_time: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Resolve the exclusive commit group.

    Args:
      success: bool[N] — which branches attempt a commit.
      commit_time: optional float/int[N] — arrival order of the commit
        attempts; earliest successful one wins.  Defaults to branch index
        (synchronous step ⇒ index order is arrival order).

    Returns:
      (winner_index: int32 scalar, any_success: bool scalar).  If no
      branch succeeds, ``winner_index`` is 0 and ``any_success`` is False
      (caller keeps the frozen origin — "if all branches abort, the
      parent resumes").
    """
    n = success.shape[0]
    if commit_time is None:
        commit_time = jnp.arange(n, dtype=jnp.float32)
    commit_time = commit_time.astype(jnp.float32)
    big = jnp.finfo(jnp.float32).max
    keyed = jnp.where(success, commit_time, big)
    winner = jnp.argmin(keyed).astype(jnp.int32)
    return winner, jnp.any(success)


def select_branch(stacked: Any, index: jax.Array) -> Any:
    """Commit: extract branch ``index`` from every stacked leaf."""
    return jax.tree_util.tree_map(
        lambda x: jax.lax.dynamic_index_in_dim(x, index, axis=0, keepdims=False),
        stacked,
    )


class ExploreResult(NamedTuple):
    state: Any           # committed state (origin if nothing succeeded)
    winner: jax.Array    # int32 — winning branch index
    committed: jax.Array # bool — did any branch commit?
    aux: Any             # stacked per-branch auxiliary outputs


def explore(
    step_fn: Callable[[Any, jax.Array], Tuple[Any, jax.Array, Any]],
    origin: Any,
    n: int,
    key: jax.Array,
    *,
    perturb_fn: Optional[Callable[[Any, jax.Array, jax.Array], Any]] = None,
    commit_time_fn: Optional[Callable[[Any], jax.Array]] = None,
) -> ExploreResult:
    """One fork/explore/commit round, fully inside jit.

    ``step_fn(branch_state, key) -> (new_state, success, aux)`` runs in
    parallel over ``n`` branches via ``vmap``.  The first successful
    branch (per :func:`first_commit_wins`) commits; if none succeeds the
    frozen origin is returned unchanged.
    """
    if perturb_fn is not None:
        branches = perturbed_fork(origin, n, perturb_fn, key)
    else:
        branches = fork_stacked(origin, n)
    keys = jax.random.split(jax.random.fold_in(key, 1), n)
    new_states, success, aux = jax.vmap(step_fn)(branches, keys)
    success = success.reshape((n,)).astype(bool)
    commit_time = commit_time_fn(aux) if commit_time_fn is not None else None
    winner, any_success = first_commit_wins(success, commit_time)
    winner_state = select_branch(new_states, winner)
    committed = jax.tree_util.tree_map(
        lambda w, o: jnp.where(
            jnp.asarray(any_success).reshape((1,) * jnp.ndim(w)), w, o
        ),
        winner_state,
        origin,
    )
    return ExploreResult(state=committed, winner=winner,
                         committed=any_success, aux=aux)
