"""BranchStore — leaf-granular copy-on-write branch contexts over pytrees.

This is the in-memory realization of the paper's BranchFS semantics, with
pytree *leaves* playing the role of files:

* **CoW delta layers**: each branch holds only the leaves it wrote
  (``delta`` dict).  Because JAX arrays are immutable, "copy"-on-write is
  zero-copy: the delta stores a reference to the new array; the base is
  never touched.  Branch creation is O(1) regardless of base size
  (paper Table 4).
* **Branch-chain resolution**: a read walks current branch → ancestors →
  base, exactly the lookup order of BranchFS §4.2.
* **Tombstones**: deletions write a sentinel so deleted leaves do not
  "reappear" from the base.
* **Frozen origin**: a branch with live children rejects writes
  (`FrozenOriginError`).
* **Nesting**: branches fork sub-branches; commit applies to the
  *immediate* parent only (paper §5.2 "Nested Branches").

The lifecycle itself (ids, parent/child links, status, epochs, exclusive
commit groups, first-commit-wins, recursive sibling invalidation) is NOT
implemented here: BranchStore is a :class:`~repro.core.lifecycle.
BranchDomain` plugged into the shared :class:`~repro.core.lifecycle.
BranchTree` kernel (DESIGN §2).  This module owns only the payload —
delta dicts and tombstones — and moves it in the ``on_fork/on_commit/
on_abort/on_invalidate`` hooks.  Thread-safety comes from the tree's
lock, mirroring the kernel's exclusive commit group.
"""

from __future__ import annotations

import threading
from typing import Any, Callable, Dict, List, Mapping, Optional, Tuple

import jax

from repro.core.errors import (
    BranchStateError,
    FrozenOriginError,
    NoSuchLeafError,
    StaleBranchError,
)
from repro.core.lifecycle import BranchStatus, BranchTree


class _Tombstone:
    """Sentinel recording a deletion in a delta layer (BranchFS §4.2)."""

    _instance: Optional["_Tombstone"] = None

    def __new__(cls) -> "_Tombstone":
        if cls._instance is None:
            cls._instance = super().__new__(cls)
        return cls._instance

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return "<TOMBSTONE>"


TOMBSTONE = _Tombstone()


class BranchStore:
    """A tree of CoW branch contexts over a flat ``{path: leaf}`` namespace.

    The root (branch id 0) is the base "filesystem".  All other branches
    are created by :meth:`fork` and resolved by :meth:`commit` /
    :meth:`abort` — both delegated to the lifecycle kernel, with this
    class acting as the BR_FS payload domain.
    """

    ROOT = 0

    def __init__(self, base: Optional[Mapping[str, Any]] = None):
        # Committed interior nodes may still be forked from (their state
        # is merged upward, but chain resolution still works), and the
        # origin stays ACTIVE while children are live — writes are gated
        # on has_live_children instead of a FROZEN status.
        self._tree = BranchTree(freeze_on_fork=False,
                                allow_fork_resolved=True)
        self._deltas: Dict[int, Dict[str, Any]] = {}
        self._tree.attach(self)
        root = self._tree.create_root()
        assert root == self.ROOT
        self._deltas[root] = dict(base or {})

    @property
    def tree(self) -> BranchTree:
        """The lifecycle kernel (shared with any co-registered domains)."""
        return self._tree

    @property
    def _lock(self) -> threading.RLock:
        return self._tree.lock

    # ------------------------------------------------------------------
    # BranchDomain payload hooks (called by the kernel, under its lock)
    # ------------------------------------------------------------------
    def on_fork(self, parent: int, children: List[int]) -> None:
        for c in children:
            self._deltas[c] = {}   # O(1): children start with empty deltas

    def on_commit(self, child: int, parent: int) -> None:
        # Apply tombstones first, then modified leaves (BranchFS §4.3).
        delta = self._deltas[child]
        parent_delta = self._deltas[parent]
        parent_is_base = self._tree.node(parent).parent is None
        for path, leaf in delta.items():
            if leaf is TOMBSTONE:
                if parent_is_base:
                    # committing into the base: delete outright
                    parent_delta.pop(path, None)
                else:
                    parent_delta[path] = TOMBSTONE
        for path, leaf in delta.items():
            if leaf is not TOMBSTONE:
                parent_delta[path] = leaf
        self._deltas[child] = {}

    def on_abort(self, branch: int) -> None:
        self._deltas[branch] = {}

    def on_invalidate(self, branch: int) -> None:
        self._deltas[branch] = {}

    def on_reap(self, branch: int) -> None:
        self._deltas.pop(branch, None)

    # ------------------------------------------------------------------
    # lifecycle: fork / commit / abort (delegated to the kernel)
    # ------------------------------------------------------------------
    def fork(self, parent: int = ROOT, n: int = 1) -> List[int]:
        """Create ``n`` sibling branches from a frozen origin.  O(1) each.

        All ``n`` branches form an *exclusive group*: at most one of them
        can commit; the winner invalidates the rest (paper §5.2
        BR_CREATE).
        """
        return self._tree.fork(parent, n)

    def commit(self, branch_id: int) -> int:
        """Atomically apply this branch's delta to its immediate parent.

        First-commit-wins: the kernel's epoch CAS decides the race under
        its lock; on success the parent's epoch is bumped, turning every
        sibling stale.  Returns the parent id (the branch "replaces" the
        parent, analogous to the PID takeover of ``BR_COMMIT``).
        """
        return self._tree.commit(branch_id)

    def abort(self, branch_id: int) -> None:
        """Discard the branch's delta; siblings remain valid.  O(1)."""
        self._tree.abort(branch_id)

    def reap(self, branch_id: int) -> int:
        """GC a fully-resolved subtree (nodes + delta entries).

        Opt-in for the store: a COMMITTED interior node normally stays
        forkable (``allow_fork_resolved``) and resolvable in read
        chains, so only reap subtrees the caller will never address
        again (e.g. after an exploration round fully resolves).
        """
        return self._tree.reap(branch_id)

    # ------------------------------------------------------------------
    # namespace ops (the "filesystem" interface)
    # ------------------------------------------------------------------
    def _writable(self, branch_id: int) -> int:
        self._tree.check_live(branch_id)
        if self._tree.has_live_children(branch_id):
            raise FrozenOriginError(
                f"branch {branch_id} has live children and is frozen")
        return branch_id

    def read(self, branch_id: int, path: str) -> Any:
        """Chain resolution: branch delta → ancestors → base (§4.2)."""
        with self._lock:
            status = self._tree.status(branch_id)
            if status is BranchStatus.STALE:
                raise StaleBranchError(
                    f"branch {branch_id} was invalidated (SIGBUS analogue)")
            if status is BranchStatus.ABORTED:
                raise BranchStateError(f"branch {branch_id} was aborted")
            for level in self._tree.chain(branch_id):
                if path in self._deltas[level]:
                    leaf = self._deltas[level][path]
                    if leaf is TOMBSTONE:
                        raise NoSuchLeafError(path)
                    return leaf
            raise NoSuchLeafError(path)

    def exists(self, branch_id: int, path: str) -> bool:
        try:
            self.read(branch_id, path)
            return True
        except NoSuchLeafError:
            return False

    def write(self, branch_id: int, path: str, value: Any) -> None:
        with self._lock:
            self._writable(branch_id)
            self._deltas[branch_id][path] = value

    def write_many(self, branch_id: int, items: Mapping[str, Any]) -> None:
        with self._lock:
            self._writable(branch_id)
            self._deltas[branch_id].update(items)

    def delete(self, branch_id: int, path: str) -> None:
        """Record a tombstone (the leaf must currently resolve)."""
        with self._lock:
            self._writable(branch_id)
            if not self.exists(branch_id, path):
                raise NoSuchLeafError(path)
            self._deltas[branch_id][path] = TOMBSTONE

    def listdir(self, branch_id: int) -> List[str]:
        """Effective namespace: union along the chain minus tombstones."""
        with self._lock:
            self._tree.node(branch_id)
            seen: Dict[str, bool] = {}
            for level in self._tree.chain(branch_id):
                for path, leaf in self._deltas[level].items():
                    if path not in seen:
                        seen[path] = leaf is not TOMBSTONE
            return sorted(p for p, alive in seen.items() if alive)

    def delta_size(self, branch_id: int) -> int:
        self._tree.node(branch_id)
        return len(self._deltas[branch_id])

    def status(self, branch_id: int) -> BranchStatus:
        return self._tree.status(branch_id)

    def epoch(self, branch_id: int) -> int:
        return self._tree.epoch(branch_id)

    # ------------------------------------------------------------------
    # pytree convenience layer
    # ------------------------------------------------------------------
    @staticmethod
    def flatten_pytree(tree: Any, prefix: str = "") -> Dict[str, Any]:
        """Flatten a pytree into ``{key-path: leaf}`` with stable names."""
        flat = jax.tree_util.tree_flatten_with_path(tree)[0]
        out: Dict[str, Any] = {}
        for path, leaf in flat:
            key = prefix + jax.tree_util.keystr(path)
            out[key] = leaf
        return out

    def snapshot_pytree(self, branch_id: int, tree: Any, prefix: str = "") -> None:
        """Write every leaf of ``tree`` into the branch (O(leaves) refs)."""
        self.write_many(branch_id, self.flatten_pytree(tree, prefix))

    def restore_pytree(self, branch_id: int, treedef_tree: Any, prefix: str = "") -> Any:
        """Rebuild a pytree shaped like ``treedef_tree`` from the branch."""
        flat = jax.tree_util.tree_flatten_with_path(treedef_tree)
        leaves = []
        for path, _ in flat[0]:
            key = prefix + jax.tree_util.keystr(path)
            leaves.append(self.read(branch_id, key))
        return jax.tree_util.tree_unflatten(flat[1], leaves)

    # ------------------------------------------------------------------
    # introspection for tests / benchmarks
    # ------------------------------------------------------------------
    def chain_depth(self, branch_id: int) -> int:
        return self._tree.chain_depth(branch_id)

    def consolidated_view(self, branch_id: int) -> Dict[str, Any]:
        """Materialize the flat effective namespace.

        This is the analogue of BranchFS *passthrough* mode: pay the chain
        walk once, then serve reads at native speed from the flat dict.
        """
        with self._lock:
            out: Dict[str, Any] = {}
            dead: set = set()
            for level in self._tree.chain(branch_id):
                for path, leaf in self._deltas[level].items():
                    if path in out or path in dead:
                        continue
                    if leaf is TOMBSTONE:
                        dead.add(path)
                    else:
                        out[path] = leaf
            return out


def explore(
    store: BranchStore,
    parent: int,
    fns: List[Callable[[int], bool]],
    *,
    threads: bool = True,
) -> Tuple[Optional[int], List[BranchStatus]]:
    """Run one fork/explore/commit round: the paper's Listing 2 in Python.

    Each ``fns[i]`` receives its branch id, does arbitrary reads/writes on
    it, and returns truthy to *attempt a commit*.  The first successful
    commit wins; every other branch ends STALE (if it lost the race) or
    ABORTED (if it returned falsy).  Returns ``(winner_branch_id | None,
    statuses)``.
    """
    branches = store.fork(parent, n=len(fns))
    winner: List[Optional[int]] = [None]

    def _run(i: int, bid: int) -> None:
        try:
            ok = fns[i](bid)
        except StaleBranchError:
            return
        if ok:
            try:
                store.commit(bid)
                winner[0] = bid
            except StaleBranchError:
                pass  # lost the race: -ESTALE
        else:
            try:
                store.abort(bid)
            except (StaleBranchError, BranchStateError):
                pass

    if threads:
        ts = [
            threading.Thread(target=_run, args=(i, bid))
            for i, bid in enumerate(branches)
        ]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
    else:
        for i, bid in enumerate(branches):
            _run(i, bid)

    return winner[0], [store.status(b) for b in branches]
