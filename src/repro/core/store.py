"""BranchStore — leaf-granular copy-on-write branch contexts over pytrees.

This is the in-memory realization of the paper's BranchFS semantics, with
pytree *leaves* playing the role of files:

* **CoW delta layers**: each branch holds only the leaves it wrote
  (``delta`` dict).  Because JAX arrays are immutable, "copy"-on-write is
  zero-copy: the delta stores a reference to the new array; the base is
  never touched.  Branch creation is O(1) regardless of base size
  (paper Table 4).
* **Branch-chain resolution**: a read walks current branch → ancestors →
  base, exactly the lookup order of BranchFS §4.2.
* **Tombstones**: deletions write a sentinel so deleted leaves do not
  "reappear" from the base.
* **Frozen origin**: a branch with live children rejects writes
  (`FrozenOriginError`).
* **First-commit-wins**: commits race on the parent's epoch; the first
  commit merges its delta into the parent and bumps the parent epoch,
  which invalidates all siblings (`StaleBranchError`, the ``-ESTALE``
  analogue).
* **Nesting**: branches fork sub-branches; commit applies to the
  *immediate* parent only (paper §5.2 "Nested Branches").

The store is thread-safe: concurrent explorer threads may race commits and
the winner is decided under a single lock, mirroring the kernel's
exclusive commit group.
"""

from __future__ import annotations

import itertools
import threading
import time
from dataclasses import dataclass, field
from enum import Enum
from typing import Any, Callable, Dict, Iterator, List, Mapping, Optional, Tuple

import jax

from repro.core.errors import (
    BranchStateError,
    FrozenOriginError,
    NoSuchLeafError,
    StaleBranchError,
)


class _Tombstone:
    """Sentinel recording a deletion in a delta layer (BranchFS §4.2)."""

    _instance: Optional["_Tombstone"] = None

    def __new__(cls) -> "_Tombstone":
        if cls._instance is None:
            cls._instance = super().__new__(cls)
        return cls._instance

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return "<TOMBSTONE>"


TOMBSTONE = _Tombstone()


class BranchStatus(Enum):
    ACTIVE = "active"
    COMMITTED = "committed"
    ABORTED = "aborted"
    STALE = "stale"  # invalidated by a sibling's commit (-ESTALE)


@dataclass
class _Node:
    """One branch context: a delta layer + lifecycle bookkeeping."""

    branch_id: int
    parent: Optional[int]
    delta: Dict[str, Any] = field(default_factory=dict)
    status: BranchStatus = BranchStatus.ACTIVE
    # Parent epoch observed at fork time.  A commit is valid only while the
    # parent's epoch is unchanged; the winning commit bumps it, so every
    # sibling's next commit/read attempt fails the epoch check (-ESTALE).
    parent_epoch_at_fork: int = 0
    epoch: int = 0  # bumped when *this* node accepts a child's commit
    children: List[int] = field(default_factory=list)
    group: Optional[int] = None  # exclusive commit group id (BR_CREATE set)
    created_at: float = field(default_factory=time.monotonic)


class BranchStore:
    """A tree of CoW branch contexts over a flat ``{path: leaf}`` namespace.

    The root (branch id 0) is the base "filesystem".  All other branches
    are created by :meth:`fork` and resolved by :meth:`commit` /
    :meth:`abort`.
    """

    ROOT = 0

    def __init__(self, base: Optional[Mapping[str, Any]] = None):
        self._lock = threading.RLock()
        self._ids = itertools.count(1)
        self._groups = itertools.count(1)
        root = _Node(branch_id=self.ROOT, parent=None)
        root.delta = dict(base or {})
        self._nodes: Dict[int, _Node] = {self.ROOT: root}

    # ------------------------------------------------------------------
    # helpers
    # ------------------------------------------------------------------
    def _node(self, branch_id: int) -> _Node:
        try:
            return self._nodes[branch_id]
        except KeyError:
            raise BranchStateError(f"unknown branch id {branch_id!r}") from None

    def _check_live(self, node: _Node) -> None:
        if node.status is BranchStatus.STALE:
            raise StaleBranchError(
                f"branch {node.branch_id} was invalidated by a sibling commit"
            )
        if node.status is not BranchStatus.ACTIVE:
            raise BranchStateError(
                f"branch {node.branch_id} is {node.status.value}, not active"
            )
        # Epoch check: if the parent epoch moved past what we forked from,
        # a sibling committed and we are stale even if not yet marked.
        if node.parent is not None:
            parent = self._nodes[node.parent]
            if parent.epoch != node.parent_epoch_at_fork:
                node.status = BranchStatus.STALE
                raise StaleBranchError(
                    f"branch {node.branch_id} is stale "
                    f"(parent epoch {parent.epoch} != "
                    f"{node.parent_epoch_at_fork} at fork)"
                )

    def _chain(self, branch_id: int) -> Iterator[_Node]:
        """Yield nodes from ``branch_id`` up to and including the root."""
        cur: Optional[int] = branch_id
        while cur is not None:
            node = self._nodes[cur]
            yield node
            cur = node.parent

    def _live_children(self, node: _Node) -> List[_Node]:
        return [
            self._nodes[c]
            for c in node.children
            if self._nodes[c].status is BranchStatus.ACTIVE
        ]

    # ------------------------------------------------------------------
    # lifecycle: fork / commit / abort
    # ------------------------------------------------------------------
    def fork(self, parent: int = ROOT, n: int = 1) -> List[int]:
        """Create ``n`` sibling branches from a frozen origin.  O(1) each.

        All ``n`` branches form an *exclusive group*: at most one of them
        can commit; the winner invalidates the rest (paper §5.2
        BR_CREATE).
        """
        if n < 1:
            raise ValueError("n must be >= 1")
        with self._lock:
            pnode = self._node(parent)
            if pnode.status not in (BranchStatus.ACTIVE, BranchStatus.COMMITTED):
                # committed interior nodes may still be forked from (their
                # state is merged upward, but chain resolution still works)
                self._check_live(pnode)
            group = next(self._groups)
            out: List[int] = []
            for _ in range(n):
                bid = next(self._ids)
                node = _Node(
                    branch_id=bid,
                    parent=parent,
                    parent_epoch_at_fork=pnode.epoch,
                    group=group,
                )
                self._nodes[bid] = node
                pnode.children.append(bid)
                out.append(bid)
            return out

    def commit(self, branch_id: int) -> int:
        """Atomically apply this branch's delta to its immediate parent.

        First-commit-wins: under the store lock, the epoch check decides
        the race.  On success the parent's epoch is bumped, turning every
        sibling stale.  Returns the parent id (the branch "replaces" the
        parent, analogous to the PID takeover of ``BR_COMMIT``).
        """
        with self._lock:
            node = self._node(branch_id)
            self._check_live(node)  # raises StaleBranchError if we lost
            if self._live_children(node):
                raise BranchStateError(
                    f"branch {branch_id} has live children; commit or abort "
                    "them first (commit applies to the immediate parent only)"
                )
            assert node.parent is not None, "root cannot commit"
            parent = self._nodes[node.parent]
            # Apply tombstones first, then modified leaves (BranchFS §4.3).
            for path, leaf in node.delta.items():
                if leaf is TOMBSTONE:
                    if parent.parent is None:
                        # committing into the base: delete outright
                        parent.delta.pop(path, None)
                    else:
                        parent.delta[path] = TOMBSTONE
            for path, leaf in node.delta.items():
                if leaf is not TOMBSTONE:
                    parent.delta[path] = leaf
            node.status = BranchStatus.COMMITTED
            node.delta = {}
            parent.epoch += 1  # invalidates all siblings
            for sid in parent.children:
                sib = self._nodes[sid]
                if sid != branch_id and sib.status is BranchStatus.ACTIVE:
                    sib.status = BranchStatus.STALE
                    self._invalidate_descendants(sib)
            return parent.branch_id

    def abort(self, branch_id: int) -> None:
        """Discard the branch's delta; siblings remain valid.  O(1)."""
        with self._lock:
            node = self._node(branch_id)
            if node.status is BranchStatus.STALE:
                # aborting a stale branch is allowed (cleanup after -ESTALE)
                node.delta = {}
                return
            if node.status is not BranchStatus.ACTIVE:
                raise BranchStateError(
                    f"branch {branch_id} is {node.status.value}"
                )
            node.status = BranchStatus.ABORTED
            node.delta = {}
            self._invalidate_descendants(node)

    def _invalidate_descendants(self, node: _Node) -> None:
        for cid in node.children:
            child = self._nodes[cid]
            if child.status is BranchStatus.ACTIVE:
                child.status = BranchStatus.STALE
            child.delta = {}
            self._invalidate_descendants(child)

    # ------------------------------------------------------------------
    # namespace ops (the "filesystem" interface)
    # ------------------------------------------------------------------
    def read(self, branch_id: int, path: str) -> Any:
        """Chain resolution: branch delta → ancestors → base (§4.2)."""
        with self._lock:
            node = self._node(branch_id)
            if node.status is BranchStatus.ACTIVE:
                self._check_live(node)
            elif node.status is BranchStatus.STALE:
                raise StaleBranchError(
                    f"branch {branch_id} was invalidated (SIGBUS analogue)"
                )
            elif node.status is BranchStatus.ABORTED:
                raise BranchStateError(f"branch {branch_id} was aborted")
            for level in self._chain(branch_id):
                if path in level.delta:
                    leaf = level.delta[path]
                    if leaf is TOMBSTONE:
                        raise NoSuchLeafError(path)
                    return leaf
            raise NoSuchLeafError(path)

    def exists(self, branch_id: int, path: str) -> bool:
        try:
            self.read(branch_id, path)
            return True
        except NoSuchLeafError:
            return False

    def write(self, branch_id: int, path: str, value: Any) -> None:
        with self._lock:
            node = self._node(branch_id)
            self._check_live(node)
            if self._live_children(node):
                raise FrozenOriginError(
                    f"branch {branch_id} has live children and is frozen"
                )
            node.delta[path] = value

    def write_many(self, branch_id: int, items: Mapping[str, Any]) -> None:
        with self._lock:
            node = self._node(branch_id)
            self._check_live(node)
            if self._live_children(node):
                raise FrozenOriginError(
                    f"branch {branch_id} has live children and is frozen"
                )
            node.delta.update(items)

    def delete(self, branch_id: int, path: str) -> None:
        """Record a tombstone (the leaf must currently resolve)."""
        with self._lock:
            node = self._node(branch_id)
            self._check_live(node)
            if self._live_children(node):
                raise FrozenOriginError(
                    f"branch {branch_id} has live children and is frozen"
                )
            if not self.exists(branch_id, path):
                raise NoSuchLeafError(path)
            node.delta[path] = TOMBSTONE

    def listdir(self, branch_id: int) -> List[str]:
        """Effective namespace: union along the chain minus tombstones."""
        with self._lock:
            self._node(branch_id)
            seen: Dict[str, bool] = {}
            for level in self._chain(branch_id):
                for path, leaf in level.delta.items():
                    if path not in seen:
                        seen[path] = leaf is not TOMBSTONE
            return sorted(p for p, alive in seen.items() if alive)

    def delta_size(self, branch_id: int) -> int:
        return len(self._node(branch_id).delta)

    def status(self, branch_id: int) -> BranchStatus:
        with self._lock:
            node = self._node(branch_id)
            if node.status is BranchStatus.ACTIVE and node.parent is not None:
                parent = self._nodes[node.parent]
                if parent.epoch != node.parent_epoch_at_fork:
                    node.status = BranchStatus.STALE
            return node.status

    def epoch(self, branch_id: int) -> int:
        return self._node(branch_id).epoch

    # ------------------------------------------------------------------
    # pytree convenience layer
    # ------------------------------------------------------------------
    @staticmethod
    def flatten_pytree(tree: Any, prefix: str = "") -> Dict[str, Any]:
        """Flatten a pytree into ``{key-path: leaf}`` with stable names."""
        flat = jax.tree_util.tree_flatten_with_path(tree)[0]
        out: Dict[str, Any] = {}
        for path, leaf in flat:
            key = prefix + jax.tree_util.keystr(path)
            out[key] = leaf
        return out

    def snapshot_pytree(self, branch_id: int, tree: Any, prefix: str = "") -> None:
        """Write every leaf of ``tree`` into the branch (O(leaves) refs)."""
        self.write_many(branch_id, self.flatten_pytree(tree, prefix))

    def restore_pytree(self, branch_id: int, treedef_tree: Any, prefix: str = "") -> Any:
        """Rebuild a pytree shaped like ``treedef_tree`` from the branch."""
        flat = jax.tree_util.tree_flatten_with_path(treedef_tree)
        leaves = []
        for path, _ in flat[0]:
            key = prefix + jax.tree_util.keystr(path)
            leaves.append(self.read(branch_id, key))
        return jax.tree_util.tree_unflatten(flat[1], leaves)

    # ------------------------------------------------------------------
    # introspection for tests / benchmarks
    # ------------------------------------------------------------------
    def chain_depth(self, branch_id: int) -> int:
        return sum(1 for _ in self._chain(branch_id)) - 1

    def consolidated_view(self, branch_id: int) -> Dict[str, Any]:
        """Materialize the flat effective namespace.

        This is the analogue of BranchFS *passthrough* mode: pay the chain
        walk once, then serve reads at native speed from the flat dict.
        """
        with self._lock:
            out: Dict[str, Any] = {}
            dead: set = set()
            for level in self._chain(branch_id):
                for path, leaf in level.delta.items():
                    if path in out or path in dead:
                        continue
                    if leaf is TOMBSTONE:
                        dead.add(path)
                    else:
                        out[path] = leaf
            return out


def explore(
    store: BranchStore,
    parent: int,
    fns: List[Callable[[int], bool]],
    *,
    threads: bool = True,
) -> Tuple[Optional[int], List[BranchStatus]]:
    """Run one fork/explore/commit round: the paper's Listing 2 in Python.

    Each ``fns[i]`` receives its branch id, does arbitrary reads/writes on
    it, and returns truthy to *attempt a commit*.  The first successful
    commit wins; every other branch ends STALE (if it lost the race) or
    ABORTED (if it returned falsy).  Returns ``(winner_branch_id | None,
    statuses)``.
    """
    branches = store.fork(parent, n=len(fns))
    winner: List[Optional[int]] = [None]

    def _run(i: int, bid: int) -> None:
        try:
            ok = fns[i](bid)
        except StaleBranchError:
            return
        if ok:
            try:
                store.commit(bid)
                winner[0] = bid
            except StaleBranchError:
                pass  # lost the race: -ESTALE
        else:
            try:
                store.abort(bid)
            except (StaleBranchError, BranchStateError):
                pass

    if threads:
        ts = [
            threading.Thread(target=_run, args=(i, bid))
            for i, bid in enumerate(branches)
        ]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
    else:
        for i, bid in enumerate(branches):
            _run(i, bid)

    return winner[0], [store.status(b) for b in branches]
