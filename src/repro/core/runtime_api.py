"""``branch()`` analogue — atomic composition of multi-domain branch forks.

The paper's central argument for a syscall (§5, Table 3) is *atomic
composition*: forking filesystem state, process groups, and memory in one
call, with kernel-side cleanup on partial failure.  In branchx the state
domains are (a) the host pytree store (≈ BR_FS), (b) device-resident
paged-KV / recurrent state (≈ BR_MEMORY), and (c) whatever additional
domains are attached to the KV manager's lifecycle kernel — e.g. the
serving engine's token tails, which resolve in the same kernel-level
commit (≈ the process group).  ``BranchRuntime.create`` forks all
requested domains or none — any failure unwinds the domains already
forked, mirroring the kernel's cleanup-on-failure guarantee.

``BranchRuntime.commit`` is the cross-domain first-commit-wins arbiter:
it takes the KV kernel's lock for the whole composite commit, verifies
every KV-domain branch is still live, and only then lets the state
store's epoch CAS decide the race — so a commit that loses in *any*
domain loses in *all* of them, and the loser's branches are unwound
rather than left half-committed (no stranded token tails, no leaked
page refcounts; see DESIGN §3).

Flags mirror Listing 1:

* ``BR_STATE``  (paper BR_FS, required) — fork the pytree store.
* ``BR_KV``     (paper BR_MEMORY)       — fork device generation state.
* ``BR_ISOLATE``                        — enforce that a context cannot
  address a sibling's handles (checked at the ``BranchHandle.group``
  accessor, the one API surface exposing siblings; inside one SPMD
  program isolation is otherwise structural).
* ``BR_CLOSE_FDS``                      — drop inherited open handles
  (the context re-opens leaves through its own chain).
"""

from __future__ import annotations

import contextlib
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.branch import BranchContext
from repro.core.errors import BranchError, BranchStateError, StaleBranchError
from repro.core.store import BranchStore

# operation codes (paper Listing 1)
BR_CREATE = 0
BR_COMMIT = 1
BR_ABORT = 2

# flags for BR_CREATE
BR_STATE = 1 << 0   # paper: BR_FS (required)
BR_KV = 1 << 1      # paper: BR_MEMORY
BR_ISOLATE = 1 << 2
BR_CLOSE_FDS = 1 << 3


@dataclass
class BranchHandle:
    """What a child receives from ``create``: its view of every domain."""

    index: int                       # 1..N, the paper's branch index
    state: Optional[BranchContext]   # BR_STATE domain
    kv_seqs: Dict[int, int] = field(default_factory=dict)  # parent seq -> forked seq
    flags: int = BR_STATE
    _resolved: bool = False
    _group: Tuple["BranchHandle", ...] = ()

    def _sibling_guard(self, other: "BranchHandle") -> None:
        if self.flags & BR_ISOLATE and other is not self:
            raise BranchError(
                "BR_ISOLATE: sibling branch handles are not addressable"
            )

    @property
    def group(self) -> Tuple["BranchHandle", ...]:
        """Every handle of this BR_CREATE set (the exclusive group).

        This is the API boundary where BR_ISOLATE is enforced: a handle
        created with the flag cannot address its siblings, so accessing
        the group (beyond a singleton, which is just ``self``) raises
        ``BranchError`` — an isolated context only ever holds its own
        view of each domain.
        """
        for h in self._group:
            self._sibling_guard(h)
        return self._group


class BranchRuntime:
    """Composes branch forks across state domains atomically."""

    def __init__(self, store: BranchStore,
                 kv_manager: Optional[Any] = None,
                 kv_fork: Optional[Callable[[int, int], List[int]]] = None):
        self.store = store
        self.kv = kv_manager  # duck-typed: fork(seq, n), commit(seq), abort(seq)
        # Injectable fork path for the KV domain: a serving stack passes
        # ``Scheduler.fork`` here so composite creates go through page-
        # budget admission (AdmissionDenied unwinds the store forks too)
        # instead of bypassing the reservation ledger.
        self.kv_fork = kv_fork or (kv_manager.fork if kv_manager else None)

    @classmethod
    def scheduled(cls, store: BranchStore, scheduler: Any) -> "BranchRuntime":
        """A runtime whose KV domain forks through scheduler admission."""
        return cls(store, scheduler.engine.kv, kv_fork=scheduler.fork)

    # ------------------------------------------------------------------
    def _kv_lock(self) -> contextlib.AbstractContextManager:
        """The KV kernel's lock, if the KV manager exposes one.

        Holding it across a composite commit serializes the cross-domain
        race decision against kernel-level commits on the same tree.
        """
        tree = getattr(self.kv, "tree", None)
        if tree is not None:
            return tree.lock
        return contextlib.nullcontext()

    # ------------------------------------------------------------------
    def create(
        self,
        parent: BranchContext,
        n_branches: int,
        flags: int = BR_STATE,
        kv_seqs: Sequence[int] = (),
    ) -> List[BranchHandle]:
        """BR_CREATE: fork ``n_branches`` contexts across all domains.

        Atomic: on any failure every domain already forked is unwound, so
        the caller never observes a half-created branch set.
        """
        if not flags & BR_STATE:
            raise ValueError("BR_STATE is required (paper: BR_FS required)")
        if n_branches < 1:
            raise ValueError("n_branches must be >= 1")

        done: List[Callable[[], None]] = []
        try:
            state_ctxs = parent.fork(n_branches)
            done.append(lambda: [c.abort() for c in state_ctxs if c.is_active])

            kv_maps: List[Dict[int, int]] = [dict() for _ in range(n_branches)]
            if flags & BR_KV:
                if self.kv is None:
                    raise BranchStateError("BR_KV requested but no kv manager")
                for seq in kv_seqs:
                    children = self.kv_fork(seq, n_branches)
                    for i, child_seq in enumerate(children):
                        kv_maps[i][seq] = child_seq
                    done.append(
                        lambda cs=children: [self.kv.abort(c) for c in cs
                                             if self.kv.is_live(c)]
                    )

            handles = [
                BranchHandle(index=i + 1, state=state_ctxs[i],
                             kv_seqs=kv_maps[i], flags=flags)
                for i in range(n_branches)
            ]
            for h in handles:
                h._group = tuple(handles)
            return handles
        except Exception:
            # kernel-side cleanup on failure: unwind in reverse order
            for undo in reversed(done):
                try:
                    undo()
                # best-effort unwind while the original error re-raises
                # below; a failing undo must not mask it
                except Exception:  # pragma: no cover  # branchlint: ignore[BL001]
                    pass
            raise

    # ------------------------------------------------------------------
    def commit(self, handle: BranchHandle) -> int:
        """BR_COMMIT: win the exclusive-group race or raise StaleBranchError.

        Order mirrors §5.2, but the race is decided *once* for the whole
        composite: under the KV kernel's lock we first verify every KV
        branch of this handle is still live (if any lost a kernel-level
        race, this handle lost everywhere — its remaining domains are
        unwound and ``StaleBranchError`` = -ESTALE is raised), then the
        state store's epoch CAS decides the group race, then the KV
        domain (and every domain attached to its kernel, e.g. serving
        token tails) promotes, then siblings are invalidated.
        """
        if handle._resolved:
            raise BranchStateError("handle already resolved")
        assert handle.state is not None
        use_kv = bool(handle.flags & BR_KV) and self.kv is not None
        with self._kv_lock() if use_kv else contextlib.nullcontext():
            if use_kv:
                dead = [c for c in handle.kv_seqs.values()
                        if not self.kv.is_live(c)]
                if dead:
                    # The KV domain already lost a first-commit-wins race:
                    # the composite commit loses atomically.  Unwind the
                    # still-live domains so nothing is stranded.
                    self.abort(handle)
                    raise StaleBranchError(
                        f"KV branches {dead} were invalidated by a sibling "
                        "commit; composite commit loses (-ESTALE)")
                tree = getattr(self.kv, "tree", None)
                if tree is not None:
                    busy = [c for c in handle.kv_seqs.values()
                            if tree.live_children(c)]
                    if busy:
                        # A frozen KV child would pass is_live but fail
                        # its kernel commit; refuse BEFORE the state CAS
                        # so no domain half-commits.
                        raise BranchStateError(
                            f"KV branches {busy} have live children; "
                            "resolve them before the composite commit")
            try:
                parent = handle.state.commit()  # first-commit-wins here
            except StaleBranchError:
                # The state domain lost the group race: the composite
                # commit loses atomically — unwind the KV domain too so
                # no pages or token tails outlive the loser.
                self.abort(handle)
                raise
            if use_kv:
                for parent_seq, child_seq in handle.kv_seqs.items():
                    self.kv.commit(child_seq)
        handle._resolved = True
        return parent

    def abort(self, handle: BranchHandle) -> None:
        """BR_ABORT: discard every domain's delta; siblings stay valid."""
        if handle._resolved:
            return
        if handle.state is not None and handle.state.is_active:
            handle.state.abort()
        if handle.flags & BR_KV and self.kv is not None:
            for child_seq in handle.kv_seqs.values():
                if self.kv.is_live(child_seq):
                    self.kv.abort(child_seq)
        handle._resolved = True

    # ------------------------------------------------------------------
    def __call__(self, op: int, **kwargs: Any) -> Any:
        """Multiplexed entry point in the style of ``bpf(2)`` / Listing 1.

        .. deprecated:: superseded by :class:`repro.api.BranchSession` —
           the one public ``branch()`` surface with a real flags word,
           handle table, errno discipline and poll/wait eventing.  The
           opcode dispatcher remains as a thin shim for existing callers.
        """
        import warnings

        warnings.warn(
            "BranchRuntime(op, ...) opcode dispatch is deprecated; use "
            "repro.api.BranchSession.branch()/commit()/abort() instead",
            DeprecationWarning, stacklevel=2)
        if op == BR_CREATE:
            return self.create(**kwargs)
        if op == BR_COMMIT:
            return self.commit(**kwargs)
        if op == BR_ABORT:
            return self.abort(**kwargs)
        raise ValueError(f"unknown branch() op {op}")


__all__ = [
    "BR_CREATE", "BR_COMMIT", "BR_ABORT",
    "BR_STATE", "BR_KV", "BR_ISOLATE", "BR_CLOSE_FDS",
    "BranchHandle", "BranchRuntime", "StaleBranchError",
]
