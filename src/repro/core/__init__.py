"""Branch contexts — the paper's primary contribution, realized for JAX.

Application code should enter through :mod:`repro.api` (the one
``branch()`` surface: handles, flags, errno, events); this package is
the kernel + domain layer underneath it.

Public API:

* :class:`BranchTree` / :class:`BranchDomain` — the branch-lifecycle
  kernel every state domain plugs into (ids, status, epochs, exclusive
  commit groups, first-commit-wins, sibling invalidation).
* :class:`BranchStore` / :class:`BranchContext` — leaf-granular CoW branch
  contexts over pytrees (host state domain, ≈ BranchFS).
* :class:`KVBranchManager` — CoW paged KV / recurrent-state branching
  (device state domain, ≈ BR_MEMORY).
* :class:`BranchRuntime` — the ``branch()`` analogue: atomic multi-domain
  fork/commit/abort with first-commit-wins.
* :mod:`repro.core.explore` — in-program N-way exploration with
  first-commit-wins collectives.
"""

from repro.core.branch import BranchContext, root_context
from repro.core.lifecycle import BranchDomain, BranchNode, BranchTree
from repro.core.errors import (
    BranchError,
    BranchStateError,
    FrozenOriginError,
    NoSuchLeafError,
    StaleBranchError,
)
from repro.core.explore import (
    ExploreResult,
    explore,
    first_commit_wins,
    fork_stacked,
    perturbed_fork,
    select_branch,
)
from repro.core.kvbranch import AppendSlot, CowOp, KVBranchManager, SeqStatus
from repro.core.kvtier import KVSnapshot, KVTierStore
from repro.core.runtime_api import (
    BR_ABORT,
    BR_CLOSE_FDS,
    BR_COMMIT,
    BR_CREATE,
    BR_ISOLATE,
    BR_KV,
    BR_STATE,
    BranchHandle,
    BranchRuntime,
)
from repro.core.store import TOMBSTONE, BranchStatus, BranchStore
from repro.core.store import explore as explore_threads

__all__ = [
    "BranchContext", "root_context",
    "BranchDomain", "BranchNode", "BranchTree",
    "BranchError", "BranchStateError", "FrozenOriginError",
    "NoSuchLeafError", "StaleBranchError",
    "ExploreResult", "explore", "explore_threads", "first_commit_wins",
    "fork_stacked", "perturbed_fork", "select_branch",
    "AppendSlot", "CowOp", "KVBranchManager", "SeqStatus",
    "KVSnapshot", "KVTierStore",
    "BR_ABORT", "BR_CLOSE_FDS", "BR_COMMIT", "BR_CREATE", "BR_ISOLATE",
    "BR_KV", "BR_STATE", "BranchHandle", "BranchRuntime",
    "TOMBSTONE", "BranchStatus", "BranchStore",
]
