"""BranchContext — the object-level lifecycle API over :class:`BranchStore`.

A ``BranchContext`` is the paper's branch context (§3.1): an isolated view
of state following the fork/explore/commit lifecycle.  It wraps one node
of a :class:`BranchStore` and adds:

* context-manager semantics — leaving the ``with`` block without a commit
  aborts the branch (no side effects escape, R2);
* pytree snapshot/restore helpers for training states;
* nested forking (R3).
"""

from __future__ import annotations

from typing import Any, Dict, List, Mapping, Optional

from repro.core.errors import BranchStateError
from repro.core.store import BranchStatus, BranchStore


class BranchContext:
    """One branch context bound to a store node."""

    def __init__(self, store: BranchStore, branch_id: int):
        self.store = store
        self.branch_id = branch_id
        self._resolved = False

    # -- lifecycle ------------------------------------------------------
    def fork(self, n: int = 1) -> List["BranchContext"]:
        """Fork ``n`` child contexts (this context becomes a frozen origin)."""
        return [
            BranchContext(self.store, bid)
            for bid in self.store.fork(self.branch_id, n=n)
        ]

    def commit(self) -> int:
        """First-commit-wins atomic commit to the immediate parent."""
        parent = self.store.commit(self.branch_id)
        self._resolved = True
        return parent

    def abort(self) -> None:
        self.store.abort(self.branch_id)
        self._resolved = True

    @property
    def status(self) -> BranchStatus:
        return self.store.status(self.branch_id)

    @property
    def is_active(self) -> bool:
        return self.status is BranchStatus.ACTIVE

    # -- namespace ------------------------------------------------------
    def read(self, path: str) -> Any:
        return self.store.read(self.branch_id, path)

    def write(self, path: str, value: Any) -> None:
        self.store.write(self.branch_id, path, value)

    def write_many(self, items: Mapping[str, Any]) -> None:
        self.store.write_many(self.branch_id, items)

    def delete(self, path: str) -> None:
        self.store.delete(self.branch_id, path)

    def listdir(self) -> List[str]:
        return self.store.listdir(self.branch_id)

    def exists(self, path: str) -> bool:
        return self.store.exists(self.branch_id, path)

    # -- pytree helpers ---------------------------------------------------
    def snapshot(self, tree: Any, prefix: str = "") -> None:
        self.store.snapshot_pytree(self.branch_id, tree, prefix)

    def restore(self, like: Any, prefix: str = "") -> Any:
        return self.store.restore_pytree(self.branch_id, like, prefix)

    def consolidated_view(self) -> Dict[str, Any]:
        return self.store.consolidated_view(self.branch_id)

    # -- context manager --------------------------------------------------
    def __enter__(self) -> "BranchContext":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        if not self._resolved and self.is_active:
            # Leaving the scope without commit == abort: no side effects
            # escape an unresolved branch (R2).
            try:
                self.abort()
            except BranchStateError:
                pass
        return False

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"BranchContext(id={self.branch_id}, status={self.status.value})"


def root_context(store: Optional[BranchStore] = None,
                 base: Optional[Mapping[str, Any]] = None) -> BranchContext:
    """Create a store (if needed) and return its root context."""
    if store is None:
        store = BranchStore(base)
    return BranchContext(store, BranchStore.ROOT)
