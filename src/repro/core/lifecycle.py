"""The branch-lifecycle kernel — one state machine for every domain.

The paper's central design point (§5) is that fork/explore/commit is *one*
OS primitive: a single kernel object owns branch identity, parent/child
links, status, epochs, exclusive commit groups, frozen-origin enforcement,
first-commit-wins arbitration, and recursive sibling invalidation — and
every state domain (filesystem, memory, process group) plugs into it
through narrow hooks.  This module is that kernel for branchx:

* :class:`BranchTree` — the thread-safe lifecycle state machine.  It owns
  *no* domain data (no deltas, no page tables, no token tails); it owns
  the transitions and decides every race under one lock.
* :class:`BranchDomain` — the plug-in protocol.  A domain receives
  ``on_fork / on_commit / on_abort / on_invalidate`` callbacks, always
  under the tree lock, and moves its own payload (delta dicts, block
  tables, token lists) accordingly.

Domains in-tree (DESIGN §2):

=====================  ============================  ==================
paper primitive        domain                         module
=====================  ============================  ==================
BR_FS                  pytree delta dicts             core/store.py
BR_MEMORY              KV block tables + refcounts    core/kvbranch.py
process group          serving token tails            runtime/serve_loop.py
branch() syscall       multi-domain composition       core/runtime_api.py
=====================  ============================  ==================

Lifecycle invariants enforced here (and only here):

* **First-commit-wins** — a commit is a CAS on the parent's epoch taken
  under the tree lock; the winner bumps the epoch, so every sibling's
  next liveness check fails (``StaleBranchError`` = ``-ESTALE``).
* **Frozen origin** — with ``freeze_on_fork=True`` the parent's *status*
  becomes FROZEN while children are live (KV semantics: appends denied,
  parent resumes when all children resolve).  With ``freeze_on_fork=
  False`` the origin stays ACTIVE and callers gate writes on
  :meth:`BranchTree.has_live_children` (store semantics).
* **Recursive sibling invalidation** — the winner's commit (or an abort)
  walks every losing subtree depth-first, firing ``on_invalidate`` per
  node so domains reclaim payloads (deltas dropped, pages decref'd,
  token tails popped).
* **Exclusive commit groups** — every ``fork(parent, n)`` batch shares a
  group id (the paper's BR_CREATE set); at most one member commits.
"""

from __future__ import annotations

import itertools
import threading
from dataclasses import dataclass, field
from enum import Enum
from typing import Dict, Iterator, List, Optional, Protocol, runtime_checkable

from repro.core.errors import BranchStateError, StaleBranchError
from repro.obs.tracer import NULL_TRACER, Tracer


class BranchStatus(Enum):
    """Unified status vocabulary across all state domains."""

    ACTIVE = "active"
    FROZEN = "frozen"        # live children exist (freeze_on_fork domains)
    COMMITTED = "committed"
    ABORTED = "aborted"
    STALE = "stale"          # invalidated by a sibling's commit (-ESTALE)


#: statuses that count as "live" (may still resolve to a commit/abort)
LIVE = (BranchStatus.ACTIVE, BranchStatus.FROZEN)


@dataclass
class BranchNode:
    """Pure lifecycle bookkeeping for one branch — no domain payload."""

    branch_id: int
    parent: Optional[int]
    status: BranchStatus = BranchStatus.ACTIVE
    # Parent epoch observed at fork time.  A commit is valid only while
    # the parent's epoch is unchanged; the winning commit bumps it, so
    # every sibling's next check fails (-ESTALE).
    parent_epoch_at_fork: int = 0
    epoch: int = 0           # bumped when *this* node accepts a commit
    children: List[int] = field(default_factory=list)
    group: Optional[int] = None   # exclusive commit group (BR_CREATE set)


@runtime_checkable
class BranchDomain(Protocol):
    """Payload hooks a state domain registers with :class:`BranchTree`.

    All hooks run under the tree lock, after the kernel has decided the
    transition is legal; a domain must not re-enter the tree's lifecycle
    methods from inside a hook.
    """

    def on_fork(self, parent: int, children: List[int]) -> None:
        """Materialize each child's payload as a view of the parent's."""

    def on_commit(self, child: int, parent: int) -> None:
        """Fold the winning child's payload into the parent's."""

    def on_abort(self, branch: int) -> None:
        """Drop the payload of a voluntarily aborted branch."""

    def on_invalidate(self, branch: int) -> None:
        """Drop the payload of a branch invalidated by a sibling's win.

        Must be idempotent: stale branches may be cleaned up twice
        (eagerly by the winner, again by a caller's abort-after-ESTALE).
        """

    def on_reap(self, branch: int) -> None:
        """Forget a reaped branch's payload *entry* entirely (GC).

        Fired when :meth:`BranchTree.reap` removes a fully-resolved node
        from the tree; the id ceases to exist afterwards, so the domain
        must drop the key itself, not just empty the value.  Optional:
        domains that do not define the hook are skipped.
        """


class BranchTree:
    """Thread-safe branch lifecycle shared by every state domain.

    Parameters
    ----------
    freeze_on_fork:
        If True, forking flips the origin's status to FROZEN until all
        children resolve (KV semantics).  If False the origin stays
        ACTIVE and only :meth:`has_live_children` reports the freeze
        (store semantics, where committed interior nodes remain
        forkable).
    allow_fork_resolved:
        If True, COMMITTED nodes may be forked from (their payload was
        merged upward but chain resolution still works — store
        semantics).
    tracer:
        Optional :class:`repro.obs.Tracer`.  When enabled, every branch
        carries one ``explore`` span from fork to resolution (track =
        branch id, process = the root of its exploration subtree) plus
        instant events for fork/commit/abort/invalidated/frozen/resumed
        — the span tree mirrors the branch tree.  Defaults to the
        shared disabled :data:`~repro.obs.tracer.NULL_TRACER`, so every
        emit site below costs one predicted branch when tracing is off.
    """

    def __init__(self, *, freeze_on_fork: bool = False,
                 allow_fork_resolved: bool = False,
                 tracer: Optional[Tracer] = None):
        self.lock = threading.RLock()
        self._ids = itertools.count(0)
        self._groups = itertools.count(1)
        self._nodes: Dict[int, BranchNode] = {}
        self._domains: List[BranchDomain] = []
        self.freeze_on_fork = freeze_on_fork
        self.allow_fork_resolved = allow_fork_resolved
        self.tracer = NULL_TRACER if tracer is None else tracer

    # ------------------------------------------------------------------
    # domain registration
    # ------------------------------------------------------------------
    def attach(self, domain: BranchDomain) -> None:
        """Register a payload domain; hooks fire in attach order."""
        with self.lock:
            if domain not in self._domains:
                self._domains.append(domain)

    # ------------------------------------------------------------------
    # node access / liveness
    # ------------------------------------------------------------------
    def node(self, branch_id: int) -> BranchNode:
        try:
            return self._nodes[branch_id]
        except KeyError:
            raise BranchStateError(
                f"unknown branch id {branch_id!r}") from None

    def __contains__(self, branch_id: int) -> bool:
        return branch_id in self._nodes

    def check_live(self, branch_id: int) -> BranchNode:
        """Raise unless the branch may still resolve (ACTIVE or FROZEN).

        Performs the lazy epoch check: if the parent's epoch moved past
        the fork-time snapshot, a sibling committed and this branch is
        stale even if not yet eagerly marked.
        """
        with self.lock:
            node = self.node(branch_id)
            if node.status is BranchStatus.STALE:
                raise StaleBranchError(
                    f"branch {branch_id} was invalidated by a sibling "
                    "commit (-ESTALE)")
            if node.status not in LIVE:
                raise BranchStateError(
                    f"branch {branch_id} is {node.status.value}, not live")
            if node.parent is not None:
                parent = self._nodes[node.parent]
                if parent.epoch != node.parent_epoch_at_fork:
                    node.status = BranchStatus.STALE
                    self._trace_resolve(branch_id, "invalidated",
                                        "invalidated")
                    raise StaleBranchError(
                        f"branch {branch_id} is stale (parent epoch "
                        f"{parent.epoch} != {node.parent_epoch_at_fork} "
                        "at fork)")
            return node

    def is_live(self, branch_id: int) -> bool:
        with self.lock:
            if branch_id not in self._nodes:
                return False
            try:
                self.check_live(branch_id)
            except (StaleBranchError, BranchStateError):
                return False
            return True

    def status(self, branch_id: int) -> BranchStatus:
        """Current status with the lazy stale check applied."""
        with self.lock:
            node = self.node(branch_id)
            if node.status in LIVE and node.parent is not None:
                parent = self._nodes[node.parent]
                if parent.epoch != node.parent_epoch_at_fork:
                    node.status = BranchStatus.STALE
                    self._trace_resolve(branch_id, "invalidated",
                                        "invalidated")
            return node.status

    def epoch(self, branch_id: int) -> int:
        return self.node(branch_id).epoch

    def live_children(self, branch_id: int) -> List[int]:
        with self.lock:
            return [c for c in self.node(branch_id).children
                    if self._nodes[c].status in LIVE]

    def has_live_children(self, branch_id: int) -> bool:
        return bool(self.live_children(branch_id))

    def chain(self, branch_id: int) -> Iterator[int]:
        """Yield ids from ``branch_id`` up to and including its root."""
        cur: Optional[int] = branch_id
        while cur is not None:
            yield cur
            cur = self._nodes[cur].parent

    def chain_depth(self, branch_id: int) -> int:
        with self.lock:
            self.node(branch_id)
            return sum(1 for _ in self.chain(branch_id)) - 1

    # ------------------------------------------------------------------
    # lifecycle transitions
    # ------------------------------------------------------------------
    def _trace_resolve(self, branch_id: int, status: str,
                       event: Optional[str] = None) -> None:
        """Close a branch's explore-span and fire its resolution instant.

        ``end_span`` pops the track's open span and returns False when
        nothing is open, so racing closers — eager sibling
        invalidation, a lazy -ESTALE discovery in ``check_live``/
        ``status``, an abort-after-ESTALE, a scheduler purge's
        ``reap`` — resolve to exactly one span close and exactly one
        instant per branch, never a double-close or a leak.
        """
        tr = self.tracer
        if tr.enabled and tr.end_span(branch_id, status=status) and event:
            tr.instant(branch_id, event)

    def create_root(self) -> int:
        """Create a parentless branch (a new tree root / base namespace)."""
        with self.lock:
            bid = next(self._ids)
            self._nodes[bid] = BranchNode(branch_id=bid, parent=None)
            if self.tracer.enabled:
                self.tracer.begin_span(bid, "explore", group=bid, root=True)
            return bid

    def fork(self, parent: int, n: int = 1) -> List[int]:
        """Create ``n`` sibling branches in one exclusive commit group.

        O(1) per branch in the kernel; domains pay only their own
        payload-view cost in ``on_fork``.
        """
        if n < 1:
            raise ValueError("n must be >= 1")
        with self.lock:
            pnode = self.node(parent)
            if pnode.status is BranchStatus.COMMITTED:
                if not self.allow_fork_resolved:
                    raise BranchStateError(
                        f"branch {parent} is committed and this tree "
                        "does not allow forking resolved branches")
            else:
                self.check_live(parent)
            group = next(self._groups)
            children: List[int] = []
            for _ in range(n):
                bid = next(self._ids)
                self._nodes[bid] = BranchNode(
                    branch_id=bid,
                    parent=parent,
                    parent_epoch_at_fork=pnode.epoch,
                    group=group,
                )
                pnode.children.append(bid)
                children.append(bid)
            for domain in self._domains:
                domain.on_fork(parent, children)
            frozen = False
            if self.freeze_on_fork and pnode.status is BranchStatus.ACTIVE:
                pnode.status = BranchStatus.FROZEN
                frozen = True
            tr = self.tracer
            if tr.enabled:
                pg = tr.group_of(parent, parent)
                for bid in children:
                    tr.begin_span(bid, "explore", parent=parent, group=pg,
                                  fork_group=group)
                tr.instant(parent, "fork", children=list(children),
                           group=group)
                if frozen:
                    tr.instant(parent, "frozen")
            return children

    def commit(self, branch_id: int) -> int:
        """First-commit-wins: CAS on the parent's epoch under the lock.

        On success: domain payloads fold upward (``on_commit``), the
        parent's epoch bumps, every live sibling subtree is invalidated
        (``on_invalidate`` per node), and a frozen parent resumes
        ACTIVE.  Returns the parent id (the PID-takeover of BR_COMMIT).
        """
        with self.lock:
            node = self.check_live(branch_id)   # loser -> StaleBranchError
            if self.has_live_children(branch_id):
                raise BranchStateError(
                    f"branch {branch_id} has live children; commit or "
                    "abort them first (commit applies to the immediate "
                    "parent only)")
            if node.parent is None:
                raise BranchStateError("root branch cannot commit")
            parent = self._nodes[node.parent]
            for domain in self._domains:
                domain.on_commit(branch_id, parent.branch_id)
            node.status = BranchStatus.COMMITTED
            parent.epoch += 1   # the CAS bump: every sibling is now stale
            self._trace_resolve(branch_id, "committed", "commit")
            for sid in parent.children:
                if sid != branch_id and self._nodes[sid].status in LIVE:
                    self._invalidate(self._nodes[sid])
            if parent.status is BranchStatus.FROZEN:
                parent.status = BranchStatus.ACTIVE
                if self.tracer.enabled:
                    self.tracer.instant(parent.branch_id, "resumed")
            return parent.branch_id

    def abort(self, branch_id: int) -> None:
        """Discard the branch; siblings stay valid.

        Aborting a STALE branch is allowed as cleanup-after-ESTALE and
        only re-fires ``on_invalidate`` (idempotent).  If all children
        of a frozen origin resolve, the origin resumes ACTIVE.
        """
        with self.lock:
            node = self.node(branch_id)
            if node.status is BranchStatus.STALE:
                for domain in self._domains:
                    domain.on_invalidate(branch_id)
                return
            if node.status not in LIVE:
                raise BranchStateError(
                    f"branch {branch_id} is {node.status.value}")
            for cid in node.children:
                if self._nodes[cid].status in LIVE:
                    self._invalidate(self._nodes[cid])
            node.status = BranchStatus.ABORTED
            for domain in self._domains:
                domain.on_abort(branch_id)
            self._trace_resolve(branch_id, "aborted", "aborted")
            self._maybe_resume_parent(node)

    def invalidate(self, branch_id: int,
                   status: BranchStatus = BranchStatus.STALE) -> None:
        """Forcibly invalidate a subtree (serving-slot eviction, OOM...).

        Unlike :meth:`abort` this works on any live node — including a
        root — and does not resume a frozen parent.
        """
        with self.lock:
            node = self.node(branch_id)
            if node.status in LIVE:
                self._invalidate(node, status=status)

    def _invalidate(self, node: BranchNode,
                    status: BranchStatus = BranchStatus.STALE) -> None:
        for cid in node.children:
            child = self._nodes[cid]
            if child.status in LIVE:
                self._invalidate(child)
        node.status = status
        for domain in self._domains:
            domain.on_invalidate(node.branch_id)
        self._trace_resolve(
            node.branch_id,
            "invalidated" if status is BranchStatus.STALE else status.value,
            "invalidated")

    def reap(self, branch_id: int) -> int:
        """Garbage-collect a fully-resolved subtree from the kernel.

        Resolved nodes are kept so callers can observe COMMITTED / STALE
        / ABORTED outcomes, but in a long-running serving loop — where
        every request and fork allocates fresh ids — that history grows
        without bound.  Once a subtree can no longer transition (no LIVE
        member), the serving layer reaps it: every node is removed from
        the tree, unlinked from its parent, and each domain drops its
        payload entry via ``on_reap``.  Returns the number of nodes
        removed; 0 (and no change) if the id is unknown or the subtree
        still has a live member.
        """
        with self.lock:
            if branch_id not in self._nodes:
                return 0
            members: List[BranchNode] = []
            stack = [self._nodes[branch_id]]
            while stack:
                cur = stack.pop()
                # status() applies the lazy -ESTALE check, so a node that
                # merely *looks* ACTIVE after a sibling commit still reaps
                if self.status(cur.branch_id) in LIVE:
                    return 0
                members.append(cur)
                stack.extend(self._nodes[c] for c in cur.children)
            root = self._nodes[branch_id]
            if root.parent is not None and root.parent in self._nodes:
                siblings = self._nodes[root.parent].children
                if branch_id in siblings:
                    siblings.remove(branch_id)
            for node in reversed(members):   # children before parents
                del self._nodes[node.branch_id]
                for domain in self._domains:
                    hook = getattr(domain, "on_reap", None)
                    if hook is not None:
                        hook(node.branch_id)
                # a scheduler purge may reap descendants whose lazy
                # -ESTALE was never observed: their explore-spans are
                # still open and must close as invalidated here (the
                # one-shot guard makes this a no-op for already-closed
                # tracks)
                self._trace_resolve(node.branch_id, "invalidated",
                                    "invalidated")
            return len(members)

    def _maybe_resume_parent(self, node: BranchNode) -> None:
        if not self.freeze_on_fork or node.parent is None:
            return
        parent = self._nodes[node.parent]
        if parent.status is BranchStatus.FROZEN and not any(
                self._nodes[c].status in LIVE for c in parent.children):
            # all children resolved -> the origin resumes (paper §5.2:
            # "if all branches abort, the parent resumes")
            parent.status = BranchStatus.ACTIVE
            if self.tracer.enabled:
                self.tracer.instant(parent.branch_id, "resumed")

    # ------------------------------------------------------------------
    # introspection
    # ------------------------------------------------------------------
    def snapshot(self) -> List[dict]:
        """Procfs-style view of the whole forest (for ``repro.api``'s
        ``tree()``): one nested dict per root, each node carrying its
        id, lazily-checked status, exclusive group and epoch.  Read-only
        and taken under the lock, so it is a consistent cut of the
        lifecycle state.
        """
        with self.lock:
            def view(bid: int) -> dict:
                node = self._nodes[bid]
                return {
                    "id": bid,
                    "status": self.status(bid).value,
                    "group": node.group,
                    "epoch": node.epoch,
                    "children": [view(c) for c in node.children
                                 if c in self._nodes],
                }
            return [view(bid) for bid, node in self._nodes.items()
                    if node.parent is None or node.parent not in self._nodes]

    def live_count(self) -> int:
        with self.lock:
            return sum(1 for n in self._nodes.values() if n.status in LIVE)

    def __len__(self) -> int:
        return len(self._nodes)


__all__ = [
    "LIVE",
    "BranchDomain",
    "BranchNode",
    "BranchStatus",
    "BranchTree",
]
