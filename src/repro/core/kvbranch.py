"""Branched paged KV caches — BR_MEMORY for the accelerator.

The paper's ``BR_MEMORY`` flag branches process memory via page-table
copy-on-write.  The accelerator-resident mutable state of an LLM agent is
its **KV cache** (attention archs) or **recurrent state** (SSM archs), and
the TPU-native analogue of page-table CoW is a **block table** over fixed-
size KV pages in HBM:

* pages are the CoW quantum (file ↔ page);
* a fork copies only the block table (O(pages_in_table) ints, no HBM
  traffic) and bumps per-page refcounts — creation cost is independent of
  context length *content* (paper Table 4's O(1)-in-base-size claim,
  measured in ``benchmarks/kvbranch_bench.py``);
* a write to a shared page (appending a token to the tail page) triggers
  CoW: allocate a fresh page, copy one page of KV, update the table;
* commit promotes the child's table to the parent and invalidates
  siblings (their pages are decref'd and recycled) — first-commit-wins;
* nesting falls out of fork-of-fork.

Host metadata (tables, refcounts, free list) lives here; the page buffers
themselves are device arrays owned by the serving engine and mutated
functionally (``jax.Array.at``) or by the Pallas paged-attention kernel.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from enum import Enum
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.errors import (
    BranchStateError,
    FrozenOriginError,
    StaleBranchError,
)


class SeqStatus(Enum):
    ACTIVE = "active"
    FROZEN = "frozen"      # has live children (frozen origin)
    COMMITTED = "committed"
    ABORTED = "aborted"
    STALE = "stale"


@dataclass
class _Seq:
    seq_id: int
    block_table: List[int]
    length: int
    parent: Optional[int] = None
    children: List[int] = field(default_factory=list)
    status: SeqStatus = SeqStatus.ACTIVE
    parent_epoch_at_fork: int = 0
    epoch: int = 0


@dataclass(frozen=True)
class CowOp:
    """A device-side page copy the caller must perform before appending."""

    src_page: int
    dst_page: int


@dataclass(frozen=True)
class AppendSlot:
    """Where the next token's KV goes for one sequence."""

    page: int
    offset: int
    cow: Tuple[CowOp, ...] = ()


class KVBranchManager:
    """Block tables + refcounts + branch lifecycle for paged KV caches."""

    def __init__(self, num_pages: int, page_size: int):
        if num_pages < 1 or page_size < 1:
            raise ValueError("num_pages and page_size must be positive")
        self.num_pages = num_pages
        self.page_size = page_size
        self._free: List[int] = list(range(num_pages - 1, -1, -1))
        self._refcount = np.zeros((num_pages,), dtype=np.int32)
        self._seqs: Dict[int, _Seq] = {}
        self._ids = itertools.count(0)

    # ------------------------------------------------------------------
    # page accounting
    # ------------------------------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free)

    def refcount(self, page: int) -> int:
        return int(self._refcount[page])

    def _alloc_page(self) -> int:
        if not self._free:
            raise MemoryError("KV page pool exhausted (-ENOSPC analogue)")
        page = self._free.pop()
        self._refcount[page] = 1
        return page

    def _incref(self, pages: Sequence[int]) -> None:
        for p in pages:
            self._refcount[p] += 1

    def _decref(self, pages: Sequence[int]) -> None:
        for p in pages:
            self._refcount[p] -= 1
            if self._refcount[p] == 0:
                self._free.append(p)
            assert self._refcount[p] >= 0, f"page {p} refcount underflow"

    # ------------------------------------------------------------------
    # sequence lifecycle
    # ------------------------------------------------------------------
    def _seq(self, seq_id: int) -> _Seq:
        try:
            return self._seqs[seq_id]
        except KeyError:
            raise BranchStateError(f"unknown sequence {seq_id}") from None

    def _check_live(self, seq: _Seq) -> None:
        if seq.status is SeqStatus.STALE:
            raise StaleBranchError(f"sequence {seq.seq_id} is stale (-ESTALE)")
        if seq.status in (SeqStatus.COMMITTED, SeqStatus.ABORTED):
            raise BranchStateError(
                f"sequence {seq.seq_id} is {seq.status.value}"
            )
        if seq.parent is not None:
            parent = self._seqs[seq.parent]
            if parent.epoch != seq.parent_epoch_at_fork:
                seq.status = SeqStatus.STALE
                raise StaleBranchError(
                    f"sequence {seq.seq_id} is stale (-ESTALE)"
                )

    def is_live(self, seq_id: int) -> bool:
        seq = self._seqs.get(seq_id)
        if seq is None:
            return False
        try:
            self._check_live(seq)
        except (StaleBranchError, BranchStateError):
            return False
        return True

    def new_seq(self, length: int = 0) -> int:
        """Create a root sequence with enough pages for ``length`` tokens."""
        n_pages = -(-max(length, 0) // self.page_size)
        table = [self._alloc_page() for _ in range(n_pages)]
        sid = next(self._ids)
        self._seqs[sid] = _Seq(seq_id=sid, block_table=table, length=length)
        return sid

    def length(self, seq_id: int) -> int:
        return self._seq(seq_id).length

    def block_table(self, seq_id: int) -> List[int]:
        return list(self._seq(seq_id).block_table)

    # ------------------------------------------------------------------
    # fork / append(CoW) / commit / abort
    # ------------------------------------------------------------------
    def fork(self, seq_id: int, n: int = 1) -> List[int]:
        """Fork ``n`` children sharing every page of the parent.

        O(table length) integer work, zero HBM traffic; the parent becomes
        a frozen origin until all children resolve.
        """
        parent = self._seq(seq_id)
        self._check_live(parent)
        out: List[int] = []
        for _ in range(n):
            self._incref(parent.block_table)
            cid = next(self._ids)
            self._seqs[cid] = _Seq(
                seq_id=cid,
                block_table=list(parent.block_table),
                length=parent.length,
                parent=seq_id,
                parent_epoch_at_fork=parent.epoch,
            )
            parent.children.append(cid)
            out.append(cid)
        parent.status = SeqStatus.FROZEN
        return out

    def prepare_append(self, seq_id: int, n_tokens: int = 1) -> List[AppendSlot]:
        """Reserve slots for the next ``n_tokens`` tokens of ``seq_id``.

        Returns one :class:`AppendSlot` per token; any CoW page copies the
        device must perform are attached to the slot that triggers them.
        The block table and length are updated eagerly (metadata is the
        source of truth; device writes follow).
        """
        seq = self._seq(seq_id)
        self._check_live(seq)
        if seq.status is SeqStatus.FROZEN:
            raise FrozenOriginError(
                f"sequence {seq_id} has live children and is frozen"
            )
        slots: List[AppendSlot] = []
        for _ in range(n_tokens):
            offset = seq.length % self.page_size
            cow: Tuple[CowOp, ...] = ()
            if offset == 0:
                # new page needed
                page = self._alloc_page()
                seq.block_table.append(page)
            else:
                page = seq.block_table[-1]
                if self._refcount[page] > 1:
                    # shared tail page: copy-on-write
                    new_page = self._alloc_page()
                    cow = (CowOp(src_page=page, dst_page=new_page),)
                    self._decref([page])
                    seq.block_table[-1] = new_page
                    page = new_page
            seq.length += 1
            slots.append(AppendSlot(page=page, offset=offset, cow=cow))
        return slots

    def commit(self, seq_id: int) -> int:
        """First-commit-wins: promote this child's table into the parent.

        Siblings turn STALE and their page references are recycled.
        Returns the parent sequence id (which resumes ACTIVE with the
        child's content, PID-takeover style).
        """
        seq = self._seq(seq_id)
        self._check_live(seq)
        if seq.children and any(
            self._seqs[c].status in (SeqStatus.ACTIVE, SeqStatus.FROZEN)
            for c in seq.children
        ):
            raise BranchStateError(
                f"sequence {seq_id} has live children; resolve them first"
            )
        if seq.parent is None:
            raise BranchStateError("root sequence cannot commit")
        parent = self._seqs[seq.parent]
        # 1. win the race (epoch CAS under the GIL-protected metadata)
        parent.epoch += 1
        # 2. parent adopts the child's table (transfer the child's refs)
        self._decref(parent.block_table)
        parent.block_table = list(seq.block_table)
        parent.length = seq.length
        seq.status = SeqStatus.COMMITTED
        # 3. invalidate siblings, recycle their pages
        for cid in parent.children:
            sib = self._seqs[cid]
            if cid != seq_id and sib.status in (SeqStatus.ACTIVE, SeqStatus.FROZEN):
                self._invalidate(sib)
        parent.children = []
        parent.status = SeqStatus.ACTIVE
        return parent.seq_id

    def abort(self, seq_id: int) -> None:
        """Discard the branch; siblings stay valid; parent may resume."""
        seq = self._seq(seq_id)
        if seq.status is SeqStatus.STALE:
            return  # already recycled by the winner's commit
        if seq.status in (SeqStatus.COMMITTED, SeqStatus.ABORTED):
            raise BranchStateError(f"sequence {seq_id} is {seq.status.value}")
        self._invalidate(seq, status=SeqStatus.ABORTED)
        if seq.parent is not None:
            parent = self._seqs[seq.parent]
            if parent.status is SeqStatus.FROZEN and not any(
                self._seqs[c].status in (SeqStatus.ACTIVE, SeqStatus.FROZEN)
                for c in parent.children
            ):
                # all children resolved -> the parent resumes (paper §5.2:
                # "if all branches abort, the parent resumes")
                parent.status = SeqStatus.ACTIVE
                parent.children = []

    def _invalidate(self, seq: _Seq, status: SeqStatus = SeqStatus.STALE) -> None:
        for cid in seq.children:
            child = self._seqs[cid]
            if child.status in (SeqStatus.ACTIVE, SeqStatus.FROZEN):
                self._invalidate(child)
        self._decref(seq.block_table)
        seq.block_table = []
        seq.status = status

    def release(self, seq_id: int) -> None:
        """Free a root/active sequence outright (serving-slot eviction)."""
        seq = self._seq(seq_id)
        if seq.status in (SeqStatus.ACTIVE, SeqStatus.FROZEN):
            self._invalidate(seq, status=SeqStatus.ABORTED)

    # ------------------------------------------------------------------
    # dense views for the device step
    # ------------------------------------------------------------------
    def dense_block_tables(
        self, seq_ids: Sequence[int], max_pages: int
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Pack block tables into ``[batch, max_pages]`` (pad = 0) plus
        lengths ``[batch]`` for the paged-attention kernel."""
        bt = np.zeros((len(seq_ids), max_pages), dtype=np.int32)
        lens = np.zeros((len(seq_ids),), dtype=np.int32)
        for i, sid in enumerate(seq_ids):
            seq = self._seq(sid)
            table = seq.block_table
            if len(table) > max_pages:
                raise ValueError(
                    f"sequence {sid} needs {len(table)} pages > {max_pages}"
                )
            bt[i, : len(table)] = table
            lens[i] = seq.length
        return bt, lens

    def stats(self) -> Dict[str, int]:
        live = sum(
            1
            for s in self._seqs.values()
            if s.status in (SeqStatus.ACTIVE, SeqStatus.FROZEN)
        )
        return {
            "sequences_live": live,
            "pages_total": self.num_pages,
            "pages_free": len(self._free),
            "pages_shared": int((self._refcount > 1).sum()),
        }
