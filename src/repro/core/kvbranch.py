"""Branched paged KV caches — BR_MEMORY for the accelerator.

The paper's ``BR_MEMORY`` flag branches process memory via page-table
copy-on-write.  The accelerator-resident mutable state of an LLM agent is
its **KV cache** (attention archs) or **recurrent state** (SSM archs), and
the TPU-native analogue of page-table CoW is a **block table** over fixed-
size KV pages in HBM:

* pages are the CoW quantum (file ↔ page);
* a fork copies only the block table (O(pages_in_table) ints, no HBM
  traffic) and bumps per-page refcounts — creation cost is independent of
  context length *content* (paper Table 4's O(1)-in-base-size claim,
  measured in ``benchmarks/kvbranch_bench.py``);
* a write to a shared page (appending a token to the tail page) triggers
  CoW: allocate a fresh page, copy one page of KV, update the table;
* commit promotes the child's table to the parent and invalidates
  siblings (their pages are decref'd and recycled) — first-commit-wins;
* nesting falls out of fork-of-fork.

The lifecycle state machine (status, epochs, first-commit-wins CAS,
frozen origins, sibling invalidation) lives in the shared kernel,
:class:`~repro.core.lifecycle.BranchTree`; this class is the BR_MEMORY
payload domain plugged into it (DESIGN §2).  It owns only block tables,
refcounts and the free list, moved by the ``on_fork/on_commit/on_abort/
on_invalidate`` hooks.  Additional domains (e.g. the serving engine's
token tails) may attach to the *same* tree, so one ``commit(seq)``
atomically resolves every domain keyed by that sequence id.

Host metadata (tables, refcounts, free list) lives here; the page buffers
themselves are device arrays owned by the serving engine and mutated
functionally (``jax.Array.at``) or by the Pallas paged-attention kernel.
"""

from __future__ import annotations

import hashlib
from collections import Counter
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.errors import (
    BranchError,
    BranchStateError,
    Errno,
    FrozenOriginError,
    PoolExhausted,
)
from repro.core.lifecycle import LIVE, BranchStatus, BranchTree
from repro.obs import Observability

# Historical alias: sequence status *is* branch status now that every
# domain shares the kernel's vocabulary.
SeqStatus = BranchStatus


@dataclass(frozen=True)
class CowOp:
    """A device-side page copy the caller must perform before appending."""

    src_page: int
    dst_page: int


@dataclass(frozen=True)
class AppendSlot:
    """Where the next token's KV goes for one sequence."""

    page: int
    offset: int
    cow: Tuple[CowOp, ...] = ()


class KVBranchManager:
    """Block tables + refcounts plugged into the branch-lifecycle kernel."""

    def __init__(self, num_pages: int, page_size: int, *,
                 obs: Observability = None):
        if num_pages < 1 or page_size < 1:
            raise ValueError("num_pages and page_size must be positive")
        self.num_pages = num_pages
        self.page_size = page_size
        self._free: List[int] = list(range(num_pages - 1, -1, -1))
        self._refcount = np.zeros((num_pages,), dtype=np.int32)
        self.obs = Observability() if obs is None else obs
        m = self.obs.metrics
        self._c_forks = m.counter("kv.branches_forked")
        self._c_commits = m.counter("kv.commits")
        self._c_aborts = m.counter("kv.aborts")
        self._c_invalidations = m.counter("kv.invalidations")
        self._c_prefix_hits = m.counter("kv.prefix_hits")
        self._c_prefix_misses = m.counter("kv.prefix_misses")
        self._c_prefix_evictions = m.counter("kv.prefix_evictions")
        self._g_free = m.gauge("kv.pages_free")
        self._g_free.set(num_pages)
        self._g_shared = m.gauge("kv.pages_shared")
        self._g_util = m.gauge("kv.pool_utilization")
        self._g_prefix_shared = m.gauge("kv.prefix_pages_shared")
        self._g_tiered = m.gauge("kv.pages_tiered")
        # incremental shared-page count (refcount 1<->2 crossings), so
        # the gauge never pays the O(num_pages) scan stats() does
        self._shared_pages = 0
        self._invalidated_once: set = set()
        # KV semantics: forking freezes the origin (appends denied) until
        # all children resolve; committed sequences are gone for good.
        self._tree = BranchTree(freeze_on_fork=True,
                                allow_fork_resolved=False,
                                tracer=self.obs.tracer)
        self._tree.attach(self)
        self._tables: Dict[int, List[int]] = {}
        self._lengths: Dict[int, int] = {}
        # Cross-request prefix cache: chained content hash of a prompt's
        # page-aligned token runs -> the page already holding that KV
        # (the gitstore idiom: content addresses, not positions).  Each
        # entry holds ONE page reference of its own, so a registered
        # page survives the request that wrote it and any later append
        # by an adopter CoWs away from it.  Evicted LRU-first when the
        # free list runs dry — the cache is reclaimable, never a
        # commitment.
        self._prefix_pages: Dict[str, int] = {}
        self._prefix_lru: Dict[str, int] = {}
        self._prefix_tick = 0
        # Tiered (demoted) branches: still live in the lifecycle tree,
        # but their pages were checkpointed out of the device pool (the
        # snapshot lives in a KVTierStore).  Maps seq id -> page count
        # needed to promote it back.
        self._tiered_pages: Dict[int, int] = {}

    @property
    def tree(self) -> BranchTree:
        """The lifecycle kernel; other domains (token tails, executor
        slots) attach here to resolve atomically with the KV domain."""
        return self._tree

    # ------------------------------------------------------------------
    # page accounting
    # ------------------------------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free)

    def refcount(self, page: int) -> int:
        return int(self._refcount[page])

    def _alloc_page(self) -> int:
        if not self._free:
            # Reclaim before refusing: prefix-cache pages whose only
            # remaining reference is the cache's own are recyclable.
            self._evict_prefixes()
        if not self._free:
            raise PoolExhausted("KV page pool exhausted (-ENOSPC)")
        page = self._free.pop()
        self._refcount[page] = 1
        self._update_pool_gauges()
        return page

    def _evict_prefixes(self) -> None:
        """Drop LRU prefix-cache entries until a page frees (or none left).

        Dropping an entry releases the cache's reference; the page only
        actually returns to the free list if no live table still shares
        it — entries still backing live sequences are cheap to drop and
        re-register, so LRU order need not care.
        """
        while self._prefix_pages and not self._free:
            key = min(self._prefix_lru, key=self._prefix_lru.__getitem__)
            page = self._prefix_pages.pop(key)
            del self._prefix_lru[key]
            self._c_prefix_evictions.inc()
            self._decref([page])
        self._g_prefix_shared.set(len(self._prefix_pages))

    def _update_pool_gauges(self) -> None:
        free = len(self._free)
        self._g_free.set(free)
        self._g_util.set(round(1.0 - free / self.num_pages, 4))

    def _incref(self, pages: Sequence[int]) -> None:
        for p in pages:
            self._refcount[p] += 1
            if self._refcount[p] == 2:
                self._shared_pages += 1
        if pages:
            self._g_shared.set(self._shared_pages)

    def _decref(self, pages: Sequence[int]) -> None:
        # Validate EVERY release before mutating anything: a double
        # release must fail with the allocator untouched.  The old guard
        # was a bare assert placed *after* the page had already
        # re-entered the free list — under ``python -O`` the assert
        # vanished and a doubly-freed page could be handed to two live
        # sequences.  Occurrence-aware: a page appearing k times in
        # ``pages`` needs k outstanding references.
        if len(pages) == 1:     # hot path (CoW faults, tail trims)
            occurrences = {pages[0]: 1} if self._refcount[pages[0]] < 1 \
                else {}
        else:
            occurrences = Counter(pages)
        for p, k in occurrences.items():
            have = int(self._refcount[p])
            if have < k:
                raise BranchError(
                    f"double release of page {p}: {k} release(s) "
                    f"requested but refcount is {have}; tables and free "
                    "list left untouched (-EINVAL)", errno=Errno.EINVAL)
        freed = False
        for p in pages:
            self._refcount[p] -= 1
            if self._refcount[p] == 1:
                self._shared_pages -= 1
            elif self._refcount[p] == 0:
                self._free.append(p)
                freed = True
        if pages:
            self._g_shared.set(self._shared_pages)
            if freed:
                self._update_pool_gauges()

    # ------------------------------------------------------------------
    # BranchDomain payload hooks (called by the kernel, under its lock)
    # ------------------------------------------------------------------
    def on_fork(self, parent: int, children: List[int]) -> None:
        table = self._tables[parent]
        for c in children:
            self._incref(table)
            self._tables[c] = list(table)
            self._lengths[c] = self._lengths[parent]
        self._c_forks.inc(len(children))

    def on_commit(self, child: int, parent: int) -> None:
        # The parent adopts the child's table, *transferring* the child's
        # page references (no incref/decref on the winning table).
        self._decref(self._tables[parent])
        self._tables[parent] = self._tables[child]
        self._lengths[parent] = self._lengths[child]
        self._tables[child] = []
        self._c_commits.inc()

    def on_abort(self, branch: int) -> None:
        self._release_pages(branch)
        self._c_aborts.inc()

    def on_invalidate(self, branch: int) -> None:
        # idempotent hook (abort-after-ESTALE re-fires it); count each
        # branch's invalidation once
        if branch not in self._invalidated_once:
            self._invalidated_once.add(branch)
            self._c_invalidations.inc()
        self._release_pages(branch)

    def on_reap(self, branch: int) -> None:
        # The kernel forgot this id: drop the payload *entries*, not just
        # their contents (host memory must not grow with request count).
        table = self._tables.pop(branch, None)
        if table:
            self._decref(table)
        self._lengths.pop(branch, None)
        self._invalidated_once.discard(branch)
        self._drop_tiered(branch)

    def _release_pages(self, branch: int) -> None:
        table = self._tables.get(branch)
        if table:
            self._decref(table)
        self._tables[branch] = []
        self._drop_tiered(branch)

    def _drop_tiered(self, branch: int) -> None:
        if self._tiered_pages.pop(branch, None) is not None:
            self._g_tiered.set(sum(self._tiered_pages.values()))

    # ------------------------------------------------------------------
    # sequence lifecycle (delegated to the kernel)
    # ------------------------------------------------------------------
    def is_live(self, seq_id: int) -> bool:
        return self._tree.is_live(seq_id)

    def status(self, seq_id: int) -> BranchStatus:
        return self._tree.status(seq_id)

    def new_seq(self, length: int = 0, *,
                prefix_pages: Optional[Sequence[int]] = None) -> int:
        """Create a root sequence with enough pages for ``length`` tokens.

        ``prefix_pages`` (from :meth:`match_prefix`) seeds the head of
        the block table with shared, CoW-protected pages — each gains a
        reference here, atomically with the fresh-tail allocation.  The
        call is transactional: pool exhaustion mid-allocation releases
        everything taken so far and re-raises, mutating nothing.
        """
        with self._tree.lock:
            n_pages = -(-max(length, 0) // self.page_size)
            shared = list(prefix_pages or ())
            if len(shared) > n_pages:
                raise BranchError(
                    f"{len(shared)} prefix pages exceed the {n_pages}-page "
                    f"table for {length} tokens (-EINVAL)",
                    errno=Errno.EINVAL)
            self._incref(shared)
            fresh: List[int] = []
            try:
                for _ in range(n_pages - len(shared)):
                    fresh.append(self._alloc_page())
            except PoolExhausted:
                self._decref(fresh)
                self._decref(shared)
                raise
            sid = self._tree.create_root()
            self._tables[sid] = shared + fresh
            self._lengths[sid] = length
            return sid

    # ------------------------------------------------------------------
    # cross-request prefix sharing (content-addressed page runs)
    # ------------------------------------------------------------------
    def _prefix_keys(self, tokens: Sequence[int]) -> List[str]:
        """Chained content key per FULL page of ``tokens``.

        Chained (each page's key folds in every preceding page) so a
        page is only shareable when the *entire* prefix up to it
        matches — position-independent content addressing would alias
        different contexts onto one KV page.
        """
        keys: List[str] = []
        h = hashlib.sha1()
        ps = self.page_size
        for i in range(len(tokens) // ps):
            h.update(np.asarray(tokens[i * ps:(i + 1) * ps],
                                dtype=np.int64).tobytes())
            keys.append(h.hexdigest())
        return keys

    def _tail_key(self, tokens: Sequence[int]) -> Optional[str]:
        """Key for a partially-filled tail page, or ``None`` if aligned.

        Keyed on the whole prefix *and* its exact length, so a cached
        tail only ever matches a byte-identical full prompt — partial
        tail pages contain fewer valid tokens than their page claims,
        and sharing them on anything less than an exact match would
        serve garbage KV.
        """
        tail = len(tokens) % self.page_size
        if tail == 0:
            return None
        h = hashlib.sha1()
        h.update(np.asarray(tokens, dtype=np.int64).tobytes())
        return f"tail:{len(tokens)}:{h.hexdigest()}"

    def match_prefix(self, tokens: Sequence[int]) -> Tuple[List[int], int]:
        """Longest cached run of shared pages covering a prefix of ``tokens``.

        Returns ``(pages, covered_tokens)``.  Full pages match from page
        0 outward; a cached partial tail page additionally matches only
        when it completes an *exact* whole-prompt hit (then ``covered ==
        len(tokens)`` and the adopter needs no prefill at all).  The
        returned pages are not referenced yet — adopt them atomically
        via ``new_seq(length, prefix_pages=pages)``.
        """
        with self._tree.lock:
            pages: List[int] = []
            keys = self._prefix_keys(tokens)
            for key in keys:
                page = self._prefix_pages.get(key)
                if page is None:
                    break
                self._prefix_tick += 1
                self._prefix_lru[key] = self._prefix_tick
                pages.append(page)
            covered = len(pages) * self.page_size
            if len(pages) == len(keys) and covered < len(tokens):
                tkey = self._tail_key(tokens)
                page = None if tkey is None else self._prefix_pages.get(tkey)
                if page is not None:
                    self._prefix_tick += 1
                    self._prefix_lru[tkey] = self._prefix_tick
                    pages.append(page)
                    covered = len(tokens)
            if covered:
                self._c_prefix_hits.inc()
            else:
                self._c_prefix_misses.inc()
            return pages, covered

    def register_prefix(self, seq_id: int, tokens: Sequence[int]) -> int:
        """Publish ``seq_id``'s prompt pages for cross-request sharing.

        ``tokens`` must be the prompt whose KV currently fills the head
        of ``seq_id``'s block table.  Every not-yet-cached full page —
        plus the partial tail page, under its exact-match-only key —
        gains one cache-owned reference.  Returns the number of pages
        newly registered.  Registering a page that later CoWs away from
        its writer is fine: the cache's copy keeps the original bytes.
        """
        with self._tree.lock:
            self._tree.node(seq_id)
            table = self._tables[seq_id]
            added = 0

            def _put(key: str, page: int) -> None:
                self._incref([page])
                self._prefix_pages[key] = page
                self._prefix_tick += 1
                self._prefix_lru[key] = self._prefix_tick

            keys = self._prefix_keys(tokens)
            for i, key in enumerate(keys):
                if key in self._prefix_pages or i >= len(table):
                    continue
                _put(key, table[i])
                added += 1
            tkey = self._tail_key(tokens)
            if (tkey is not None and tkey not in self._prefix_pages
                    and len(table) > len(keys)):
                _put(tkey, table[len(keys)])
                added += 1
            if added:
                self._g_prefix_shared.set(len(self._prefix_pages))
            return added

    def prefix_cache_size(self) -> int:
        return len(self._prefix_pages)

    def length(self, seq_id: int) -> int:
        self._tree.node(seq_id)
        return self._lengths[seq_id]

    def block_table(self, seq_id: int) -> List[int]:
        self._tree.node(seq_id)
        return list(self._tables[seq_id])

    # ------------------------------------------------------------------
    # fork / append(CoW) / commit / abort
    # ------------------------------------------------------------------
    def fork(self, seq_id: int, n: int = 1) -> List[int]:
        """Fork ``n`` children sharing every page of the parent.

        O(table length) integer work, zero HBM traffic; the parent becomes
        a frozen origin until all children resolve.
        """
        with self._tree.lock:
            self._check_not_tiered(seq_id)
            return self._tree.fork(seq_id, n)

    def fork_batch(self, seq_id: int,
                   n: int = 1) -> Tuple[List[int], List[CowOp]]:
        """Vectorized fork: ``n`` siblings plus their fused tail CoW plan.

        The TClone-style hot path for agent fan-out: all ``n`` children
        are created in one kernel transaction (one lock, one exclusive
        commit group), and the shared-tail copy-on-write every child
        would otherwise fault individually at its first append is
        resolved *eagerly* — each child's table tail is swapped to a
        freshly allocated page here, and the page copies are returned as
        one :class:`CowOp` list the caller services in a **single**
        fused ``_copy_pages`` device dispatch.  ``n`` sequential
        ``fork(seq, 1)`` calls pay ``n`` dispatches for the same state.

        Only the partially-filled tail page is pre-faulted (a full tail
        means the next append opens a fresh page — no CoW to hoist).  If
        the pool empties mid-plan the remaining children simply keep the
        shared tail and fault lazily later; eager CoW is an optimization,
        never a correctness requirement.  Callers going through
        :meth:`Scheduler.fork <repro.runtime.scheduler.Scheduler.fork>`
        admission cannot hit that path — the reservation ledger covers
        one CoW'd tail page per child.
        """
        with self._tree.lock:
            self._check_not_tiered(seq_id)
            children = self._tree.fork(seq_id, n)
            ops: List[CowOp] = []
            table = self._tables[seq_id]
            if table and self._lengths[seq_id] % self.page_size != 0:
                shared = table[-1]
                for c in children:
                    child_table = self._tables[c]
                    if self._refcount[shared] <= 1 or \
                            not child_table or child_table[-1] != shared:
                        continue
                    try:
                        fresh = self._alloc_page()
                    except PoolExhausted:
                        break   # remaining children CoW lazily on append
                    self._decref([shared])
                    child_table[-1] = fresh
                    ops.append(CowOp(src_page=shared, dst_page=fresh))
            return children, ops

    def prepare_append(self, seq_id: int, n_tokens: int = 1) -> List[AppendSlot]:
        """Reserve slots for the next ``n_tokens`` tokens of ``seq_id``.

        Returns one :class:`AppendSlot` per token; any CoW page copies the
        device must perform are attached to the slot that triggers them.
        The block table and length are updated eagerly (metadata is the
        source of truth; device writes follow).
        """
        with self._tree.lock:
            node = self._tree.check_live(seq_id)
            if node.status is BranchStatus.FROZEN:
                raise FrozenOriginError(
                    f"sequence {seq_id} has live children and is frozen")
            self._check_not_tiered(seq_id)
            table = self._tables[seq_id]
            slots: List[AppendSlot] = []
            try:
                for _ in range(n_tokens):
                    offset = self._lengths[seq_id] % self.page_size
                    cow: Tuple[CowOp, ...] = ()
                    if offset == 0:
                        # new page needed
                        page = self._alloc_page()
                        table.append(page)
                    else:
                        page = table[-1]
                        if self._refcount[page] > 1:
                            # shared tail page: copy-on-write
                            new_page = self._alloc_page()
                            cow = (CowOp(src_page=page, dst_page=new_page),)
                            self._decref([page])
                            table[-1] = new_page
                            page = new_page
                    self._lengths[seq_id] += 1
                    slots.append(AppendSlot(page=page, offset=offset,
                                            cow=cow))
            except MemoryError:
                # -ENOSPC midway: earlier tokens of this call mutated the
                # table/length — undo them so the caller sees all or
                # nothing (length == tokens - 1 stays intact).
                self._undo_slots(seq_id, slots)
                raise
            return slots

    def _undo_slots(self, seq_id: int, slots: Sequence[AppendSlot]) -> None:
        """Reverse the metadata mutations of reserved-but-unused slots.

        Only legal before any device write consumed the slots: CoW page
        copies and KV writes happen strictly after slot reservation, so
        rolling back tables/lengths/refcounts here leaves no device state
        referencing the undone pages.
        """
        table = self._tables[seq_id]
        for slot in reversed(slots):
            self._lengths[seq_id] -= 1
            if slot.cow:
                (op,) = slot.cow
                self._incref([op.src_page])
                self._decref([op.dst_page])   # freshly allocated -> freed
                table[-1] = op.src_page
            elif slot.offset == 0:
                table.pop()
                self._decref([slot.page])

    def prepare_append_batch(
        self, seq_ids: Sequence[int], n_tokens: int = 1
    ) -> List[List[AppendSlot]]:
        """All-or-nothing slot reservation across a decode batch.

        Either every sequence gets its slots or *no* metadata is mutated:
        if the pool exhausts (or a sequence turns out frozen/stale) after
        earlier batch members were prepared, their mutations — including
        speculative CoW tail-page swaps whose device copy has not run —
        are rolled back before the error propagates.  This turns a
        mid-batch -ENOSPC into a clean, retryable -EAGAIN instead of
        silent KV corruption of earlier batch members.
        """
        with self._tree.lock:
            done: List[Tuple[int, List[AppendSlot]]] = []
            try:
                for sid in seq_ids:
                    done.append((sid, self.prepare_append(sid, n_tokens)))
            except Exception:
                for sid, slots in reversed(done):
                    self._undo_slots(sid, slots)
                raise
            return [slots for _, slots in done]

    def truncate(self, seq_id: int, new_length: int) -> None:
        """Shrink a sequence to ``new_length`` cached tokens.

        The speculative-decoding primitive: a draft branch whose suffix
        failed verification keeps only its verified prefix.  Surplus
        tail pages are decref'd (a page still shared with the fork
        origin simply drops this branch's reference); retained pages are
        untouched, and any stale KV beyond ``new_length`` in a partially
        filled tail page is never read (attention is bounded by the
        length) and is overwritten by later appends.
        """
        with self._tree.lock:
            node = self._tree.check_live(seq_id)
            if node.status is BranchStatus.FROZEN:
                raise FrozenOriginError(
                    f"sequence {seq_id} has live children and is frozen")
            self._check_not_tiered(seq_id)
            if new_length < 0 or new_length > self._lengths[seq_id]:
                raise ValueError(
                    f"cannot truncate sequence {seq_id} from "
                    f"{self._lengths[seq_id]} to {new_length} tokens")
            table = self._tables[seq_id]
            keep = -(-new_length // self.page_size)
            if keep < len(table):
                self._decref(table[keep:])
                del table[keep:]
            self._lengths[seq_id] = new_length

    def commit(self, seq_id: int) -> int:
        """First-commit-wins: promote this child's table into the parent.

        Siblings turn STALE and their page references are recycled.
        Returns the parent sequence id (which resumes ACTIVE with the
        child's content, PID-takeover style).
        """
        with self._tree.lock:
            # A tiered child has an empty table; committing it would
            # strip the parent's pages and adopt nothing.
            self._check_not_tiered(seq_id)
            return self._tree.commit(seq_id)

    def abort(self, seq_id: int) -> None:
        """Discard the branch; siblings stay valid; parent may resume."""
        self._tree.abort(seq_id)

    def release(self, seq_id: int) -> None:
        """Free a root/active sequence outright (serving-slot eviction).

        The subtree is invalidated and then *reaped*: lifecycle nodes and
        payload entries (tables, lengths, attached-domain dicts) are
        dropped, so a long-running serving loop does not accumulate host
        state for retired requests.
        """
        with self._tree.lock:
            self._tree.invalidate(seq_id, status=BranchStatus.ABORTED)
            self._tree.reap(seq_id)

    # ------------------------------------------------------------------
    # tiering (device -> host/disk demotion, BR_TIERED)
    # ------------------------------------------------------------------
    def _check_not_tiered(self, seq_id: int) -> None:
        if seq_id in self._tiered_pages:
            raise BranchError(
                f"sequence {seq_id} is tiered out (pages checkpointed to "
                "a lower tier); restore it before operating on its KV "
                "(-EAGAIN)", errno=Errno.EAGAIN)

    def is_tiered(self, seq_id: int) -> bool:
        return seq_id in self._tiered_pages

    def demote(self, seq_id: int) -> List[int]:
        """Release a live branch's device pages for tiering.

        The branch stays live in the lifecycle tree (its length and
        node survive; first-commit-wins semantics are untouched) but its
        block table is emptied and every page reference dropped — the
        caller must have snapshotted the page contents first (the
        engine's ``checkpoint`` does).  Returns the old table so the
        caller can gather pages *before* calling, or audit after.
        """
        with self._tree.lock:
            self._tree.check_live(seq_id)
            if seq_id in self._tiered_pages:
                raise BranchStateError(f"sequence {seq_id} is already tiered")
            if self._tree.has_live_children(seq_id):
                raise BranchError(
                    f"sequence {seq_id} has live children sharing its "
                    "pages; demote the leaves instead (-EBUSY)",
                    errno=Errno.EBUSY)
            table = self._tables[seq_id]
            pages = list(table)
            self._decref(table)
            self._tables[seq_id] = []
            self._tiered_pages[seq_id] = len(pages)
            self._g_tiered.set(sum(self._tiered_pages.values()))
            return pages

    def promote(self, seq_id: int) -> List[int]:
        """Re-seat a tiered branch: allocate a fresh block table.

        Transactional — pool exhaustion mid-allocation frees everything
        taken and re-raises with the branch still tiered, so the caller
        can demote something else and retry.  The caller scatters the
        snapshot back into the returned pages.
        """
        with self._tree.lock:
            self._tree.check_live(seq_id)
            if seq_id not in self._tiered_pages:
                raise BranchStateError(f"sequence {seq_id} is not tiered")
            fresh: List[int] = []
            try:
                for _ in range(self._tiered_pages[seq_id]):
                    fresh.append(self._alloc_page())
            except PoolExhausted:
                self._decref(fresh)
                raise
            self._tables[seq_id] = fresh
            del self._tiered_pages[seq_id]
            self._g_tiered.set(sum(self._tiered_pages.values()))
            return fresh

    # ------------------------------------------------------------------
    # dense views for the device step
    # ------------------------------------------------------------------
    def dense_block_tables(
        self, seq_ids: Sequence[int], max_pages: int
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Pack block tables into ``[batch, max_pages]`` (pad = 0) plus
        lengths ``[batch]`` for the paged-attention kernel."""
        bt = np.zeros((len(seq_ids), max_pages), dtype=np.int32)
        lens = np.zeros((len(seq_ids),), dtype=np.int32)
        for i, sid in enumerate(seq_ids):
            self._tree.node(sid)
            self._check_not_tiered(sid)
            table = self._tables[sid]
            if len(table) > max_pages:
                raise ValueError(
                    f"sequence {sid} needs {len(table)} pages > {max_pages}"
                )
            bt[i, : len(table)] = table
            lens[i] = self._lengths[sid]
        return bt, lens

    def footprints(self) -> Dict[int, int]:
        """Per-branch page footprint (pages referenced by each live
        branch's table) — the per-tenant accounting view."""
        with self._tree.lock:
            return {sid: len(table) for sid, table in self._tables.items()
                    if sid in self._tree
                    and self._tree.node(sid).status in LIVE}

    def stats(self) -> Dict[str, int]:
        return {
            "sequences_live": self._tree.live_count(),
            "pages_total": self.num_pages,
            "pages_free": len(self._free),
            "pages_shared": int((self._refcount > 1).sum()),
            "prefix_pages_cached": len(self._prefix_pages),
            "sequences_tiered": len(self._tiered_pages),
            "pages_tiered": sum(self._tiered_pages.values()),
        }


__all__ = [
    "AppendSlot",
    "CowOp",
    "KVBranchManager",
    "SeqStatus",
]
