"""Branch-context errors — one errno vocabulary for every layer.

The paper's ``branch()`` is a syscall, and syscalls report failure
through *one* errno namespace.  Before this module was unified, the
repro had three error conventions: ``Scheduler`` raised
``AdmissionDenied``, ``KVBranchManager`` raised a bare ``MemoryError``
for pool exhaustion, and ``explore_ctx`` wrapped both in ``BranchError``
subclasses with ``-ESTALE``/``-EAGAIN`` spelled out in prose.  Now every
branch-layer exception derives from :class:`BranchError` and carries a
machine-readable code from the shared :class:`Errno` enum:

=====================  ==========  =======================================
exception              errno       syscall meaning
=====================  ==========  =======================================
BadHandleError         EBADF       stale/closed branch handle (generation
                                   counter mismatch in the handle table)
NoSuchLeafError        ENOENT      chain resolution found nothing
AdmissionDenied        EAGAIN      page-budget backpressure (retryable) —
                                   or ENOSPC when the request can *never*
                                   fit the pool / block table
PoolExhausted          ENOSPC      KV page pool empty mid-operation
BranchStateError       EINVAL      lifecycle misuse (double commit, op on
                                   resolved branch, bad flags)
FrozenOriginError      EAGAIN      write to an origin with live children
StaleBranchError       ESTALE      invalidated by a sibling's commit
=====================  ==========  =======================================

Callers that care about the *code* check ``err.errno``; callers that
care about the *family* catch the subclass.  Both views are one object,
so there is no mapping code to drift.
"""

from __future__ import annotations

from enum import IntEnum
from typing import Optional


class Errno(IntEnum):
    """The branch layer's errno namespace (values mirror Linux).

    An exception carrying ``Errno.EBADF`` is the library analogue of a
    syscall returning ``-EBADF``; the sign convention is dropped because
    Python signals failure by raising, not by returning negatives.
    """

    EPERM = 1      # operation not permitted (flag forbids it)
    ENOENT = 2     # no such entry (chain resolution)
    EBADF = 9      # stale/unknown branch handle
    EAGAIN = 11    # try again (backpressure, frozen origin)
    EBUSY = 16     # resource busy (live children)
    EINVAL = 22    # lifecycle misuse / bad arguments
    ENOSPC = 28    # page pool can never absorb the request
    ESTALE = 116   # invalidated by a sibling's first-commit win


class BranchError(RuntimeError):
    """Base class for all branch-context errors.

    Every instance carries :attr:`errno` — the subclass default, or an
    explicit override (``AdmissionDenied(msg, errno=Errno.ENOSPC)`` for
    a request that can *never* fit, vs the retryable EAGAIN default).
    """

    default_errno: Errno = Errno.EINVAL

    def __init__(self, *args: object, errno: Optional[Errno] = None):
        super().__init__(*args)
        self.errno: Errno = errno if errno is not None else self.default_errno


class StaleBranchError(BranchError):
    """Raised when operating on a branch invalidated by a sibling's commit.

    The OS analogue is ``-ESTALE`` returned from ``branch(BR_COMMIT)`` to
    every loser of the exclusive commit group, and ``SIGBUS`` delivered to
    mappings of an invalidated branch.
    """

    default_errno = Errno.ESTALE


class FrozenOriginError(BranchError):
    """Raised when writing to a parent that has live child branches.

    The paper freezes the origin while branches exist (filesystem writes
    denied, memory pages read-only returning ``-EAGAIN``); this eliminates
    merge conflicts by construction.
    """

    default_errno = Errno.EAGAIN


class BranchStateError(BranchError):
    """Raised on lifecycle misuse (double commit, op on aborted branch...)."""

    default_errno = Errno.EINVAL


class NoSuchLeafError(BranchError, KeyError):
    """Raised when chain resolution finds no leaf and no tombstone hides one."""

    default_errno = Errno.ENOENT


class BadHandleError(BranchError):
    """Raised when a session handle's generation counter no longer matches.

    The ``-EBADF`` of the branch layer: handles are fd-like integers
    packing a table index with a generation counter, so a handle kept
    across a ``close`` (slot reuse bumps the generation) can never
    silently address the new occupant — it fails here instead.
    """

    default_errno = Errno.EBADF


class AdmissionDenied(BranchError):
    """Raised when admission would overrun the page budget.

    The -EAGAIN of the serving layer: the caller may retry after commits
    or retirements recycle pages.  Requests rejected at ``submit``
    because they can *never* fit carry ``Errno.ENOSPC`` instead — no
    amount of retrying resizes the pool.
    """

    default_errno = Errno.EAGAIN


class PoolExhausted(BranchError, MemoryError):
    """Raised when the KV page pool empties mid-operation (``-ENOSPC``).

    Subclasses :class:`MemoryError` so pre-unification callers that
    caught the pool's bare ``MemoryError`` keep working; new code should
    catch :class:`BranchError` and check ``errno is Errno.ENOSPC``.
    Scheduler admission makes this unreachable for scheduled work — it
    can only fire on raw engine use that bypasses the reservation ledger.
    """

    default_errno = Errno.ENOSPC


__all__ = [
    "AdmissionDenied",
    "BadHandleError",
    "BranchError",
    "BranchStateError",
    "Errno",
    "FrozenOriginError",
    "NoSuchLeafError",
    "PoolExhausted",
    "StaleBranchError",
]
