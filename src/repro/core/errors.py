"""Branch-context error types.

Mirrors the errno vocabulary of the paper's ``branch()`` syscall:
``StaleBranchError`` is the ``-ESTALE`` a losing sibling receives after a
first-commit-wins race; ``FrozenOriginError`` is the parent's read-only
(``-EAGAIN``) behaviour while branches exist.
"""

from __future__ import annotations


class BranchError(RuntimeError):
    """Base class for all branch-context errors."""


class StaleBranchError(BranchError):
    """Raised when operating on a branch invalidated by a sibling's commit.

    The OS analogue is ``-ESTALE`` returned from ``branch(BR_COMMIT)`` to
    every loser of the exclusive commit group, and ``SIGBUS`` delivered to
    mappings of an invalidated branch.
    """


class FrozenOriginError(BranchError):
    """Raised when writing to a parent that has live child branches.

    The paper freezes the origin while branches exist (filesystem writes
    denied, memory pages read-only returning ``-EAGAIN``); this eliminates
    merge conflicts by construction.
    """


class BranchStateError(BranchError):
    """Raised on lifecycle misuse (double commit, op on aborted branch...)."""


class NoSuchLeafError(BranchError, KeyError):
    """Raised when chain resolution finds no leaf and no tombstone hides one."""
