"""Tiered KV snapshot store — device → host RAM → disk.

The device page pool is the scarcest resource in the system; a held or
parked branch pins its pages for minutes while contributing nothing to
the running batch.  :class:`KVTierStore` holds full-fidelity snapshots
of demoted branches (pages in the pool's *native* dtype, per-page int8
scales when quantized, the block-table shape, and the token tail) so
the engine can hand the device pages back to the allocator and later
restore the branch token-identically.

Tier policy is capacity-driven and transparent to callers:

* **host** — snapshots live as numpy arrays up to ``host_bytes``;
* **disk** — the least-recently-used host snapshot spills to an
  ``.npz`` file when the host tier is over budget, and transparently
  loads back on :meth:`get`.

The store is also a :class:`~repro.core.lifecycle.BranchDomain`: attach
it to the same :class:`BranchTree` as the KV manager and snapshots of
branches that get aborted / invalidated / reaped are dropped in the
same atomic lifecycle transition — a tiered loser of first-commit-wins
cannot leak its snapshot.
"""

from __future__ import annotations

import os
import tempfile
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.core.errors import BranchError, Errno
from repro.obs import Observability


@dataclass
class KVSnapshot:
    """Everything needed to re-seat one branch token-identically.

    Pages are stored in the pool's native dtype (bf16 bytes or int8 +
    per-page scales) — re-quantizing on restore would drift tokens.
    Shapes: ``k_pages``/``v_pages`` are ``[layers, n_pages, page_size,
    kv_heads, head_dim]``; scales (int8 pools only) are ``[layers,
    n_pages, kv_heads]``.
    """

    seq_id: int
    length: int
    n_pages: int
    tokens: List[int]
    k_pages: np.ndarray
    v_pages: np.ndarray
    k_scales: Optional[np.ndarray] = None
    v_scales: Optional[np.ndarray] = None
    meta: Dict[str, object] = field(default_factory=dict)

    @property
    def nbytes(self) -> int:
        n = self.k_pages.nbytes + self.v_pages.nbytes
        if self.k_scales is not None:
            n += self.k_scales.nbytes
        if self.v_scales is not None:
            n += self.v_scales.nbytes
        return n


class KVTierStore:
    """Host/disk snapshot tiers for demoted KV branches."""

    def __init__(self, *, host_bytes: int = 64 << 20,
                 disk_dir: Optional[str] = None,
                 obs: Observability = None):
        self.host_bytes = host_bytes
        self._disk_dir = disk_dir
        self._host: Dict[int, KVSnapshot] = {}     # insertion order = LRU
        self._disk: Dict[int, str] = {}            # seq id -> .npz path
        self._disk_bytes: Dict[int, int] = {}
        self.obs = Observability() if obs is None else obs
        m = self.obs.metrics
        self._c_puts = m.counter("tier.demotions")
        self._c_gets = m.counter("tier.restores")
        self._c_spills = m.counter("tier.spills")
        self._c_loads = m.counter("tier.disk_loads")
        self._g_host = m.gauge("tier.host_bytes")
        self._g_disk = m.gauge("tier.disk_bytes")
        self._g_snaps = m.gauge("tier.snapshots")

    # ------------------------------------------------------------------
    # tiers
    # ------------------------------------------------------------------
    def _dir(self) -> str:
        if self._disk_dir is None:
            self._disk_dir = tempfile.mkdtemp(prefix="repro-kvtier-")
        else:
            os.makedirs(self._disk_dir, exist_ok=True)
        return self._disk_dir

    def _host_used(self) -> int:
        return sum(s.nbytes for s in self._host.values())

    def _update_gauges(self) -> None:
        self._g_host.set(self._host_used())
        self._g_disk.set(sum(self._disk_bytes.values()))
        self._g_snaps.set(len(self._host) + len(self._disk))

    def _spill_lru(self) -> None:
        """Move the least-recently-used host snapshot to the disk tier."""
        sid = next(iter(self._host))
        snap = self._host.pop(sid)
        path = os.path.join(self._dir(), f"seq_{sid}.npz")
        arrays = {"k_pages": snap.k_pages, "v_pages": snap.v_pages,
                  "tokens": np.asarray(snap.tokens, dtype=np.int64),
                  "hdr": np.asarray([snap.seq_id, snap.length,
                                     snap.n_pages], dtype=np.int64)}
        if snap.k_scales is not None:
            arrays["k_scales"] = snap.k_scales
            arrays["v_scales"] = snap.v_scales
        np.savez(path, **arrays)
        self._disk[sid] = path
        self._disk_bytes[sid] = os.path.getsize(path)
        self._c_spills.inc()

    def _load(self, sid: int) -> KVSnapshot:
        path = self._disk.pop(sid)
        self._disk_bytes.pop(sid, None)
        with np.load(path) as z:
            hdr = z["hdr"]
            snap = KVSnapshot(
                seq_id=int(hdr[0]), length=int(hdr[1]),
                n_pages=int(hdr[2]), tokens=[int(t) for t in z["tokens"]],
                k_pages=z["k_pages"], v_pages=z["v_pages"],
                k_scales=z["k_scales"] if "k_scales" in z else None,
                v_scales=z["v_scales"] if "v_scales" in z else None)
        try:
            os.remove(path)
        except OSError:
            pass
        self._c_loads.inc()
        return snap

    # ------------------------------------------------------------------
    # public API
    # ------------------------------------------------------------------
    def put(self, snap: KVSnapshot) -> None:
        """Store a snapshot (host tier; LRU spills to disk over budget)."""
        if snap.seq_id in self._host or snap.seq_id in self._disk:
            raise BranchError(
                f"sequence {snap.seq_id} already has a tiered snapshot "
                "(-EBUSY)", errno=Errno.EBUSY)
        self._host[snap.seq_id] = snap
        self._c_puts.inc()
        # Spill *other* snapshots first (the newcomer is the hottest);
        # a single snapshot bigger than the budget spills itself.
        while self._host_used() > self.host_bytes and len(self._host) > 1:
            self._spill_lru()
        if self._host_used() > self.host_bytes and self._host:
            self._spill_lru()
        self._update_gauges()

    def get(self, seq_id: int) -> KVSnapshot:
        """Fetch a snapshot (loading from disk if spilled); keeps it stored."""
        snap = self._host.pop(seq_id, None)
        if snap is None:
            if seq_id not in self._disk:
                raise BranchError(
                    f"no tiered snapshot for sequence {seq_id} (-ENOENT)",
                    errno=Errno.ENOENT)
            snap = self._load(seq_id)
        self._host[seq_id] = snap          # re-insert = touch (MRU)
        self._c_gets.inc()
        self._update_gauges()
        return snap

    def drop(self, seq_id: int) -> bool:
        """Discard a snapshot; returns whether one existed."""
        had = self._host.pop(seq_id, None) is not None
        path = self._disk.pop(seq_id, None)
        self._disk_bytes.pop(seq_id, None)
        if path is not None:
            had = True
            try:
                os.remove(path)
            except OSError:
                pass
        if had:
            self._update_gauges()
        return had

    def __contains__(self, seq_id: int) -> bool:
        return seq_id in self._host or seq_id in self._disk

    def __len__(self) -> int:
        return len(self._host) + len(self._disk)

    def stats(self) -> Dict[str, int]:
        return {
            "snapshots": len(self),
            "host_snapshots": len(self._host),
            "disk_snapshots": len(self._disk),
            "host_bytes": self._host_used(),
            "disk_bytes": sum(self._disk_bytes.values()),
        }

    # ------------------------------------------------------------------
    # BranchDomain hooks — snapshots die with their branch
    # ------------------------------------------------------------------
    def on_fork(self, parent: int, children: List[int]) -> None:
        pass     # tiered branches cannot fork (kvbranch guards it)

    def on_commit(self, child: int, parent: int) -> None:
        pass     # tiered branches cannot commit (kvbranch guards it)

    def on_abort(self, branch: int) -> None:
        self.drop(branch)

    def on_invalidate(self, branch: int) -> None:
        self.drop(branch)

    def on_reap(self, branch: int) -> None:
        self.drop(branch)


__all__ = ["KVSnapshot", "KVTierStore"]
