from repro.data.synthetic import DataState, SyntheticLMPipeline

__all__ = ["DataState", "SyntheticLMPipeline"]
