"""Deterministic, shardable, checkpointable synthetic LM data pipeline.

Tokens are generated from a counter-mode hash (threefry via jax.random
keyed on (seed, step, shard)) so that:
* any (step, shard) batch is reproducible with no state but the cursor —
  the pipeline's checkpoint is a single integer (plus config);
* restarting from a checkpoint replays the exact stream (fault-tolerance
  tests assert bit-identical batches after restore);
* shards never overlap.

The token distribution is Zipf-like with a Markov "document" structure so
the loss curve is non-trivial (learnable bigram statistics), which the
~100M end-to-end example uses to show optimization progress.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig


class DataState(NamedTuple):
    step: int
    seed: int
    shard: int
    num_shards: int


@dataclass
class SyntheticLMPipeline:
    cfg: ArchConfig
    batch: int                 # per-shard batch
    seq: int
    seed: int = 0
    shard: int = 0
    num_shards: int = 1
    _step: int = 0

    def __post_init__(self):
        assert 0 <= self.shard < self.num_shards
        v = self.cfg.vocab_size
        # fixed Zipf-ish unigram + a deterministic bigram shift so the
        # stream has learnable structure
        ranks = np.arange(1, v + 1, dtype=np.float64)
        probs = 1.0 / ranks
        self._probs = jnp.asarray(probs / probs.sum(), jnp.float32)

    # ------------------------------------------------------------------
    def _gen(self, step: int) -> Dict[str, jnp.ndarray]:
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(self.seed), step),
            self.shard,
        )
        cb = self.cfg.num_codebooks
        shape = ((self.batch, self.seq + 1, cb) if cb > 1
                 else (self.batch, self.seq + 1))
        base = jax.random.categorical(
            key, jnp.log(self._probs)[None], shape=shape)
        # bigram structure: even positions strongly predict the next token
        rolled = (base * 7 + 13) % self.cfg.vocab_size
        pos = jnp.arange(self.seq + 1) % 2 == 1
        pos = pos[None, :, None] if cb > 1 else pos[None, :]
        toks = jnp.where(pos, rolled, base).astype(jnp.int32)
        batch = {
            "tokens": toks[:, :-1],
            "targets": toks[:, 1:],
        }
        if self.cfg.frontend == "vlm_stub":
            batch["frontend_embed"] = jax.random.normal(
                jax.random.fold_in(key, 999),
                (self.batch, self.cfg.frontend_tokens, self.cfg.d_model),
                jnp.bfloat16,
            )
        return batch

    def next(self) -> Dict[str, jnp.ndarray]:
        out = self._gen(self._step)
        self._step += 1
        return out

    def peek(self, step: int) -> Dict[str, jnp.ndarray]:
        return self._gen(step)

    # ------------------------------------------------------------------
    # checkpointable cursor
    # ------------------------------------------------------------------
    def state(self) -> DataState:
        return DataState(step=self._step, seed=self.seed, shard=self.shard,
                         num_shards=self.num_shards)

    def restore(self, state: DataState) -> None:
        assert state.seed == self.seed
        self._step = state.step

    @classmethod
    def from_state(cls, cfg: ArchConfig, batch: int, seq: int,
                   state: DataState) -> "SyntheticLMPipeline":
        p = cls(cfg, batch, seq, seed=state.seed, shard=state.shard,
                num_shards=state.num_shards)
        p._step = state.step
        return p
