"""musicgen-medium — decoder-only over EnCodec tokens [arXiv:2306.05284; hf].

The EnCodec frontend is a STUB per the assignment: inputs are precomputed
frame token ids across ``num_codebooks`` parallel codebooks; the model
sums per-codebook embeddings and predicts all codebooks per position.
"""

from repro.configs.base import ArchConfig, register

MUSICGEN_MEDIUM = register(ArchConfig(
    name="musicgen-medium",
    family="audio",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,           # MHA
    d_ff=6144,
    vocab_size=2048,           # per-codebook EnCodec vocabulary
    mlp_activation="geglu",
    frontend="audio_stub",
    num_codebooks=4,
    source="[arXiv:2306.05284; hf]",
))
