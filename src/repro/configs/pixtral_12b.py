"""pixtral-12b — VLM: pixtral-ViT frontend (stub) + mistral-nemo decoder
[hf:mistralai/Pixtral-12B-2409; unverified].

The modality frontend is a STUB per the assignment: ``input_specs()``
provides precomputed patch embeddings that occupy the first
``frontend_tokens`` positions of the sequence.
"""

from repro.configs.base import ArchConfig, register

PIXTRAL_12B = register(ArchConfig(
    name="pixtral-12b",
    family="vlm",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131072,
    mlp_activation="swiglu",
    frontend="vlm_stub",
    frontend_tokens=1024,      # one 1024-patch image per sequence
    source="[hf:mistralai/Pixtral-12B-2409; unverified]",
))
