"""Assigned input-shape presets + ShapeDtypeStruct ``input_specs``.

The four LM shapes from the assignment.  ``decode_*`` / ``long_*`` lower
``serve_step`` (one new token against a KV cache of ``seq_len``), NOT
``train_step``.  ``long_500k`` requires sub-quadratic attention and is
only applicable to SSM/hybrid archs (skips recorded by
:func:`cell_applicable`).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def cell_applicable(cfg: ArchConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Is (arch × shape) a runnable cell?  Returns (ok, reason_if_not).

    Rules from the assignment:
    * ``long_500k`` needs sub-quadratic attention → run only for
      SSM/hybrid archs; skip for pure full-attention archs.
    * decode shapes are skipped for encoder-only archs (none assigned).
    """
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, (
            f"{cfg.name} is pure full-attention; long_500k requires "
            "sub-quadratic attention (SSM/hybrid only) — skip per assignment"
        )
    return True, ""


def _token_spec(cfg: ArchConfig, batch: int, seq: int) -> jax.ShapeDtypeStruct:
    if cfg.num_codebooks > 1:
        return jax.ShapeDtypeStruct((batch, seq, cfg.num_codebooks), jnp.int32)
    return jax.ShapeDtypeStruct((batch, seq), jnp.int32)


def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    Weak-type-correct, shardable, no device allocation — fed to
    ``jax.jit(step).lower(**input_specs(...))`` by the dry-run.
    """
    from repro.models.model import decode_state_specs  # lazy: avoid cycle

    b, s = shape.global_batch, shape.seq_len
    dt = jnp.bfloat16
    if shape.kind == "train":
        specs: Dict[str, Any] = {
            "tokens": _token_spec(cfg, b, s),
            "targets": _token_spec(cfg, b, s),
        }
        if cfg.frontend == "vlm_stub":
            specs["frontend_embed"] = jax.ShapeDtypeStruct(
                (b, cfg.frontend_tokens, cfg.d_model), dt
            )
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": _token_spec(cfg, b, s)}
        if cfg.frontend == "vlm_stub":
            specs["frontend_embed"] = jax.ShapeDtypeStruct(
                (b, cfg.frontend_tokens, cfg.d_model), dt
            )
        return specs
    # decode: one new token against a cache of seq_len
    return {
        "tokens": _token_spec(cfg, b, 1),
        "cache": decode_state_specs(cfg, batch=b, max_len=s),
        "pos": jax.ShapeDtypeStruct((b,), jnp.int32),
    }
