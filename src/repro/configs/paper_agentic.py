"""paper-agentic — the paper's own workload: a small serving model whose
KV cache is branched for agentic exploration (fork N continuations,
first-commit-wins).  Used by examples/agentic_serve.py and the serving
benchmarks; small enough to run real forward passes on CPU.
"""

from repro.configs.base import ArchConfig, register

PAPER_AGENTIC = register(ArchConfig(
    name="paper-agentic",
    family="dense",
    num_layers=4,
    d_model=256,
    num_heads=8,
    num_kv_heads=4,
    d_ff=1024,
    vocab_size=512,
    mlp_activation="swiglu",
    source="[paper §6 workload analogue]",
))
