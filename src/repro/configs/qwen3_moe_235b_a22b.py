"""qwen3-moe-235b-a22b — 128-expert top-8 MoE [hf:Qwen/Qwen3-30B-A3B; hf]."""

from repro.configs.base import ArchConfig, register

QWEN3_MOE_235B_A22B = register(ArchConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    head_dim=128,
    d_ff=1536,                 # per-expert FFN width
    vocab_size=151936,
    mlp_activation="swiglu",
    num_experts=128,
    experts_per_token=8,
    source="[hf:Qwen/Qwen3-30B-A3B; hf]",
))
