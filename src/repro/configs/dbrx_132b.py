"""dbrx-132b — 16-expert top-4 fine-grained MoE [hf:databricks/dbrx-base; unverified]."""

from repro.configs.base import ArchConfig, register

DBRX_132B = register(ArchConfig(
    name="dbrx-132b",
    family="moe",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=10752,
    vocab_size=100352,
    mlp_activation="geglu",
    num_experts=16,
    experts_per_token=4,
    source="[hf:databricks/dbrx-base; unverified]",
))
