"""Architecture configuration schema + registry.

Every assigned architecture is a frozen :class:`ArchConfig`; the registry
maps ``--arch <id>`` to it.  ``reduced()`` produces the tiny same-family
config used by CPU smoke tests; the full configs are exercised only via
the dry-run (ShapeDtypeStruct, no allocation).
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field, replace
from typing import Callable, Dict, List, Optional, Tuple


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int                  # query heads (0 => attention-free)
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 => d_model // num_heads
    # ---- MLP / attention variants -------------------------------------
    mlp_activation: str = "swiglu"  # swiglu | sqrelu | geglu
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 1_000_000.0
    norm_eps: float = 1e-5
    # ---- MoE -----------------------------------------------------------
    num_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25
    # ---- SSM (Mamba2 / SSD) ---------------------------------------------
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 128
    ssm_conv_kernel: int = 4
    ssm_groups: int = 1
    # ---- hybrid (zamba-style shared attention) --------------------------
    attn_every: int = 0             # 0 => pure; k => shared attn block @ k
    # ---- modality frontends (stubs) --------------------------------------
    frontend: str = "none"          # none | vlm_stub | audio_stub
    frontend_tokens: int = 0        # prefix positions fed by the stub
    num_codebooks: int = 1          # musicgen: parallel EnCodec codebooks
    # ---- numerics ---------------------------------------------------------
    dtype: str = "bfloat16"
    # provenance: [source; verified-tier]
    source: str = ""

    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.num_heads and self.head_dim == 0:
            object.__setattr__(self, "head_dim",
                               self.d_model // self.num_heads)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    @property
    def ssm_conv_dim(self) -> int:
        # x, B, C are all convolved (Mamba2 layout)
        return self.ssm_d_inner + 2 * self.ssm_groups * self.ssm_state

    @property
    def n_attn_layers(self) -> int:
        """How many attention applications one forward pass makes."""
        if self.family == "ssm":
            return 0
        if self.family == "hybrid":
            return self.num_layers // max(self.attn_every, 1)
        return self.num_layers

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic archs (SSM/hybrid) run the long_500k shape."""
        return self.family in ("ssm", "hybrid")

    # ------------------------------------------------------------------
    # parameter counting (used by roofline MODEL_FLOPS = 6·N·D)
    # ------------------------------------------------------------------
    def param_count(self, active_only: bool = False) -> int:
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.head_dim
        n = 0
        # embeddings (+ output head unless tied)
        n += self.num_codebooks * v * d
        n += 0 if self.tie_embeddings else d * v * self.num_codebooks
        if self.frontend != "none":
            n += d * d  # stub frontend projection

        def attn_params() -> int:
            p = d * self.num_heads * hd          # q
            p += 2 * d * self.num_kv_heads * hd  # k, v
            p += self.num_heads * hd * d         # o
            if self.qkv_bias:
                p += (self.num_heads + 2 * self.num_kv_heads) * hd
            return p

        def mlp_params(ff: int) -> int:
            mults = 3 if self.mlp_activation in ("swiglu", "geglu") else 2
            return mults * d * ff

        if self.family == "ssm":
            di, cdim = self.ssm_d_inner, self.ssm_conv_dim
            per = d * (2 * di + 2 * self.ssm_groups * self.ssm_state
                       + self.ssm_heads)          # in_proj
            per += cdim * self.ssm_conv_kernel    # conv
            per += 2 * self.ssm_heads             # A, D
            per += di                              # gated norm
            per += di * d                          # out_proj
            per += 2 * d                           # norms
            n += self.num_layers * per
        elif self.family == "hybrid":
            di, cdim = self.ssm_d_inner, self.ssm_conv_dim
            per = d * (2 * di + 2 * self.ssm_groups * self.ssm_state
                       + self.ssm_heads)
            per += cdim * self.ssm_conv_kernel
            per += 2 * self.ssm_heads + di + di * d + 2 * d
            n += self.num_layers * per
            # ONE shared attention block reused every attn_every layers
            n += 2 * d * d          # concat([h, h0]) -> d projection
            n += attn_params() + mlp_params(f) + 2 * d
        else:
            per = attn_params() + 2 * d
            if self.is_moe:
                per += d * self.num_experts  # router
                expert = mlp_params(f)
                if active_only:
                    per += self.experts_per_token * expert
                else:
                    per += self.num_experts * expert
            else:
                per += mlp_params(f)
            n += self.num_layers * per
        n += d  # final norm
        return n

    def active_param_count(self) -> int:
        return self.param_count(active_only=True)

    def kv_bytes_per_token(self, bytes_per_el: int = 2) -> int:
        return (self.n_attn_layers * 2 * self.num_kv_heads * self.head_dim
                * bytes_per_el)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    if cfg.name in _REGISTRY:
        raise ValueError(f"duplicate arch {cfg.name}")
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    # import side-effect registration of all arch modules
    import repro.configs  # noqa: F401

    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown arch {name!r}; known: {sorted(_REGISTRY)}"
        ) from None


def list_archs() -> List[str]:
    import repro.configs  # noqa: F401

    return sorted(_REGISTRY)


def reduced(cfg: ArchConfig, *, layers: int = 2, d_model: int = 64,
            vocab: int = 256) -> ArchConfig:
    """Tiny same-family config for CPU smoke tests."""
    heads = 4 if cfg.num_heads else 0
    kv = 0
    if cfg.num_heads:
        # preserve the GQA ratio qualitatively
        kv = max(1, heads * cfg.num_kv_heads // cfg.num_heads)
        if cfg.num_kv_heads == cfg.num_heads:
            kv = heads
    changes = dict(
        num_layers=layers,
        d_model=d_model,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=(d_model // heads) if heads else 0,
        d_ff=(2 * d_model) if cfg.d_ff else 0,
        vocab_size=vocab,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_head_dim=16 if cfg.ssm_state else 64,
        ssm_chunk=8,
        frontend_tokens=4 if cfg.frontend != "none" else 0,
    )
    if cfg.is_moe:
        changes.update(num_experts=4, experts_per_token=2)
    if cfg.family == "hybrid":
        changes.update(attn_every=2, num_layers=max(layers, 4))
    return replace(cfg, **changes)
