"""Architecture configs — one module per assigned architecture.

Importing this package registers every config; ``get_config(name)`` /
``list_archs()`` are the public entry points.
"""

from repro.configs.base import ArchConfig, get_config, list_archs, reduced

# registration side effects — one module per assigned architecture
from repro.configs.granite_8b import GRANITE_8B
from repro.configs.nemotron_4_15b import NEMOTRON_4_15B
from repro.configs.stablelm_12b import STABLELM_12B
from repro.configs.qwen2_1_5b import QWEN2_1_5B
from repro.configs.pixtral_12b import PIXTRAL_12B
from repro.configs.zamba2_7b import ZAMBA2_7B
from repro.configs.qwen3_moe_235b_a22b import QWEN3_MOE_235B_A22B
from repro.configs.dbrx_132b import DBRX_132B
from repro.configs.musicgen_medium import MUSICGEN_MEDIUM
from repro.configs.mamba2_2_7b import MAMBA2_2_7B
from repro.configs.paper_agentic import PAPER_AGENTIC

ASSIGNED_ARCHS = [
    "granite-8b",
    "nemotron-4-15b",
    "stablelm-12b",
    "qwen2-1.5b",
    "pixtral-12b",
    "zamba2-7b",
    "qwen3-moe-235b-a22b",
    "dbrx-132b",
    "musicgen-medium",
    "mamba2-2.7b",
]

__all__ = [
    "ArchConfig", "get_config", "list_archs", "reduced", "ASSIGNED_ARCHS",
]
