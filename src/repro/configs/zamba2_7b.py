"""zamba2-7b — hybrid: Mamba2 backbone + shared attention blocks
[arXiv:2411.15242; unverified].

81 Mamba2 layers; ONE shared attention+MLP block (weights reused) applied
every ``attn_every`` layers on ``concat([h, h0])`` (h0 = embedding output),
following the Zamba shared-block design.
"""

from repro.configs.base import ArchConfig, register

ZAMBA2_7B = register(ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,           # MHA in the shared block
    d_ff=14336,
    vocab_size=32000,
    mlp_activation="swiglu",
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=128,
    attn_every=6,              # 81 layers -> 13 shared-block applications
    source="[arXiv:2411.15242; unverified]",
))
