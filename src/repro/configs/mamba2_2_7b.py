"""mamba2-2.7b — attention-free SSD (state-space duality) [arXiv:2405.21060; unverified]."""

from repro.configs.base import ArchConfig, register

MAMBA2_2_7B = register(ArchConfig(
    name="mamba2-2.7b",
    family="ssm",
    num_layers=64,
    d_model=2560,
    num_heads=0,               # attention-free
    num_kv_heads=0,
    d_ff=0,                    # Mamba2 blocks have no separate MLP
    vocab_size=50280,
    ssm_state=128,
    ssm_head_dim=64,           # d_inner 5120 -> 80 SSD heads
    ssm_expand=2,
    ssm_chunk=128,
    source="[arXiv:2405.21060; unverified]",
))
