"""Pure-jnp oracle for causal GQA flash attention."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray
                        ) -> jnp.ndarray:
    """Full-materialization causal attention.

    q: [b, s, h, hd]; k, v: [b, s, kv, hd]; returns [b, s, h, hd].
    """
    b, s, h, hd = q.shape
    kv = k.shape[2]
    g = h // kv
    scale = 1.0 / math.sqrt(hd)
    qr = q.reshape(b, s, kv, g, hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qr, k,
                        preferred_element_type=jnp.float32) * scale
    mask = jnp.tril(jnp.ones((s, s), bool))
    scores = jnp.where(mask[None, None, None], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs.astype(v.dtype), v)
    return out.reshape(b, s, h, hd)
