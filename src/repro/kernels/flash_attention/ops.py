"""Jit'd wrapper for flash attention with a custom VJP.

Forward: Pallas kernel (TPU) / chunked-jnp fallback elsewhere.
Backward: recompute-based VJP through the chunked-jnp implementation —
the forward kernel is the perf-critical path (prefill), while training
backward keeps XLA's fused recompute (remat makes this the same FLOPs a
dedicated backward kernel would do, see DESIGN §7).
"""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels.flash_attention.kernel import flash_attention_kernel
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.select import resolve_impl
from repro.models.layers import chunked_causal_attention


@partial(jax.custom_vjp, nondiff_argnums=(3,))
def _flash(q, k, v, impl):
    if impl == "pallas":
        return flash_attention_kernel(q, k, v)
    if impl == "interpret":
        return flash_attention_kernel(q, k, v, interpret=True)
    return chunked_causal_attention(q, k, v)


def _fwd(q, k, v, impl):
    return _flash(q, k, v, impl), (q, k, v)


def _bwd(impl, res, g):
    q, k, v = res
    _, vjp = jax.vjp(lambda q_, k_, v_: chunked_causal_attention(q_, k_, v_),
                     q, k, v)
    return vjp(g)


_flash.defvjp(_fwd, _bwd)


@partial(jax.jit, static_argnames=("impl",))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    *, impl: str = "auto") -> jax.Array:
    """Causal GQA attention.  q: [b,s,h,hd]; k,v: [b,s,kv,hd]."""
    return _flash(q, k, v, resolve_impl(impl, cpu_fallback="chunked"))
