"""Pallas TPU kernel: causal GQA flash attention (forward).

Standard online-softmax tiling adapted to the TPU memory hierarchy:

* grid = (batch, q_heads, q_blocks, kv_blocks); the kv axis is innermost
  so the fp32 accumulators for one q tile live in VMEM scratch across the
  whole kv sweep — the TPU analogue of keeping them in GPU registers;
* q/k/v tiles are ``[128, head_dim]`` — 128 rows align the MXU systolic
  array, head_dim rides the 128-lane VREG dimension;
* causal skipping: blocks strictly above the diagonal are skipped with
  ``pl.when`` (no FLOPs issued; the compiler still prefetches the tile —
  acceptable because the skipped fraction is ≤ half and prefetch is
  overlapped);
* GQA is expressed in the ``index_map``: kv tiles are indexed by
  ``q_head // group`` so no repeated-KV materialization ever exists.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_BIG = -0.7 * float(jnp.finfo(jnp.float32).max)


def _kernel(
    q_ref,    # [1, 1, bq, hd]
    k_ref,    # [1, 1, bk, hd]
    v_ref,    # [1, 1, bk, hd]
    o_ref,    # [1, 1, bq, hd]
    m_ref,    # [bq, 1] f32 scratch
    l_ref,    # [bq, 1] f32 scratch
    acc_ref,  # [bq, hd] f32 scratch
    *,
    bq: int,
    bk: int,
    scale: float,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    n_k = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_BIG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # causal: kv block strictly above the diagonal contributes nothing
    @pl.when(ki * bk <= qi * bq + bq - 1)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # [bq, hd]
        k = k_ref[0, 0].astype(jnp.float32)          # [bk, hd]
        v = v_ref[0, 0].astype(jnp.float32)

        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                    # [bq, bk]

        qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        causal = kpos <= qpos
        s = jnp.where(causal, s, NEG_BIG)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        p = jnp.where(causal, p, 0.0)

        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new

    @pl.when(ki == n_k - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_kernel(
    q: jax.Array,   # [b, s, h, hd]
    k: jax.Array,   # [b, s, kv, hd]
    v: jax.Array,
    *,
    bq: int = 128,
    bk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, s, h, hd = q.shape
    kv = k.shape[2]
    g = h // kv
    scale = 1.0 / math.sqrt(hd)
    bq = min(bq, s)
    bk = min(bk, s)
    assert s % bq == 0 and s % bk == 0, (s, bq, bk)

    # head-major layout for clean [rows, head_dim] tiles
    qt = q.transpose(0, 2, 1, 3)   # [b, h, s, hd]
    kt = k.transpose(0, 2, 1, 3)   # [b, kv, s, hd]
    vt = v.transpose(0, 2, 1, 3)

    grid = (b, h, s // bq, s // bk)

    kernel = pl.pallas_call(
        functools.partial(_kernel, bq=bq, bk=bk, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd),
                         lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b_, h_, qi, ki: (b_, h_ // g, ki, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b_, h_, qi, ki: (b_, h_ // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd),
                               lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        out_shape=jax.ShapeDtypeStruct((b, h, s, hd), q.dtype),
        interpret=interpret,
    )
    out = kernel(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
