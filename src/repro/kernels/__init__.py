"""Pallas TPU kernels for the perf-critical compute layers.

Each kernel ships as a subpackage: ``kernel.py`` (pl.pallas_call +
explicit BlockSpec VMEM tiling), ``ops.py`` (jit'd public wrapper with
backend fallback), ``ref.py`` (pure-jnp oracle).  All validated in
interpret mode against the oracles by ``tests/kernels/``.
"""

from repro.kernels.flash_attention import flash_attention
from repro.kernels.paged_attention import (paged_attention,
    paged_chunk_attention)
from repro.kernels.ssd_scan import ssd_scan

__all__ = ["flash_attention", "paged_attention",
           "paged_chunk_attention", "ssd_scan"]
