from repro.kernels.paged_attention.ops import (
    paged_attention,
    paged_chunk_attention,
)

__all__ = ["paged_attention", "paged_chunk_attention"]
