"""Jit'd public wrappers for paged decode attention.

Backend selection (shared with every ``kernels/*/ops.py`` via
:mod:`repro.kernels.select`): the Pallas kernel on TPU, interpret-mode
Pallas off-TPU when ``REPRO_KERNELS_INTERPRET=1`` (CPU CI executes the
kernel bodies), and the pure-jnp gather reference otherwise (CPU
smoke/serving — same math, same roofline terms).

Two entry points:

* :func:`paged_attention` — cached-only decode gather (the original,
  legacy two-dispatch serving path).
* :func:`paged_chunk_attention` — the fused CoW-aware kernel behind the
  serving decode fast path and speculative verify: inline chunk K/V,
  per-step CoW page indirection, optional int8 dequant (DESIGN §12).
"""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels.paged_attention.kernel import (
    paged_attention_kernel,
    paged_chunk_attention_kernel,
)
from repro.kernels.paged_attention.ref import (
    paged_attention_ref,
    paged_chunk_attention_ref,
)
from repro.kernels.select import resolve_impl


@partial(jax.jit, static_argnames=("impl",))
def paged_attention(
    q: jax.Array,            # [b, kv, g, hd]
    k_pages: jax.Array,      # [n_pages, page, kv, hd]
    v_pages: jax.Array,
    block_tables: jax.Array, # [b, max_pages] int32
    lengths: jax.Array,      # [b] int32
    *,
    impl: str = "auto",
) -> jax.Array:
    """Decode attention over CoW KV pages.  Returns [b, kv, g, hd]."""
    impl = resolve_impl(impl)
    if impl == "pallas":
        return paged_attention_kernel(q, k_pages, v_pages, block_tables,
                                      lengths)
    if impl == "interpret":
        return paged_attention_kernel(q, k_pages, v_pages, block_tables,
                                      lengths, interpret=True)
    if impl == "ref":
        return paged_attention_ref(q, k_pages, v_pages, block_tables,
                                   lengths)
    raise ValueError(f"unknown impl {impl}")


def paged_chunk_attention(
    q: jax.Array,            # [b, t, kv, g, hd]
    k_new: jax.Array,        # [b, t, kv, hd]
    v_new: jax.Array,
    k_pages: jax.Array,      # [n_pages, page, kv, hd] (int8 if quantized)
    v_pages: jax.Array,
    block_tables: jax.Array, # [b, max_pages] int32
    lengths: jax.Array,      # [b] int32 — cached length (chunk excluded)
    page_map: jax.Array,     # [n_pages] int32 CoW dst->src indirection
    k_scales: jax.Array = None,   # [n_pages, kv] f32 (int8 mode)
    v_scales: jax.Array = None,
    *,
    impl: str = "auto",
) -> jax.Array:
    """Fused CoW-aware decode (t=1) / speculative-verify (t=k) attention.

    Not jitted here: this op is always called from inside the engine's
    jitted decode/verify step, so wrapping it again would only add a
    dispatch boundary.  Returns [b, t, kv, g, hd].
    """
    impl = resolve_impl(impl)
    if impl in ("pallas", "interpret"):
        return paged_chunk_attention_kernel(
            q, k_new, v_new, k_pages, v_pages, block_tables, lengths,
            page_map, k_scales, v_scales, interpret=impl == "interpret")
    if impl == "ref":
        return paged_chunk_attention_ref(
            q, k_new, v_new, k_pages, v_pages, block_tables, lengths,
            page_map, k_scales, v_scales)
    raise ValueError(f"unknown impl {impl}")
