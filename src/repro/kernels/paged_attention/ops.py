"""Jit'd public wrapper for paged decode attention.

Backend selection: the Pallas kernel on TPU, interpret-mode Pallas when
requested (CPU validation), and the pure-jnp gather reference otherwise
(CPU smoke/serving — same math, same roofline terms)."""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels.paged_attention.kernel import paged_attention_kernel
from repro.kernels.paged_attention.ref import paged_attention_ref


@partial(jax.jit, static_argnames=("impl",))
def paged_attention(
    q: jax.Array,            # [b, kv, g, hd]
    k_pages: jax.Array,      # [n_pages, page, kv, hd]
    v_pages: jax.Array,
    block_tables: jax.Array, # [b, max_pages] int32
    lengths: jax.Array,      # [b] int32
    *,
    impl: str = "auto",
) -> jax.Array:
    """Decode attention over CoW KV pages.  Returns [b, kv, g, hd]."""
    if impl == "auto":
        impl = ("pallas" if jax.default_backend() == "tpu" else "ref")
    if impl == "pallas":
        return paged_attention_kernel(q, k_pages, v_pages, block_tables,
                                      lengths)
    if impl == "interpret":
        return paged_attention_kernel(q, k_pages, v_pages, block_tables,
                                      lengths, interpret=True)
    if impl == "ref":
        return paged_attention_ref(q, k_pages, v_pages, block_tables,
                                   lengths)
    raise ValueError(f"unknown impl {impl}")
