"""Pallas TPU kernel: paged decode attention with block-table indirection.

This is the paper's branch-chain resolution moved on-chip: a branched
sequence's KV pages are scattered across the HBM page pool (shared CoW
prefixes + private tail pages), and the block table — the flattened
branch chain — drives which page each grid step streams into VMEM.

TPU adaptation notes (vs. a GPU paged-attention port):
* the block table rides in **scalar-prefetch SMEM** so the ``index_map``
  can select the next HBM page *before* the grid step runs — Pallas
  double-buffers the page loads, hiding the indirection latency that a
  GPU kernel hides with warp-level gathers;
* online-softmax accumulators persist in VMEM **scratch** across the
  sequential page-walk grid dimension (TPU grids iterate, they don't
  oversubscribe like SM blocks);
* tiles are MXU-shaped: page_size is a multiple of 8 and head_dim a
  multiple of 128 on real hardware (decode is HBM-bandwidth-bound, so
  the matmul shape mostly matters for VREG packing).

Grid: (batch, kv_heads, pages).  The page axis is innermost so the
accumulators for one (seq, head) stay resident until finalized.

Two kernels live here:

* :func:`paged_attention_kernel` — the original cached-only decode
  gather (KV for the current token must already be in the pool).
* :func:`paged_chunk_attention_kernel` — the **CoW-aware fused** decode/
  verify kernel (DESIGN §12).  It additionally takes (a) the current
  chunk's K/V *inline* (``t`` freshly projected tokens that are NOT in
  the pool yet — ``t=1`` is plain decode, ``t=k`` is speculative
  verify), (b) a per-step **page indirection vector** ``page_map`` so a
  pending lazy-CoW fault's destination page is redirected to its still-
  valid source *inside the attention gather* (no materialized page copy
  on the attention path), and (c) optional per-page/per-kv-head int8
  dequant scales.  The in-chunk part is causal: query ``i`` of the
  chunk sees cached positions plus chunk keys ``0..i``.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_BIG = -0.7 * float(jnp.finfo(jnp.float32).max)


def _kernel(
    # scalar prefetch
    block_tables_ref,   # [b, max_pages] int32 (SMEM)
    lengths_ref,        # [b] int32 (SMEM)
    # inputs
    q_ref,              # [1, 1, g, hd]
    k_ref,              # [1, page, 1, hd]
    v_ref,              # [1, page, 1, hd]
    # outputs
    o_ref,              # [1, 1, g, hd]
    # scratch
    m_ref,              # [g, 1] f32
    l_ref,              # [g, 1] f32
    acc_ref,            # [g, hd] f32
    *,
    page_size: int,
    scale: float,
):
    b = pl.program_id(0)
    i = pl.program_id(2)
    n_pages = pl.num_programs(2)

    @pl.when(i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_BIG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)              # [g, hd]
    k = k_ref[0, :, 0, :].astype(jnp.float32)        # [page, hd]
    v = v_ref[0, :, 0, :].astype(jnp.float32)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * scale                                        # [g, page]

    pos = i * page_size + jax.lax.broadcasted_iota(jnp.int32,
                                                   (1, page_size), 1)
    valid = pos < lengths_ref[b]                     # [1, page]
    s = jnp.where(valid, s, NEG_BIG)

    m_prev = m_ref[...]                              # [g, 1]
    m_cur = jnp.max(s, axis=1, keepdims=True)        # [g, 1]
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)                  # [g, 1]
    p = jnp.exp(s - m_new)                           # [g, page]
    p = jnp.where(valid, p, 0.0)

    l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_ref[...] = m_new

    @pl.when(i == n_pages - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def paged_attention_kernel(
    q: jax.Array,            # [b, kv, g, hd]
    k_pages: jax.Array,      # [n_pages, page, kv, hd]
    v_pages: jax.Array,
    block_tables: jax.Array, # [b, max_pages] int32
    lengths: jax.Array,      # [b] int32
    *,
    interpret: bool = False,
) -> jax.Array:
    b, kv, g, hd = q.shape
    page = k_pages.shape[1]
    max_pages = block_tables.shape[1]
    scale = 1.0 / math.sqrt(hd)

    grid = (b, kv, max_pages)

    def q_map(b_, h_, i_, bt, ln):
        return (b_, h_, 0, 0)

    def kv_map(b_, h_, i_, bt, ln):
        return (bt[b_, i_], 0, h_, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, g, hd), q_map),
            pl.BlockSpec((1, page, 1, hd), kv_map),
            pl.BlockSpec((1, page, 1, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd), q_map),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, hd), jnp.float32),
        ],
    )

    kernel = pl.pallas_call(
        functools.partial(_kernel, page_size=page, scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kv, g, hd), q.dtype),
        interpret=interpret,
    )
    return kernel(block_tables.astype(jnp.int32), lengths.astype(jnp.int32),
                  q, k_pages, v_pages)


# ---------------------------------------------------------------------------
# fused CoW-aware chunk kernel (decode t=1 / speculative verify t=k)
# ---------------------------------------------------------------------------

def _chunk_kernel(
    # scalar prefetch
    block_tables_ref,   # [b, max_pages] int32 (SMEM)
    lengths_ref,        # [b] int32 (SMEM) — cached length, chunk excluded
    page_map_ref,       # [n_pages] int32 (SMEM) — CoW dst -> src redirect
    # inputs
    q_ref,              # [1, 1, t*g, hd]
    kn_ref,             # [1, t, 1, hd]   chunk K (inline, not in the pool)
    vn_ref,             # [1, t, 1, hd]
    k_ref,              # [1, page, 1, hd] (int8 when quantized)
    v_ref,              # [1, page, 1, hd]
    *rest,              # [ks_ref, vs_ref,] o_ref, m_ref, l_ref, acc_ref
    page_size: int,
    scale: float,
    t: int,
    g: int,
    quantized: bool,
):
    if quantized:
        ks_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref = rest
    else:
        o_ref, m_ref, l_ref, acc_ref = rest
        ks_ref = vs_ref = None
    b = pl.program_id(0)
    i = pl.program_id(2)
    n_pages = pl.num_programs(2)

    @pl.when(i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_BIG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)              # [t*g, hd]
    k = k_ref[0, :, 0, :].astype(jnp.float32)        # [page, hd]
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    if quantized:
        k = k * ks_ref[0, 0]
        v = v * vs_ref[0, 0]

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * scale                                        # [t*g, page]

    pos = i * page_size + jax.lax.broadcasted_iota(jnp.int32,
                                                   (1, page_size), 1)
    valid = pos < lengths_ref[b]                     # [1, page]
    s = jnp.where(valid, s, NEG_BIG)

    m_prev = m_ref[...]                              # [t*g, 1]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    p = jnp.where(valid, p, 0.0)

    l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_ref[...] = m_new

    @pl.when(i == n_pages - 1)
    def _finalize():
        # in-chunk causal attention: query row r belongs to chunk token
        # r // g and may see chunk keys 0..r//g (its own key included —
        # the classic decode "attend to yourself" position)
        kn = kn_ref[0, :, 0, :].astype(jnp.float32)  # [t, hd]
        vn = vn_ref[0, :, 0, :].astype(jnp.float32)
        sn = jax.lax.dot_general(
            q, kn, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                    # [t*g, t]
        q_tok = jax.lax.broadcasted_iota(jnp.int32, (t * g, t), 0) // g
        k_tok = jax.lax.broadcasted_iota(jnp.int32, (t * g, t), 1)
        causal = k_tok <= q_tok
        sn = jnp.where(causal, sn, NEG_BIG)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(sn, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(sn - m_new)
        p = jnp.where(causal, p, 0.0)
        l = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
        acc = alpha * acc_ref[...] + jax.lax.dot_general(
            p, vn, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        l = jnp.maximum(l, 1e-30)
        o_ref[0, 0] = (acc / l).astype(o_ref.dtype)


def paged_chunk_attention_kernel(
    q: jax.Array,            # [b, t, kv, g, hd]
    k_new: jax.Array,        # [b, t, kv, hd] — the chunk's K, inline
    v_new: jax.Array,
    k_pages: jax.Array,      # [n_pages, page, kv, hd] (int8 if quantized)
    v_pages: jax.Array,
    block_tables: jax.Array, # [b, max_pages] int32
    lengths: jax.Array,      # [b] int32 — cached length (chunk excluded)
    page_map: jax.Array,     # [n_pages] int32 — identity except CoW dst->src
    k_scales: jax.Array = None,  # [n_pages, kv] f32 (int8 mode)
    v_scales: jax.Array = None,
    *,
    interpret: bool = False,
) -> jax.Array:
    """Fused CoW-aware decode/verify attention.  Returns [b, t, kv, g, hd].

    Cached positions are gathered through ``page_map`` (so a pending CoW
    fault's redirect resolves in-kernel against the pre-copy pool), the
    ``t`` chunk tokens attend causally among themselves via the inline
    ``k_new``/``v_new`` (their KV need not be in the pool), and int8
    pools are dequantized per page/kv-head in VMEM.
    """
    b, t, kv, g, hd = q.shape
    page = k_pages.shape[1]
    max_pages = block_tables.shape[1]
    scale = 1.0 / math.sqrt(hd)
    quantized = k_scales is not None

    # the page walk treats the (t, g) query block as one t*g query set —
    # every chunk token sees the same cached positions
    qf = q.transpose(0, 2, 1, 3, 4).reshape(b, kv, t * g, hd)

    grid = (b, kv, max_pages)

    def q_map(b_, h_, i_, bt, ln, pm):
        return (b_, h_, 0, 0)

    def chunk_map(b_, h_, i_, bt, ln, pm):
        return (b_, 0, h_, 0)

    def kv_map(b_, h_, i_, bt, ln, pm):
        return (pm[bt[b_, i_]], 0, h_, 0)

    def scale_map(b_, h_, i_, bt, ln, pm):
        return (pm[bt[b_, i_]], h_)

    in_specs = [
        pl.BlockSpec((1, 1, t * g, hd), q_map),
        pl.BlockSpec((1, t, 1, hd), chunk_map),
        pl.BlockSpec((1, t, 1, hd), chunk_map),
        pl.BlockSpec((1, page, 1, hd), kv_map),
        pl.BlockSpec((1, page, 1, hd), kv_map),
    ]
    args = [qf, k_new, v_new, k_pages, v_pages]
    if quantized:
        in_specs += [pl.BlockSpec((1, 1), scale_map),
                     pl.BlockSpec((1, 1), scale_map)]
        args += [k_scales, v_scales]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, t * g, hd), q_map),
        scratch_shapes=[
            pltpu.VMEM((t * g, 1), jnp.float32),
            pltpu.VMEM((t * g, 1), jnp.float32),
            pltpu.VMEM((t * g, hd), jnp.float32),
        ],
    )

    kernel = pl.pallas_call(
        functools.partial(_chunk_kernel, page_size=page, scale=scale,
                          t=t, g=g, quantized=quantized),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kv, t * g, hd), q.dtype),
        interpret=interpret,
    )
    out = kernel(block_tables.astype(jnp.int32),
                 lengths.astype(jnp.int32),
                 page_map.astype(jnp.int32), *args)
    return out.reshape(b, kv, t, g, hd).transpose(0, 2, 1, 3, 4)
