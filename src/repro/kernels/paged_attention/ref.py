"""Pure-jnp oracle for paged decode attention over branched KV pages."""

from __future__ import annotations

import math

import jax.numpy as jnp
import jax


def paged_attention_ref(
    q: jnp.ndarray,            # [b, kv, g, hd]
    k_pages: jnp.ndarray,      # [n_pages, page, kv, hd]
    v_pages: jnp.ndarray,      # [n_pages, page, kv, hd]
    block_tables: jnp.ndarray, # [b, max_pages] int32 (pad = anything)
    lengths: jnp.ndarray,      # [b] int32
) -> jnp.ndarray:
    """Gather pages densely, then masked softmax attention.

    Returns [b, kv, g, hd].
    """
    b, kv, g, hd = q.shape
    page = k_pages.shape[1]
    max_pages = block_tables.shape[1]
    s = max_pages * page

    # dense gather of each sequence's pages: [b, max_pages, page, kv, hd]
    k = k_pages[block_tables].reshape(b, s, kv, hd)
    v = v_pages[block_tables].reshape(b, s, kv, hd)

    scale = 1.0 / math.sqrt(hd)
    scores = jnp.einsum("bkgh,bskh->bkgs", q, k,
                        preferred_element_type=jnp.float32) * scale
    mask = jnp.arange(s)[None, :] < lengths[:, None]      # [b, s]
    scores = jnp.where(mask[:, None, None, :], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskh->bkgh", probs.astype(v.dtype), v)
    return out


def paged_chunk_attention_ref(
    q: jnp.ndarray,            # [b, t, kv, g, hd]
    k_new: jnp.ndarray,        # [b, t, kv, hd] — chunk K, not in the pool
    v_new: jnp.ndarray,
    k_pages: jnp.ndarray,      # [n_pages, page, kv, hd] (int8 if quantized)
    v_pages: jnp.ndarray,
    block_tables: jnp.ndarray, # [b, max_pages] int32
    lengths: jnp.ndarray,      # [b] int32 — cached length (chunk excluded)
    page_map: jnp.ndarray = None,  # [n_pages] int32 CoW dst->src redirect
    k_scales: jnp.ndarray = None,  # [n_pages, kv] f32 per-page dequant
    v_scales: jnp.ndarray = None,
) -> jnp.ndarray:
    """Oracle for the fused CoW-aware decode/verify kernel.

    Dense gather of each sequence's pages *through the CoW indirection*
    (pending faults read their source page), optional int8 dequant, then
    masked softmax over cached positions plus a causal in-chunk block
    for the ``t`` inline tokens.  Returns [b, t, kv, g, hd].
    """
    b, t, kv, g, hd = q.shape
    page = k_pages.shape[1]
    max_pages = block_tables.shape[1]
    s = max_pages * page

    tables = block_tables
    if page_map is not None:
        tables = page_map[block_tables]            # resolve CoW redirects
    k = k_pages[tables].astype(jnp.float32)        # [b, mp, page, kv, hd]
    v = v_pages[tables].astype(jnp.float32)
    if k_scales is not None:
        k = k * k_scales[tables][:, :, None, :, None]
        v = v * v_scales[tables][:, :, None, :, None]
    k = k.reshape(b, s, kv, hd)
    v = v.reshape(b, s, kv, hd)

    scale = 1.0 / math.sqrt(hd)
    qf = q.astype(jnp.float32)
    sc = jnp.einsum("btkgh,bskh->btkgs", qf, k,
                    preferred_element_type=jnp.float32) * scale
    mask = jnp.arange(s)[None, :] < lengths[:, None]          # [b, s]
    sc = jnp.where(mask[:, None, None, None, :], sc, -jnp.inf)
    sn = jnp.einsum("btkgh,bjkh->btkgj", qf,
                    k_new.astype(jnp.float32),
                    preferred_element_type=jnp.float32) * scale
    causal = (jnp.arange(t)[:, None] >= jnp.arange(t)[None, :])  # [t, j]
    sn = jnp.where(causal[None, :, None, None, :], sn, -jnp.inf)

    scores = jnp.concatenate([sc, sn], axis=-1)    # [b, t, kv, g, s + t]
    probs = jax.nn.softmax(scores, axis=-1)
    out = (jnp.einsum("btkgs,bskh->btkgh", probs[..., :s], v)
           + jnp.einsum("btkgj,bjkh->btkgh", probs[..., s:],
                        v_new.astype(jnp.float32)))
    return out.astype(q.dtype)
