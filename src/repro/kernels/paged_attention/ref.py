"""Pure-jnp oracle for paged decode attention over branched KV pages."""

from __future__ import annotations

import math

import jax.numpy as jnp
import jax


def paged_attention_ref(
    q: jnp.ndarray,            # [b, kv, g, hd]
    k_pages: jnp.ndarray,      # [n_pages, page, kv, hd]
    v_pages: jnp.ndarray,      # [n_pages, page, kv, hd]
    block_tables: jnp.ndarray, # [b, max_pages] int32 (pad = anything)
    lengths: jnp.ndarray,      # [b] int32
) -> jnp.ndarray:
    """Gather pages densely, then masked softmax attention.

    Returns [b, kv, g, hd].
    """
    b, kv, g, hd = q.shape
    page = k_pages.shape[1]
    max_pages = block_tables.shape[1]
    s = max_pages * page

    # dense gather of each sequence's pages: [b, max_pages, page, kv, hd]
    k = k_pages[block_tables].reshape(b, s, kv, hd)
    v = v_pages[block_tables].reshape(b, s, kv, hd)

    scale = 1.0 / math.sqrt(hd)
    scores = jnp.einsum("bkgh,bskh->bkgs", q, k,
                        preferred_element_type=jnp.float32) * scale
    mask = jnp.arange(s)[None, :] < lengths[:, None]      # [b, s]
    scores = jnp.where(mask[:, None, None, :], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskh->bkgh", probs.astype(v.dtype), v)
    return out
