"""Backend selection shared by every ``kernels/*/ops.py`` wrapper.

``impl="auto"`` resolves once per call site:

* on TPU the compiled Pallas kernel runs;
* off-TPU the default is the jnp reference (``ref``/``chunked``) — the
  kernels' math oracle — **unless** ``REPRO_KERNELS_INTERPRET=1`` is
  set, in which case the *Pallas kernel code itself* executes in
  interpret mode.  CPU CI exports the flag so the kernel bodies (index
  maps, scalar prefetch, online-softmax scratch) are exercised on every
  run instead of silently falling back to the oracle everywhere.
"""

from __future__ import annotations

import os

import jax

_TRUTHY = ("1", "true", "yes", "on")

INTERPRET_ENV = "REPRO_KERNELS_INTERPRET"


def interpret_requested() -> bool:
    """Whether the environment asks for interpret-mode Pallas off-TPU."""
    return os.environ.get(INTERPRET_ENV, "").strip().lower() in _TRUTHY


def resolve_impl(impl: str, *, cpu_fallback: str = "ref") -> str:
    """Resolve ``"auto"`` to a concrete backend name.

    Non-``auto`` values pass through untouched, so explicit requests
    (tests pinning ``interpret``, benchmarks pinning ``ref``) always
    win over the environment.
    """
    if impl != "auto":
        return impl
    if jax.default_backend() == "tpu":
        return "pallas"
    if interpret_requested():
        return "interpret"
    return cpu_fallback


__all__ = ["INTERPRET_ENV", "interpret_requested", "resolve_impl"]
