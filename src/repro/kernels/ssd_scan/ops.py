"""Jit'd wrapper for the SSD scan: kernel on TPU, chunked-jnp elsewhere."""

from __future__ import annotations

from functools import partial
from typing import Tuple

import jax

from repro.kernels.select import resolve_impl
from repro.kernels.ssd_scan.kernel import ssd_scan_kernel
from repro.kernels.ssd_scan.ref import ssd_scan_ref


@partial(jax.jit, static_argnames=("chunk", "impl"))
def ssd_scan(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
             C: jax.Array, *, chunk: int = 128, impl: str = "auto",
             ) -> Tuple[jax.Array, jax.Array]:
    """Mamba2 SSD scan.  Returns (y [b,s,H,P], final_state [b,H,N,P])."""
    impl = resolve_impl(impl)
    if impl == "pallas":
        return ssd_scan_kernel(x, dt, A, B, C, chunk=chunk)
    if impl == "interpret":
        return ssd_scan_kernel(x, dt, A, B, C, chunk=chunk, interpret=True)
    if impl == "ref":
        return ssd_scan_ref(x, dt, A, B, C, chunk)
    raise ValueError(f"unknown impl {impl}")
