"""Oracle for the SSD scan kernel: the model's chunked-jnp implementation
(itself validated against one-token recurrence by the smoke tests)."""

from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

from repro.models.ssm import ssd_chunked


def ssd_scan_ref(x, dt, A, B, C, chunk: int) -> Tuple[jnp.ndarray,
                                                      jnp.ndarray]:
    """x: [b,s,H,P]; dt: [b,s,H] (post-softplus); A: [H]; B,C: [b,s,N].

    Returns (y [b,s,H,P], final_state [b,H,N,P]).
    """
    return ssd_chunked(x, dt, A, B, C, chunk)
