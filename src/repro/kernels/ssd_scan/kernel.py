"""Pallas TPU kernel: Mamba2 SSD chunked scan.

State-space duality on the MXU: for each (sequence, head) the kernel
walks the chunk axis sequentially, computing the quadratic *intra-chunk*
dual form as three small matmuls (``[Q,N]×[N,Q]``, ``[Q,Q]×[Q,P]``,
``[N,Q]×[Q,P]``) and carrying the ``[N,P]`` recurrent state in fp32 VMEM
scratch across grid steps — the inter-chunk recurrence never touches HBM.

Tiling: chunk Q=128 rows (MXU-aligned), state N=64..128 and head dim
P=64 ride the lane dimension.  dt/decay math is fp32; the matmul inputs
are cast to the model dtype.

Grid: (batch, heads, chunks) — chunks innermost so the state scratch for
one (b, h) stays resident until the sequence is done.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(
    x_ref,     # [1, 1, Q, P]
    dt_ref,    # [1, 1, Q, 1]  (post-softplus, f32)
    a_ref,     # [1, 1]        (A for this head, f32, negative)
    b_ref,     # [1, 1, Q, N]
    c_ref,     # [1, 1, Q, N]
    y_ref,     # [1, 1, Q, P]  out
    state_out_ref,  # [1, 1, N, P] out (final state)
    state_ref,      # [N, P] f32 scratch
    *,
    chunk: int,
):
    ci = pl.program_id(2)
    n_chunks = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0, 0]                             # [Q, P]
    dt = dt_ref[0, 0, 0].astype(jnp.float32)       # [Q, 1]
    A = a_ref[0, 0]                                # scalar f32
    Bm = b_ref[0, 0]                               # [Q, N]
    Cm = c_ref[0, 0]                               # [Q, N]

    dA = dt * A                                    # [Q, 1], negative
    cum = jnp.cumsum(dA, axis=0)                   # [Q, 1]

    # intra-chunk dual form
    cb = jax.lax.dot_general(
        Cm.astype(jnp.float32), Bm.astype(jnp.float32),
        (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32,
    )                                              # [Q, Q]
    decay = jnp.exp(cum - cum.T)                   # [Q, Q] (q row, k col)
    qpos = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    kpos = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(kpos <= qpos, decay, 0.0)
    W = cb * L * dt.T                              # [Q, Q] f32
    y = jax.lax.dot_general(
        W.astype(x.dtype), x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                              # [Q, P]

    # inter-chunk contribution from the carried state
    y_off = jax.lax.dot_general(
        Cm.astype(jnp.float32), state_ref[...],
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32,
    ) * jnp.exp(cum)                               # [Q, P]

    y_ref[0, 0, 0] = (y + y_off).astype(y_ref.dtype)

    # state update: S = exp(cum_Q) * S + (B * dt * decay_to_end)^T @ x
    decay_end = jnp.exp(cum[-1:] - cum)            # [Q, 1]
    wk = (Bm.astype(jnp.float32) * (dt * decay_end))  # [Q, N]
    s_new = jax.lax.dot_general(
        wk, x.astype(jnp.float32), (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                              # [N, P]
    state_ref[...] = jnp.exp(cum[-1]) * state_ref[...] + s_new

    @pl.when(ci == n_chunks - 1)
    def _emit_state():
        state_out_ref[0, 0] = state_ref[...]


def ssd_scan_kernel(
    x: jax.Array,    # [b, s, H, P]
    dt: jax.Array,   # [b, s, H] f32 (post-softplus)
    A: jax.Array,    # [H] f32 (negative)
    B: jax.Array,    # [b, s, N]
    C: jax.Array,    # [b, s, N]
    *,
    chunk: int = 128,
    interpret: bool = False,
):
    b, s, H, P = x.shape
    N = B.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    # head-major chunked layouts
    xt = x.transpose(0, 2, 1, 3).reshape(b, H, nc, chunk, P)
    dtt = dt.astype(jnp.float32).transpose(0, 2, 1).reshape(b, H, nc,
                                                            chunk, 1)
    Bt = B.reshape(b, nc, chunk, N)
    Ct = C.reshape(b, nc, chunk, N)
    A2 = A.astype(jnp.float32).reshape(H, 1)

    grid = (b, H, nc)

    y, state = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, chunk, P),
                         lambda b_, h_, c_: (b_, h_, c_, 0, 0)),
            pl.BlockSpec((1, 1, 1, chunk, 1),
                         lambda b_, h_, c_: (b_, h_, c_, 0, 0)),
            pl.BlockSpec((1, 1), lambda b_, h_, c_: (h_, 0)),
            pl.BlockSpec((1, 1, chunk, N),
                         lambda b_, h_, c_: (b_, c_, 0, 0)),
            pl.BlockSpec((1, 1, chunk, N),
                         lambda b_, h_, c_: (b_, c_, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, chunk, P),
                         lambda b_, h_, c_: (b_, h_, c_, 0, 0)),
            pl.BlockSpec((1, 1, N, P), lambda b_, h_, c_: (b_, h_, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, H, nc, chunk, P), x.dtype),
            jax.ShapeDtypeStruct((b, H, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(xt, dtt, A2, Bt, Ct)

    y = y.reshape(b, H, s, P).transpose(0, 2, 1, 3)
    return y, state
