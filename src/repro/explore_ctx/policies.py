"""Ready-to-use exploration policies (the paper's BranchContext library).

Each policy is a generator over one exploration root: it yields work
items (:class:`~repro.explore_ctx.driver.Fork`,
:class:`~repro.explore_ctx.driver.Decode`) to the driver, resolves its
branches with ``commit``/``abort`` directly, and returns a
:class:`~repro.explore_ctx.context.PolicyResult`.  Compose them with
``yield from`` (e.g. a tree search whose leaf evaluation is a nested
best-of-N), or hand them to :meth:`ExplorationDriver.explore` for the
three-line usage::

    drv = ExplorationDriver(Scheduler(engine))
    exp = drv.explore(prompt, max_new_tokens=24, policy=best_of_n, n=4)
    print(exp.run().tokens)

All branching goes through scheduler admission: under memory pressure a
policy sees backpressure (its forks wait) or, on a proven permanent
stall, ``AdmissionDenied`` — which ``tree_search`` absorbs by
committing the best of what it already has.
"""

from __future__ import annotations

from typing import Generator, List

from repro.core.errors import BranchError
from repro.explore_ctx.context import BranchContext, policy_result as _result
from repro.explore_ctx.driver import Decode, Fork
from repro.explore_ctx.scoring import Scorer, mean_token_score


def _fork_or_none(ctx: BranchContext, n: int) -> Generator:
    """Fork through admission; ``None`` when the fork cannot happen.

    Transient pressure never reaches the policy (the driver retries the
    fork as other explorations recycle pages); what lands here is the
    *permanent* -EAGAIN (the driver proved nothing else can free pages)
    or a context that resolved underneath us (e.g. the root retired at
    its budget after a degraded level) — in both cases the policy should
    degrade rather than die.
    """
    try:
        return (yield Fork(ctx, n))
    except BranchError:   # includes AdmissionDenied
        return None


def best_of_n(ctx: BranchContext, *, n: int = 4, tokens: int = 8,
              score_fn: Scorer = mean_token_score,
              temperature: float = 1.5) -> Generator:
    """Fork ``n`` branches, decode ``tokens`` each, commit the best."""
    kids = yield from _fork_or_none(ctx, n)
    if kids is None:
        # permanent page pressure: degrade to the unforked origin
        yield Decode([ctx], tokens, temperature=temperature)
        return _result(ctx, committed=False, policy="best_of_n",
                       degraded=True, branches=0, scores=[])
    yield Decode(kids, tokens, temperature=temperature)
    for k in kids:
        k.score = score_fn(k)
    winner = max(kids, key=lambda k: k.score)
    winner.commit()   # first-commit-wins recycles every sibling
    return _result(ctx, score=winner.score, policy="best_of_n",
                   branches=n, scores=[k.score for k in kids])


def beam_search(ctx: BranchContext, *, width: int = 3, depth: int = 2,
                tokens_per_level: int = 4,
                score_fn: Scorer = mean_token_score,
                temperature: float = 1.5) -> Generator:
    """Greedy beam: per level, fork ``width`` candidates and commit the
    best into the root before descending — the Tree-of-Thoughts loop of
    ``examples/agentic_serve.py`` as a reusable policy."""
    levels = []
    last_score = None
    for level in range(depth):
        kids = yield from _fork_or_none(ctx, width)
        if kids is None:
            # degrade this level to an unforked continuation
            yield Decode([ctx], tokens_per_level, temperature=temperature)
            levels.append({"level": level, "degraded": True})
            continue
        yield Decode(kids, tokens_per_level, temperature=temperature)
        for k in kids:
            k.score = score_fn(k)
        winner = max(kids, key=lambda k: k.score)
        winner.commit()   # per-level commit: losers recycled immediately
        last_score = winner.score
        levels.append({"level": level, "winner_seq": winner.seq,
                       "scores": [k.score for k in kids]})
    return _result(ctx, score=last_score, policy="beam_search",
                   width=width, depth=depth, levels=levels)


def tree_search(ctx: BranchContext, *, fan_out: int = 3,
                tokens_per_node: int = 4, max_nodes: int = 9,
                max_depth: int = 3, prune_below: float = None,
                score_fn: Scorer = mean_token_score,
                temperature: float = 1.5) -> Generator:
    """Best-first tree search with a fan-out budget and early abort.

    Expands the most promising live node into ``fan_out`` *nested*
    children until ``max_nodes`` branches have been created (or the
    page budget pushes back permanently), aborting children scoring
    below ``prune_below`` on the spot.  The best surviving node's whole
    lineage then commits level by level — recursive sibling
    invalidation reclaims every other subtree in one cascade.
    """
    frontier: List[BranchContext] = [ctx]
    candidates: List[BranchContext] = []
    created = pruned = 0
    denied = False
    while frontier and created < max_nodes:
        frontier.sort(key=lambda c: c.score if c.score is not None
                      else float("inf"), reverse=True)
        node = frontier.pop(0)
        n = min(fan_out, max_nodes - created)
        try:
            kids = yield Fork(node, n)
        except BranchError:   # includes the permanent -EAGAIN
            denied = True     # backpressure: use what we have
            break
        created += len(kids)
        yield Decode(kids, tokens_per_node, temperature=temperature)
        for k in kids:
            k.score = score_fn(k)
            if prune_below is not None and k.score < prune_below:
                k.abort()   # early abort: pages recycled mid-search
                pruned += 1
                continue
            candidates.append(k)
            if k.depth - ctx.depth < max_depth:
                frontier.append(k)
    live = [c for c in candidates if c.alive]
    if not live:
        if denied and not created:
            # couldn't even open the search: degrade to unforked decode
            yield Decode([ctx], tokens_per_node, temperature=temperature)
        # everything pruned/denied: the origin resumes — keep it
        return _result(ctx, committed=False, policy="tree_search",
                       branches_created=created, pruned=pruned,
                       denied=denied)
    best = max(live, key=lambda c: c.score)
    best.prune_children()   # an expanded winner sheds its live subtree
    best.commit_chain(until=ctx)
    return _result(ctx, score=best.score, policy="tree_search",
                   branches_created=created, pruned=pruned,
                   denied=denied, winner_depth=best.depth - ctx.depth)


__all__ = ["beam_search", "best_of_n", "tree_search"]
