"""BranchContext — the exploration-policy subsystem (paper artifact #2).

A context-manager API (:class:`BranchContext`) over the scheduler's
admission-checked branch lifecycle, an event-driven
:class:`ExplorationDriver` that multiplexes many concurrent searches
over one engine's continuous-batching loop, and a library of reusable
policies: :func:`best_of_n`, :func:`beam_search`, :func:`tree_search`,
:func:`speculative_decode`, plus the training-side
:class:`SpeculativeTrainer`.  See DESIGN §9.
"""

from repro.explore_ctx.context import BranchContext, PolicyResult
from repro.explore_ctx.driver import (
    Decode,
    Exploration,
    ExplorationDriver,
    Fork,
    Submit,
    Tick,
)
from repro.explore_ctx.policies import beam_search, best_of_n, tree_search
from repro.explore_ctx.scoring import (
    combined_score,
    diversity_score,
    lcp_len,
    mean_token_score,
)
from repro.explore_ctx.speculative import SpeculativeTrainer, speculative_decode

__all__ = [
    "BranchContext",
    "Decode",
    "Exploration",
    "ExplorationDriver",
    "Fork",
    "PolicyResult",
    "SpeculativeTrainer",
    "Submit",
    "Tick",
    "beam_search",
    "best_of_n",
    "combined_score",
    "diversity_score",
    "lcp_len",
    "mean_token_score",
    "speculative_decode",
    "tree_search",
]
