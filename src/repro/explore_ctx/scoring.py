"""Branch scorers — the "explore" half of fork/explore/commit.

A scorer maps a :class:`~repro.explore_ctx.context.BranchContext` to a
float; policies rank sibling branches with it and commit the winner.
In production this is a verifier, reward model or unit-test harness;
these built-ins are cheap stand-ins over the generated token ids so the
policies (and their benchmarks) run hermetically.
"""

from __future__ import annotations

from typing import Callable, Sequence

import numpy as np

from repro.explore_ctx.context import BranchContext

Scorer = Callable[[BranchContext], float]


def mean_token_score(ctx: BranchContext) -> float:
    """Mean generated token id — the seed example's stand-in reward."""
    gen = ctx.generated()
    return float(np.mean(gen)) if gen else float("-inf")


def diversity_score(ctx: BranchContext) -> float:
    """Fraction of distinct tokens in the generation (anti-loop prior)."""
    gen = ctx.generated()
    return len(set(gen)) / len(gen) if gen else float("-inf")


def combined_score(*weighted: "tuple[float, Scorer]") -> Scorer:
    """Weighted sum of scorers: ``combined_score((1.0, a), (0.5, b))``."""

    def score(ctx: BranchContext) -> float:
        return sum(w * f(ctx) for w, f in weighted)

    return score


def lcp_len(a: Sequence[int], b: Sequence[int]) -> int:
    """Longest-common-prefix length (speculative-decode verification)."""
    n = 0
    for x, y in zip(a, b):
        if x != y:
            break
        n += 1
    return n


__all__ = ["Scorer", "combined_score", "diversity_score", "lcp_len",
           "mean_token_score"]
