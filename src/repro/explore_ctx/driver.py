"""Event-driven exploration driver — many searches, one engine.

The paper's BranchContext library is only useful at serving scale if
hundreds of independent explorations can share one engine without
hand-rolled coordination.  This driver is that multiplexer:

* **Policies are generators.**  A policy yields *work items* —
  :class:`Submit`, :class:`Fork`, :class:`Decode`, :class:`Tick` — and
  performs commits/aborts synchronously on its contexts.  ``yield
  from`` composes policies into nested searches.
* **One continuous batch.**  Each driver step resumes every policy
  whose wait is satisfied, then runs exactly one ``Scheduler.step`` —
  so decode work from every live exploration lands in the same
  continuous batch (per-sequence sampling settings let greedy
  verification and high-temperature exploration share a dispatch).
* **Backpressure, not crashes.**  A ``Fork`` that the page-budget
  ledger cannot absorb parks the exploration and retries each step:
  other explorations' commits recycle pages and unblock it.  Only a
  *provably* stalled system (a driver round in which nothing decoded,
  admitted, retired or resumed — deterministic, so nothing ever will)
  throws ``AdmissionDenied`` into the blocked policies, which may then
  shrink their fan-out or commit what they have.
* **Nothing leaks.**  When a policy returns (or raises), its request is
  force-retired through :meth:`Scheduler.finish`: the root subtree is
  released across every domain and all reservations return to the
  pool.  N explorations entering always means a drained pool leaving.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Generator, List, Optional, Sequence, Tuple

from repro.core.branch import root_context
from repro.core.errors import BranchError, BranchStateError
from repro.core.runtime_api import BranchRuntime
from repro.core.store import BranchStore
from repro.explore_ctx.context import BranchContext, StateContext
from repro.runtime.scheduler import AdmissionDenied, Scheduler


# ---------------------------------------------------------------------------
# work items a policy may yield
# ---------------------------------------------------------------------------

@dataclass
class Submit:
    """Queue a request; resumes with the admitted root BranchContext."""

    prompt: Sequence[int]
    max_new_tokens: int = 16


@dataclass
class Fork:
    """Fork ``n`` children of ``ctx``; resumes with the child contexts.

    Retried with backpressure while the page budget cannot absorb it.
    """

    ctx: BranchContext
    n: int


@dataclass
class Decode:
    """Decode ``tokens`` more tokens on each context, then resume.

    The driver unparks the sequences, tags their sampling settings, and
    lets the scheduler batch them with everyone else's work; contexts
    that resolve or hit their request budget early count as done.
    ``greedy``/``temperature`` may be scalars or per-context rows, so a
    greedy verifier and sampled drafts decode in ONE wait (and one
    device batch) — the per-sequence sampling feature's whole point.
    """

    ctxs: Sequence[BranchContext]
    tokens: int
    greedy: Any = False
    temperature: Any = 1.5


@dataclass
class Tick:
    """Let the engine run ``steps`` scheduler steps (generic wait)."""

    steps: int = 1


# ---------------------------------------------------------------------------
# waits (internal): when may a parked exploration resume?
# ---------------------------------------------------------------------------

class _WaitAdmitted:
    def __init__(self, req_id: int):
        self.req_id = req_id

    def poll(self, drv: "ExplorationDriver") -> Tuple[bool, Any]:
        try:
            seq = drv.sched.seq_of(self.req_id)
        except BranchError:
            return False, None
        # the seq was held in the admission transaction (submit(hold=True))
        return True, drv._bind_root(self.req_id, seq)


class _WaitFork:
    def __init__(self, item: Fork):
        self.item = item
        self.attempts = 0

    def poll(self, drv: "ExplorationDriver") -> Tuple[bool, Any]:
        try:
            kids = self.item.ctx.fork(self.item.n)
        except AdmissionDenied:
            self.attempts += 1
            return False, None
        return True, kids


class _WaitTokens:
    def __init__(self, item: Decode, targets: Dict[int, int]):
        self.item = item
        self.targets = targets   # seq -> produced() target

    def _satisfied(self, drv: "ExplorationDriver", seq: int,
                   target: int) -> bool:
        sched = drv.sched
        if not sched.is_tracked(seq):
            return True          # resolved / reaped / evicted
        if not sched.engine.kv.is_live(seq):
            return True
        req = sched.request_of(seq)
        if req is None:
            return True
        produced = sched.produced(seq)
        return produced >= target or produced >= req.max_new_tokens

    def poll(self, drv: "ExplorationDriver") -> Tuple[bool, Any]:
        if not all(self._satisfied(drv, s, t)
                   for s, t in self.targets.items()):
            return False, None
        for seq in self.targets:
            if drv.sched.is_tracked(seq):
                drv.sched.hold(seq)   # park again: policy regains control
        return True, None


class _WaitSteps:
    def __init__(self, until_step: int):
        self.until_step = until_step

    def poll(self, drv: "ExplorationDriver") -> Tuple[bool, Any]:
        return drv.steps >= self.until_step, None


# ---------------------------------------------------------------------------
# exploration handle
# ---------------------------------------------------------------------------

class Exploration:
    """A launched policy: its future result plus bookkeeping."""

    def __init__(self, driver: "ExplorationDriver",
                 gen: Generator, name: str):
        self.driver = driver
        self.gen = gen
        self.name = name
        self.req_id: Optional[int] = None
        self.root: Optional[BranchContext] = None
        self.wait: Optional[Any] = None
        self.started = False
        self.done = False
        self.result: Any = None
        self.error: Optional[BaseException] = None
        self.error_reported = False   # raised to a caller exactly once
        self.final_tokens: Optional[List[int]] = None

    def run(self, max_steps: int = 10_000, **decode_kw: Any) -> Any:
        """Drive the whole fleet until *this* exploration resolves."""
        self.driver.run(max_steps=max_steps, until=self, **decode_kw)
        if self.error is not None:
            self.error_reported = True
            raise self.error
        return self.result


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

class ExplorationDriver:
    """Multiplexes generator policies over one scheduler."""

    def __init__(self, sched: Scheduler, *,
                 store: Optional[BranchStore] = None):
        self.sched = sched
        self.store = store
        # composite contexts: the runtime's KV fork is the scheduler's,
        # so store+KV creates go through page-budget admission together
        self.runtime = (BranchRuntime.scheduled(store, sched)
                        if store is not None else None)
        self._state_root: Optional[StateContext] = (
            root_context(store) if store is not None else None)
        self._live: List[Exploration] = []
        self.explorations: List[Exploration] = []
        self.steps = 0

    # -- launching ------------------------------------------------------
    def launch(self, gen: Generator, *, name: str = "") -> Exploration:
        """Register a policy generator; it starts on the next step."""
        exp = Exploration(self, gen, name or f"exploration-{len(self.explorations)}")
        self._live.append(exp)
        self.explorations.append(exp)
        return exp

    def explore(self, prompt: Sequence[int], max_new_tokens: int,
                policy: Any, *, name: str = "",
                **policy_kw: Any) -> Exploration:
        """One-liner: submit ``prompt`` and run ``policy`` on its root."""

        def wrapper() -> Generator:
            ctx = yield Submit(prompt, max_new_tokens)
            return (yield from policy(ctx, **policy_kw))

        return self.launch(wrapper(), name=name or getattr(
            policy, "__name__", "policy"))

    def _bind_root(self, req_id: int, seq: int) -> BranchContext:
        state = None
        if self._state_root is not None:
            # each exploration explores inside its own store subtree, so
            # concurrent explorations never race each other's epoch CAS
            (state,) = self._state_root.fork(1)
        return BranchContext(self.sched, seq, req_id=req_id,
                             runtime=self.runtime, state=state)

    # -- stepping -------------------------------------------------------
    def _advance(self, exp: Exploration, value: Any = None,
                 error: Optional[BaseException] = None) -> None:
        """Run one exploration's host code until it blocks again."""
        while True:
            try:
                if error is not None:
                    err, error = error, None
                    item = exp.gen.throw(err)
                elif not exp.started:
                    exp.started = True
                    item = next(exp.gen)
                else:
                    item = exp.gen.send(value)
            except StopIteration as stop:
                self._finalize(exp, stop.value)
                return
            except BaseException as err:   # policy bug: fail + clean up
                self._fail(exp, err)
                return

            if isinstance(item, Submit):
                try:
                    exp.req_id = self.sched.submit(
                        list(item.prompt), item.max_new_tokens, hold=True)
                except AdmissionDenied as err:
                    # can NEVER fit: not backpressure — the policy decides
                    value, error = None, err
                    continue
                self.sched.admit()   # admit eagerly if pages allow
                exp.wait = _WaitAdmitted(exp.req_id)
                ok, value = exp.wait.poll(self)   # may admit immediately
                if ok:
                    exp.root = value
                    exp.wait = None
                    continue
                return
            elif isinstance(item, Fork):
                try:
                    value = item.ctx.fork(item.n)
                    continue
                except AdmissionDenied:
                    exp.wait = _WaitFork(item)    # backpressure: retry
                    return
                except BranchError as err:
                    # forking a resolved/evicted context is a policy-level
                    # condition: deliver it to the generator, not the run
                    value, error = None, err
                    continue
            elif isinstance(item, Decode):
                k = len(item.ctxs)
                g_row = (list(item.greedy) if isinstance(
                    item.greedy, (list, tuple)) else [item.greedy] * k)
                t_row = (list(item.temperature) if isinstance(
                    item.temperature, (list, tuple))
                    else [item.temperature] * k)
                if len(g_row) != k or len(t_row) != k:
                    value, error = None, ValueError(
                        "Decode sampling rows must match its contexts")
                    continue
                targets: Dict[int, int] = {}
                for ctx, g, t in zip(item.ctxs, g_row, t_row):
                    seq = ctx.seq
                    if not self.sched.is_tracked(seq):
                        continue   # already resolved: nothing to decode
                    self.sched.set_sampling(seq, greedy=g, temperature=t)
                    self.sched.unhold(seq)
                    targets[seq] = self.sched.produced(seq) + item.tokens
                if not targets:
                    value = None
                    continue
                exp.wait = _WaitTokens(item, targets)
                return
            elif isinstance(item, Tick):
                exp.wait = _WaitSteps(self.steps + item.steps)
                return
            else:
                value, error = None, TypeError(
                    f"policy yielded {item!r}; expected Submit/Fork/"
                    "Decode/Tick")

    def _cleanup(self, exp: Exploration) -> None:
        if exp.req_id is not None:
            if not self.sched.finished(exp.req_id):
                self.sched.finish(exp.req_id)
            if self.sched.peek_result(exp.req_id) is not None:
                exp.final_tokens = self.sched.result(exp.req_id)
        # composite mode: the per-exploration store subtree is done —
        # abort + reap it so a long-running driver's store stays bounded
        # (a policy that wants state to outlive its exploration must
        # surface it through its return value before finishing)
        if exp.root is not None and exp.root.state is not None \
                and self.store is not None:
            state = exp.root.state
            try:
                if state.is_active:
                    state.abort()
            except BranchStateError:
                pass
            self.store.reap(state.branch_id)

    def _finalize(self, exp: Exploration, result: Any) -> None:
        exp.result = result
        exp.done = True
        exp.wait = None
        self._live.remove(exp)
        self._cleanup(exp)

    def _fail(self, exp: Exploration, err: BaseException) -> None:
        exp.error = err
        exp.done = True
        exp.wait = None
        self._live.remove(exp)
        self._cleanup(exp)   # release the subtree: no stranded reservations

    def step(self, **decode_kw: Any) -> Dict[str, Any]:
        """One round: resume ready explorations, then one scheduler step."""
        self.sched.admit()   # admit first so _WaitAdmitted binds + holds
        resumed = 0
        for exp in list(self._live):
            if exp.done:
                continue
            if exp.wait is None:
                self._advance(exp)
                resumed += 1
            else:
                try:
                    ok, value = exp.wait.poll(self)
                except Exception as err:
                    # a wait that can never be satisfied (its context was
                    # evicted/resolved underneath it) fails into the
                    # policy, not the driver loop
                    exp.wait = None
                    self._advance(exp, error=err)
                    resumed += 1
                    continue
                if ok:
                    exp.wait = None
                    if isinstance(value, BranchContext) and exp.root is None:
                        exp.root = value
                    self._advance(exp, value)
                    resumed += 1
        st = self.sched.step(**decode_kw)
        st["resumed"] = resumed
        st["live_explorations"] = len(self._live)
        self.steps += 1
        return st

    def run(self, max_steps: int = 10_000, *,
            until: Optional[Exploration] = None,
            raise_errors: bool = True, **decode_kw: Any) -> List[Exploration]:
        """Step until every exploration (or ``until``) resolves."""
        decode_kw = dict(decode_kw)
        key = decode_kw.pop("key", None)
        if key is not None:
            # one key must not reach every step (identical sampling
            # noise each round): it reseeds the scheduler's stream
            self.sched.seed_sampling(key)
        stalled = 0
        for _ in range(max_steps):
            if not self._live or (until is not None and until.done):
                break
            st = self.step(**decode_kw)
            if st["resumed"] or st["decoded"] or st["admitted"] \
                    or st["retired"]:
                stalled = 0
                continue
            if any(isinstance(e.wait, _WaitSteps) for e in self._live):
                continue   # a Tick always resolves: steps advance
            # A fully idle round is deterministic: nothing will change on
            # its own.  Kick ONE fork-blocked policy with a permanent
            # -EAGAIN (it may shrink its fan-out or degrade to unforked
            # decoding, freeing pages for the rest); if nobody is
            # fork-blocked, the stall is unrecoverable.
            stalled += 1
            if self._kick_stalled():
                stalled = 0
            elif stalled > 1:
                blocked = [e.name for e in self._live]
                raise RuntimeError(
                    f"exploration driver stalled; blocked: {blocked}")
        else:
            if self._live and (until is None or not until.done):
                raise RuntimeError(
                    f"driver exceeded max_steps={max_steps} with "
                    f"{len(self._live)} explorations live")
        if raise_errors:
            if until is not None:
                # the caller awaits ONE exploration: only its error is
                # theirs; other failures surface on their own run calls
                if until.error is not None and not until.error_reported:
                    until.error_reported = True
                    raise until.error
            else:
                for exp in self.explorations:
                    if exp.error is not None and not exp.error_reported:
                        exp.error_reported = True
                        raise exp.error
        return self.explorations

    def _kick_stalled(self) -> int:
        """Throw -EAGAIN into ONE fork-blocked policy on a proven stall."""
        for exp in list(self._live):
            if isinstance(exp.wait, _WaitFork):
                wait, exp.wait = exp.wait, None
                self._advance(exp, error=AdmissionDenied(
                    f"fork({wait.item.ctx.seq}, n={wait.item.n}) cannot be "
                    f"admitted after {wait.attempts} retries and no other "
                    "exploration can free pages (-EAGAIN, permanent)"))
                return 1
        return 0


__all__ = ["Decode", "Exploration", "ExplorationDriver", "Fork",
           "Submit", "Tick"]
