"""Event-driven exploration driver — many searches, one engine.

The paper's BranchContext library is only useful at serving scale if
hundreds of independent explorations can share one engine without
hand-rolled coordination.  This driver is that multiplexer, and since
the ``repro.api`` redesign it runs **entirely through the public
surface**: every fork is a ``session.branch()`` call, every wait is a
:class:`~repro.api.events.Waiter` registration, every retirement is
``session.finish()`` — no raw scheduler verbs.

* **Policies are generators.**  A policy yields *work items* —
  :class:`Submit`, :class:`Fork`, :class:`Decode`, :class:`Tick` — and
  performs commits/aborts synchronously on its contexts.  ``yield
  from`` composes policies into nested searches.
* **One continuous batch.**  Each driver step resumes every policy
  whose wait is satisfied, then runs exactly one ``session.step`` —
  so decode work from every live exploration lands in the same
  continuous batch (per-sequence sampling settings let greedy
  verification and high-temperature exploration share a dispatch).
* **Backpressure, not crashes.**  A ``Fork`` that the page-budget
  ledger cannot absorb parks the exploration and retries each step:
  other explorations' commits recycle pages and unblock it.  Only a
  *provably* stalled system (a driver round in which nothing decoded,
  admitted, retired or resumed — deterministic, so nothing ever will)
  throws ``AdmissionDenied`` into the blocked policies, which may then
  shrink their fan-out or commit what they have.
* **Nothing leaks.**  When a policy returns (or raises), its request is
  force-retired through ``session.finish``: the root subtree is
  released across every domain, all reservations return to the pool,
  and every handle rooted at the request is closed (recycling its
  table slot).  N explorations entering always means a drained pool
  leaving.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Generator, List, Optional, Sequence, Tuple

from repro.api.events import EV_ADMITTED, Waiter
from repro.api.flags import BR_HOLD
from repro.api.session import BranchSession
from repro.core.errors import AdmissionDenied, BranchError, Errno
from repro.core.store import BranchStore
from repro.explore_ctx.context import BranchContext, StateContext  # noqa: F401


# ---------------------------------------------------------------------------
# work items a policy may yield
# ---------------------------------------------------------------------------

@dataclass
class Submit:
    """Queue a request; resumes with the admitted root BranchContext."""

    prompt: Sequence[int]
    max_new_tokens: int = 16


@dataclass
class Fork:
    """Fork ``n`` children of ``ctx``; resumes with the child contexts.

    Retried with backpressure while the page budget cannot absorb it.
    ``flags`` ORs extra ``repro.api`` flags into the fork —
    ``BR_SPECULATIVE`` declares the children truncatable drafts.
    """

    ctx: BranchContext
    n: int
    flags: int = 0


@dataclass
class Decode:
    """Decode ``tokens`` more tokens on each context, then resume.

    The driver unparks the sequences, tags their sampling settings, and
    lets the scheduler batch them with everyone else's work; contexts
    that resolve or hit their request budget early count as done.
    ``greedy``/``temperature`` may be scalars or per-context rows, so a
    greedy verifier and sampled drafts decode in ONE wait (and one
    device batch) — the per-sequence sampling feature's whole point.
    """

    ctxs: Sequence[BranchContext]
    tokens: int
    greedy: Any = False
    temperature: Any = 1.5


@dataclass
class Tick:
    """Let the engine run ``steps`` scheduler steps (generic wait)."""

    steps: int = 1


# ---------------------------------------------------------------------------
# waits (internal): when may a parked exploration resume?
# All readiness goes through the session's event surface — the driver
# never inspects scheduler internals.
# ---------------------------------------------------------------------------

class _WaitAdmitted:
    def __init__(self, hd: int):
        self.hd = hd

    def poll(self, drv: "ExplorationDriver") -> Tuple[bool, Any]:
        if not drv.session.events(self.hd) & EV_ADMITTED:
            return False, None
        return True, BranchContext(drv.session, self.hd)


class _WaitFork:
    def __init__(self, item: Fork):
        self.item = item
        self.attempts = 0

    def poll(self, drv: "ExplorationDriver") -> Tuple[bool, Any]:
        try:
            kids = self.item.ctx.fork(self.item.n, self.item.flags)
        except AdmissionDenied:
            self.attempts += 1
            return False, None
        return True, kids


class _WaitDecode:
    """A Decode whose demoted context cannot be re-seated yet.

    The scheduler may checkpoint a held branch out of the device pool
    to admit new work (demote-before-deny); resuming it restores the
    snapshot, and that restore is budget-checked.  Until it is
    admitted, the whole Decode retries with backpressure — mirroring
    ``_WaitFork`` — then delegates to the token wait it finally starts.
    """

    def __init__(self, item: Decode, g_row: List[Any], t_row: List[Any]):
        self.item = item
        self.g_row = g_row
        self.t_row = t_row
        self.attempts = 0
        self.inner: Optional["_WaitTokens"] = None

    def poll(self, drv: "ExplorationDriver") -> Tuple[bool, Any]:
        if self.inner is None:
            try:
                self.inner = drv._start_decode(self.item, self.g_row,
                                               self.t_row)
            except AdmissionDenied:
                self.attempts += 1
                return False, None
            if self.inner is None:      # every context resolved meanwhile
                return True, None
        return self.inner.poll(drv)


class _WaitTokens:
    def __init__(self, waiter: Waiter, ctxs: Sequence[BranchContext]):
        self.waiter = waiter
        self.ctxs = ctxs

    def poll(self, drv: "ExplorationDriver") -> Tuple[bool, Any]:
        ready = self.waiter.poll()
        if len(ready) < len(self.waiter.handles()):
            return False, None
        for ctx in self.ctxs:
            drv.session.pause(ctx.hd)   # park again: policy regains control
        return True, None


class _WaitSteps:
    def __init__(self, until_step: int):
        self.until_step = until_step

    def poll(self, drv: "ExplorationDriver") -> Tuple[bool, Any]:
        return drv.steps >= self.until_step, None


# ---------------------------------------------------------------------------
# exploration handle
# ---------------------------------------------------------------------------

class Exploration:
    """A launched policy: its future result plus bookkeeping."""

    def __init__(self, driver: "ExplorationDriver",
                 gen: Generator, name: str):
        self.driver = driver
        self.gen = gen
        self.name = name
        self.hd: Optional[int] = None          # session root handle
        self.req_id: Optional[int] = None
        self.root: Optional[BranchContext] = None
        self.wait: Optional[Any] = None
        self.started = False
        self.done = False
        self.result: Any = None
        self.error: Optional[BaseException] = None
        self.error_reported = False   # raised to a caller exactly once
        self.final_tokens: Optional[List[int]] = None

    def run(self, max_steps: int = 10_000, **decode_kw: Any) -> Any:
        """Drive the whole fleet until *this* exploration resolves."""
        self.driver.run(max_steps=max_steps, until=self, **decode_kw)
        if self.error is not None:
            self.error_reported = True
            raise self.error
        return self.result


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

class ExplorationDriver:
    """Multiplexes generator policies over one session."""

    def __init__(self, session: Any, *,
                 store: Optional[BranchStore] = None):
        if isinstance(session, BranchSession):
            if store is not None and session.store is not store:
                raise BranchError(
                    "pass the store to BranchSession, not the driver",
                    errno=Errno.EINVAL)
            self.session = session
        else:
            # migration path: wrap a bare Scheduler (or engine) in a
            # session; BranchSession validates the type
            self.session = BranchSession(session, store=store)
        self.sched = self.session.sched
        self.store = self.session.store
        self._live: List[Exploration] = []
        self.explorations: List[Exploration] = []
        self.steps = 0

    # -- launching ------------------------------------------------------
    def launch(self, gen: Generator, *, name: str = "") -> Exploration:
        """Register a policy generator; it starts on the next step."""
        exp = Exploration(self, gen, name or f"exploration-{len(self.explorations)}")
        self._live.append(exp)
        self.explorations.append(exp)
        return exp

    def explore(self, prompt: Sequence[int], max_new_tokens: int,
                policy: Any, *, name: str = "",
                **policy_kw: Any) -> Exploration:
        """One-liner: submit ``prompt`` and run ``policy`` on its root."""

        def wrapper() -> Generator:
            ctx = yield Submit(prompt, max_new_tokens)
            return (yield from policy(ctx, **policy_kw))

        return self.launch(wrapper(), name=name or getattr(
            policy, "__name__", "policy"))

    @property
    def live(self) -> List[Exploration]:
        """Unresolved explorations (read-only view for external loops)."""
        return list(self._live)

    def _bind_root(self, req_id: int,
                   seq: Optional[int] = None) -> BranchContext:
        """Wrap an externally submitted request in a root context
        (migration aid; new code opens through the session).  ``seq``
        is accepted for backward compatibility and must be the
        request's own root sequence — binding always resolves through
        the request id.
        """
        hd = self.session.adopt(req_id)
        if seq is not None and self.session.seq_of(hd) != seq:
            actual = self.session.seq_of(hd)
            # drop the just-adopted handle before raising: the request
            # itself stays with the scheduler, but the slot must not
            # leak (close() never resolves; see session.close)
            self.session.close(hd)
            raise BranchError(
                f"request {req_id} is rooted at seq {actual}, "
                f"not {seq}", errno=Errno.EINVAL)
        return BranchContext(self.session, hd)

    # -- stepping -------------------------------------------------------
    def _advance(self, exp: Exploration, value: Any = None,
                 error: Optional[BaseException] = None) -> None:
        """Run one exploration's host code until it blocks again."""
        while True:
            try:
                if error is not None:
                    err, error = error, None
                    item = exp.gen.throw(err)
                elif not exp.started:
                    exp.started = True
                    item = next(exp.gen)
                else:
                    item = exp.gen.send(value)
            except StopIteration as stop:
                self._finalize(exp, stop.value)
                return
            except BaseException as err:   # policy bug: fail + clean up
                self._fail(exp, err)
                return

            if isinstance(item, Submit):
                try:
                    exp.hd = self.session.open(
                        list(item.prompt), item.max_new_tokens,
                        flags=BR_HOLD)
                except AdmissionDenied as err:
                    # can NEVER fit: not backpressure — the policy decides
                    value, error = None, err
                    continue
                exp.req_id = self.session.req_id_of(exp.hd)
                wait = _WaitAdmitted(exp.hd)
                ok, value = wait.poll(self)   # may be admitted already
                if ok:
                    exp.root = value
                    continue
                exp.wait = wait
                return
            elif isinstance(item, Fork):
                try:
                    value = item.ctx.fork(item.n, item.flags)
                    continue
                except AdmissionDenied:
                    exp.wait = _WaitFork(item)    # backpressure: retry
                    return
                except BranchError as err:
                    # forking a resolved/evicted context is a policy-level
                    # condition: deliver it to the generator, not the run
                    value, error = None, err
                    continue
            elif isinstance(item, Decode):
                k = len(item.ctxs)
                g_row = (list(item.greedy) if isinstance(
                    item.greedy, (list, tuple)) else [item.greedy] * k)
                t_row = (list(item.temperature) if isinstance(
                    item.temperature, (list, tuple))
                    else [item.temperature] * k)
                if len(g_row) != k or len(t_row) != k:
                    value, error = None, ValueError(
                        "Decode sampling rows must match its contexts")
                    continue
                try:
                    wait = self._start_decode(item, g_row, t_row)
                except AdmissionDenied:
                    # a demoted context cannot re-seat yet: retry with
                    # backpressure, like a fork under page pressure
                    exp.wait = _WaitDecode(item, g_row, t_row)
                    return
                if wait is None:
                    value = None   # every context already resolved
                    continue
                exp.wait = wait
                return
            elif isinstance(item, Tick):
                exp.wait = _WaitSteps(self.steps + item.steps)
                return
            else:
                value, error = None, TypeError(
                    f"policy yielded {item!r}; expected Submit/Fork/"
                    "Decode/Tick")

    def _finalize(self, exp: Exploration, result: Any) -> None:
        exp.result = result
        exp.done = True
        exp.wait = None
        self._live.remove(exp)
        if exp.hd is not None:
            # finish releases the subtree across every domain, reaps the
            # composite store branch, and closes all of its handles
            exp.final_tokens = self.session.finish(exp.hd)

    def _start_decode(self, item: Decode, g_row: List[Any],
                      t_row: List[Any]) -> Optional["_WaitTokens"]:
        """Unpark + tag every still-tracked context of a Decode.

        Returns the token wait, or ``None`` when every context resolved
        meanwhile.  Transactional against restore backpressure: if a
        demoted context's re-seat is denied (``AdmissionDenied`` out of
        ``session.resume``), everything already unparked is re-held and
        the denial re-raised so the caller can retry the whole Decode.
        """
        waiter = Waiter(self.session)
        active: List[BranchContext] = []
        try:
            for ctx, g, t in zip(item.ctxs, g_row, t_row):
                if not self.session.tracked(ctx.hd):
                    continue   # already resolved: nothing to decode
                target = self.session.produced(ctx.hd) + item.tokens
                self.session.resume(ctx.hd, greedy=g, temperature=t)
                waiter.add(ctx.hd, events=0, produced=target)
                active.append(ctx)
        except AdmissionDenied:
            for ctx in active:
                self.session.pause(ctx.hd)
            raise
        if not active:
            return None
        return _WaitTokens(waiter, active)

    def _fail(self, exp: Exploration, err: BaseException) -> None:
        exp.error = err
        exp.done = True
        exp.wait = None
        self._live.remove(exp)
        if exp.hd is not None:
            exp.final_tokens = self.session.finish(exp.hd)

    def step(self, **decode_kw: Any) -> Dict[str, Any]:
        """One round: resume ready explorations, then one session step."""
        self.session.admit()   # admit first so _WaitAdmitted binds + holds
        resumed = 0
        for exp in list(self._live):
            if exp.done:
                continue
            if exp.wait is None:
                self._advance(exp)
                resumed += 1
            else:
                try:
                    ok, value = exp.wait.poll(self)
                except Exception as err:
                    # a wait that can never be satisfied (its context was
                    # evicted/resolved underneath it) fails into the
                    # policy, not the driver loop
                    exp.wait = None
                    self._advance(exp, error=err)
                    resumed += 1
                    continue
                if ok:
                    exp.wait = None
                    if isinstance(value, BranchContext) and exp.root is None:
                        exp.root = value
                    self._advance(exp, value)
                    resumed += 1
        st = self.session.step(**decode_kw)
        st["resumed"] = resumed
        st["live_explorations"] = len(self._live)
        self.steps += 1
        return st

    def run(self, max_steps: int = 10_000, *,
            until: Optional[Exploration] = None,
            raise_errors: bool = True, **decode_kw: Any) -> List[Exploration]:
        """Step until every exploration (or ``until``) resolves."""
        decode_kw = dict(decode_kw)
        key = decode_kw.pop("key", None)
        if key is not None:
            # one key must not reach every step (identical sampling
            # noise each round): it reseeds the scheduler's stream
            self.sched.seed_sampling(key)
        stalled = 0
        for _ in range(max_steps):
            if not self._live or (until is not None and until.done):
                break
            st = self.step(**decode_kw)
            if st["resumed"] or st["decoded"] or st["admitted"] \
                    or st["retired"]:
                stalled = 0
                continue
            if any(isinstance(e.wait, _WaitSteps) for e in self._live):
                continue   # a Tick always resolves: steps advance
            # A fully idle round is deterministic: nothing will change on
            # its own.  Kick ONE fork-blocked policy with a permanent
            # -EAGAIN (it may shrink its fan-out or degrade to unforked
            # decoding, freeing pages for the rest); if nobody is
            # fork-blocked, the stall is unrecoverable.
            stalled += 1
            if self._kick_stalled():
                stalled = 0
            elif stalled > 1:
                blocked = [e.name for e in self._live]
                raise BranchError(
                    f"exploration driver stalled; blocked: {blocked}",
                    errno=Errno.EBUSY)
        else:
            if self._live and (until is None or not until.done):
                raise BranchError(
                    f"driver exceeded max_steps={max_steps} with "
                    f"{len(self._live)} explorations live",
                    errno=Errno.EAGAIN)
        if raise_errors:
            if until is not None:
                # the caller awaits ONE exploration: only its error is
                # theirs; other failures surface on their own run calls
                if until.error is not None and not until.error_reported:
                    until.error_reported = True
                    raise until.error
            else:
                for exp in self.explorations:
                    if exp.error is not None and not exp.error_reported:
                        exp.error_reported = True
                        raise exp.error
        return self.explorations

    def kick_stalled(self) -> int:
        """Throw -EAGAIN into ONE fork-blocked policy on a proven stall.

        Public for external continuous loops (the serving front door's
        engine multiplexer owns its own stepping loop instead of
        :meth:`run`, but needs the same escape hatch when a round makes
        no progress and a fork-blocked policy is the reason): the kicked
        policy may shrink its fan-out or degrade to unforked decoding,
        freeing pages for everyone else.  Returns 1 if a policy was
        kicked, else 0.
        """
        return self._kick_stalled()

    def _kick_stalled(self) -> int:
        for exp in list(self._live):
            if isinstance(exp.wait, _WaitFork):
                wait, exp.wait = exp.wait, None
                self._advance(exp, error=AdmissionDenied(
                    f"fork({wait.item.ctx.seq}, n={wait.item.n}) cannot be "
                    f"admitted after {wait.attempts} retries and no other "
                    "exploration can free pages (-EAGAIN, permanent)"))
                return 1
        return 0


__all__ = ["Decode", "Exploration", "ExplorationDriver", "Fork",
           "Submit", "Tick"]
