"""BranchContext — one node of a scheduled exploration tree.

The paper ships two artifacts: the branch *primitive* (kernel, domains,
scheduler — PR 1) and **BranchContext**, the integration library that
turns the primitive into ready-to-use exploration patterns.  Since the
``repro.api`` redesign this class is pure **sugar over session
handles**: every lifecycle verb delegates to one
:class:`~repro.api.BranchSession` method, so a context and a raw handle
are always interchangeable (``ctx.hd`` is the handle; wrap any handle
in a context to get the object-style API back).

What the sugar adds over raw ``branch()`` calls:

* **Tree bookkeeping** — parent/children links, depth, per-node scores,
  ``commit_chain`` promoting a deep winner level by level.
* **Exploration defaults** — ``fork`` passes ``BR_HOLD`` (the driver
  paces decoding), ``BR_NESTED`` (policies nest freely) and
  ``BR_NONBLOCK`` (the driver owns the retry loop) so policies never
  spell flag words.
* **Context-manager semantics** — leaving a ``with`` block without
  commit aborts; no side effects escape an unresolved branch.

Contexts do not pace their own decoding: the
:class:`~repro.explore_ctx.driver.ExplorationDriver` multiplexes decode
work from many live contexts into the scheduler's continuous-batching
loop through the session's :class:`~repro.api.events.Waiter`.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

from repro.api.flags import BR_HOLD, BR_NESTED, BR_NONBLOCK
from repro.api.session import BranchSession
from repro.core.branch import BranchContext as StateContext
from repro.core.errors import BadHandleError, BranchStateError
from repro.core.lifecycle import BranchStatus


@dataclass
class PolicyResult:
    """What an exploration policy returns through its driver."""

    req_id: Optional[int]
    tokens: List[int]            # the exploration root's full token list
    generated: List[int]         # tokens beyond the root's starting point
    score: Optional[float] = None
    committed: bool = True       # False if the policy kept the origin
    stats: Dict[str, Any] = field(default_factory=dict)


def policy_result(root: "BranchContext", *, score: Optional[float] = None,
                  committed: bool = True, **stats: Any) -> PolicyResult:
    """Assemble a :class:`PolicyResult` from the exploration root."""
    toks = root.tokens()
    return PolicyResult(req_id=root.req_id, tokens=toks,
                        generated=toks[root.fork_len:], score=score,
                        committed=committed, stats=stats)


class BranchContext:
    """A scheduled branch following fork/explore/commit-or-abort."""

    def __init__(self, session: BranchSession, hd: int, *,
                 parent: Optional["BranchContext"] = None):
        self.session = session
        self.hd = hd
        self.parent = parent
        self.seq = session.seq_of(hd)
        self.req_id = session.req_id_of(hd)
        self.children: List["BranchContext"] = []
        self.depth = 0 if parent is None else parent.depth + 1
        self.score: Optional[float] = None
        self._resolved = False
        # token count at creation: generated() is everything after this
        self.fork_len = len(self.tokens())

    # -- liveness -------------------------------------------------------
    @property
    def alive(self) -> bool:
        try:
            return self.session.alive(self.hd)
        except BadHandleError:
            return False             # handle closed: the branch is gone

    @property
    def status(self) -> Optional[BranchStatus]:
        try:
            return self.session.status(self.hd)   # None once reaped
        except BadHandleError:
            return None

    @property
    def resolved(self) -> bool:
        return self._resolved

    @property
    def state(self) -> Optional[StateContext]:
        """The composite store-domain context (None in KV-only mode)."""
        try:
            return self.session.state_of(self.hd)
        except BadHandleError:
            return None

    # -- content --------------------------------------------------------
    def tokens(self) -> List[int]:
        """This branch's full token list (prompt + committed + own)."""
        try:
            return self.session.tokens(self.hd)
        except BadHandleError:
            raise BranchStateError(
                f"branch context hd={self.hd:#x} was closed "
                "(its request finished)") from None

    def generated(self) -> List[int]:
        """Tokens this context added since it was forked."""
        return self.tokens()[self.fork_len:]

    # -- lifecycle ------------------------------------------------------
    def fork(self, n: int = 1, flags: int = 0) -> List["BranchContext"]:
        """Fork ``n`` admission-checked children (one exclusive group).

        One vectorized ``branch()`` call: all ``n`` siblings admitted in
        one ledger transaction, tail CoW fused into one dispatch, every
        domain forked atomically.  Children are parked (``BR_HOLD``) —
        the driver decides when they decode — and the call never blocks
        (``BR_NONBLOCK``): page pressure raises ``AdmissionDenied`` for
        the driver's backpressure loop to absorb.
        """
        hds = self.session.branch(
            self.hd, flags | BR_HOLD | BR_NESTED | BR_NONBLOCK, n)
        kids = [BranchContext(self.session, hd, parent=self) for hd in hds]
        self.children.extend(kids)
        return kids

    def commit(self) -> Optional["BranchContext"]:
        """First-commit-wins into the parent; siblings invalidated."""
        if self._resolved:
            raise BranchStateError("branch context already resolved")
        self.session.commit(self.hd)
        self._resolved = True
        return self.parent

    def commit_chain(self, until: Optional["BranchContext"] = None
                     ) -> "BranchContext":
        """Commit this branch level by level up to ``until`` (default:
        the exploration root).

        Each step's winner invalidates its siblings' whole subtrees —
        the nested-search ending where one leaf's lineage becomes the
        request's committed content.  Returns the context committed into.
        """
        cur = self
        while cur is not until and cur.parent is not None:
            cur.commit()
            cur = cur.parent
        return cur

    def abort(self) -> None:
        """Discard this branch (and, recursively, its live subtree)."""
        if self._resolved:
            return
        try:
            self.session.abort(self.hd)
        except BadHandleError:
            pass                     # closed: nothing left to discard
        self._resolved = True

    def prune_children(self) -> int:
        """Abort every live child subtree (pre-commit cleanup)."""
        n = 0
        for k in self.children:
            if not k._resolved and k.alive:
                k.abort()
                n += 1
        return n

    def truncate(self, n_generated: int) -> None:
        """Keep only the first ``n_generated`` tokens generated here.

        The speculative-decode primitive: a draft keeps its verified
        prefix and commits that.  Requires the context to have been
        forked ``BR_SPECULATIVE`` (``-EPERM`` otherwise).
        """
        self.session.truncate(self.hd, n_generated)

    def verify(self, drafts: List[List[int]]) -> List[List[int]]:
        """Fused speculative verify against this branch (one dispatch).

        Each draft is k proposed next tokens; each returned row is the
        target's greedy continuation at every draft position, so
        ``lcp_len(draft, row)`` is the draft's verified-prefix length.
        Pure scoring — no decode, no new branches, this context's KV is
        read-only.  The usual caller holds the frozen origin while the
        drafts are its live children.
        """
        return self.session.verify(self.hd, drafts)

    # -- context manager ------------------------------------------------
    def __enter__(self) -> "BranchContext":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        if not self._resolved and self.alive and self.parent is not None:
            self.abort()
        return False

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        st = self.status
        return (f"BranchContext(hd={self.hd:#x}, seq={self.seq}, "
                f"depth={self.depth}, "
                f"status={st.value if st else 'reaped'})")


__all__ = ["BranchContext", "PolicyResult", "StateContext",
           "policy_result"]
