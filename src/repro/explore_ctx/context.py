"""BranchContext — one node of a scheduled exploration tree.

The paper ships two artifacts: the branch *primitive* (kernel, domains,
scheduler — PR 1) and **BranchContext**, the integration library that
turns the primitive into ready-to-use exploration patterns.  This module
is the library's spine: a context-manager handle over one scheduler-
tracked sequence that exposes the structured fork/explore/commit-or-
abort lifecycle to policies.

A context differs from raw engine/scheduler calls in three ways:

* **Admission-checked by construction** — ``fork`` goes through
  ``Scheduler.fork`` (or, for composite contexts, a
  ``BranchRuntime`` whose KV fork is the scheduler's), so every branch
  a policy creates is backed by a worst-case page reservation and
  ``AdmissionDenied`` is backpressure, never mid-decode ``-ENOSPC``.
* **Nestable** — a child context forks grandchildren; aborting an
  ancestor invalidates the whole subtree across every domain
  (the kernel's recursive sibling invalidation, reached through one
  object).  ``commit_chain`` promotes a deep winner level by level to
  the exploration root.
* **Composite** — a context may carry a :class:`~repro.core.branch.
  BranchContext` (store) view alongside its KV sequence; forks and
  commits then resolve both domains atomically through
  :class:`~repro.core.runtime_api.BranchRuntime`, so a policy can
  branch filesystem-like agent state together with generation state.

Contexts do not pace their own decoding: the
:class:`~repro.explore_ctx.driver.ExplorationDriver` multiplexes decode
work from many live contexts into the scheduler's continuous-batching
loop.  Within a ``with`` block, leaving without commit aborts (no side
effects escape an unresolved branch).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

from repro.core.branch import BranchContext as StateContext
from repro.core.errors import BranchStateError
from repro.core.lifecycle import BranchStatus
from repro.core.runtime_api import BR_KV, BR_STATE, BranchHandle, BranchRuntime
from repro.runtime.scheduler import AdmissionDenied


@dataclass
class PolicyResult:
    """What an exploration policy returns through its driver."""

    req_id: Optional[int]
    tokens: List[int]            # the exploration root's full token list
    generated: List[int]         # tokens beyond the root's starting point
    score: Optional[float] = None
    committed: bool = True       # False if the policy kept the origin
    stats: Dict[str, Any] = field(default_factory=dict)


def policy_result(root: "BranchContext", *, score: Optional[float] = None,
                  committed: bool = True, **stats: Any) -> PolicyResult:
    """Assemble a :class:`PolicyResult` from the exploration root."""
    toks = root.tokens()
    return PolicyResult(req_id=root.req_id, tokens=toks,
                        generated=toks[root.fork_len:], score=score,
                        committed=committed, stats=stats)


class BranchContext:
    """A scheduled branch following fork/explore/commit-or-abort."""

    def __init__(self, sched: Any, seq: int, *,
                 parent: Optional["BranchContext"] = None,
                 req_id: Optional[int] = None,
                 runtime: Optional[BranchRuntime] = None,
                 state: Optional[StateContext] = None,
                 handle: Optional[BranchHandle] = None):
        self.sched = sched
        self.engine = sched.engine
        self.seq = seq
        self.parent = parent
        self.req_id = req_id if req_id is not None else (
            parent.req_id if parent is not None else None)
        self.runtime = runtime if runtime is not None else (
            parent.runtime if parent is not None else None)
        self.state = state
        self.handle = handle
        self.children: List["BranchContext"] = []
        self.depth = 0 if parent is None else parent.depth + 1
        self.score: Optional[float] = None
        self._resolved = False
        # token count at creation: generated() is everything after this
        self.fork_len = len(self.engine.tokens(seq))

    # -- liveness -------------------------------------------------------
    @property
    def alive(self) -> bool:
        return self.seq in self.engine.kv.tree and \
            self.engine.kv.is_live(self.seq)

    @property
    def status(self) -> Optional[BranchStatus]:
        if self.seq not in self.engine.kv.tree:
            return None          # reaped
        return self.engine.kv.status(self.seq)

    @property
    def resolved(self) -> bool:
        return self._resolved

    # -- content --------------------------------------------------------
    def tokens(self) -> List[int]:
        """This branch's full token list (prompt + committed + own)."""
        if self.seq in self.engine.token_domain:
            return self.engine.tokens(self.seq)
        if self._resolved and self.parent is not None:
            return self.parent.tokens()   # committed: content lives there
        if self.parent is None and self.req_id is not None:
            # the root hit its decode budget and retired naturally: the
            # scheduler captured the result before releasing the seq
            res = self.sched.peek_result(self.req_id)
            if res is not None:
                return res
        raise BranchStateError(
            f"branch context seq={self.seq} has no token tail "
            "(invalidated and reaped)")

    def generated(self) -> List[int]:
        """Tokens this context added since it was forked."""
        return self.tokens()[self.fork_len:]

    # -- lifecycle ------------------------------------------------------
    def fork(self, n: int = 1) -> List["BranchContext"]:
        """Fork ``n`` admission-checked children (one exclusive group).

        Composite contexts fork the store domain in the same atomic
        create: an ``AdmissionDenied`` from the KV side unwinds the
        store forks, so no domain is half-created.  Children are parked
        (held) — the driver decides when they decode.
        """
        if self.runtime is not None and self.state is not None:
            # check the cheap reservation ledger BEFORE forking the store
            # domain: a backpressure retry must not churn store nodes
            if not self.sched.can_fork(self.seq, n):
                raise AdmissionDenied(
                    f"fork({self.seq}, n={n}) exceeds the page budget "
                    "(-EAGAIN)")
            handles = self.runtime.create(
                self.state, n, flags=BR_STATE | BR_KV, kv_seqs=[self.seq])
            kids = [
                BranchContext(self.sched, h.kv_seqs[self.seq], parent=self,
                              state=h.state, handle=h)
                for h in handles
            ]
        else:
            kids = [BranchContext(self.sched, s, parent=self)
                    for s in self.sched.fork(self.seq, n)]
        for k in kids:
            self.sched.hold(k.seq)
        self.children.extend(kids)
        return kids

    def commit(self) -> Optional["BranchContext"]:
        """First-commit-wins into the parent; siblings invalidated."""
        if self._resolved:
            raise BranchStateError("branch context already resolved")
        if self.handle is not None:
            self.runtime.commit(self.handle)
        else:
            self.engine.commit(self.seq)
        self._resolved = True
        return self.parent

    def commit_chain(self, until: Optional["BranchContext"] = None
                     ) -> "BranchContext":
        """Commit this branch level by level up to ``until`` (default:
        the exploration root).

        Each step's winner invalidates its siblings' whole subtrees —
        the nested-search ending where one leaf's lineage becomes the
        request's committed content.  Returns the context committed into.
        """
        cur = self
        while cur is not until and cur.parent is not None:
            cur.commit()
            cur = cur.parent
        return cur

    def abort(self) -> None:
        """Discard this branch (and, recursively, its live subtree)."""
        if self._resolved:
            return
        if self.handle is not None:
            self.runtime.abort(self.handle)
        elif self.seq in self.engine.kv.tree and \
                self.engine.kv.is_live(self.seq):
            self.engine.abort(self.seq)
        self._resolved = True

    def prune_children(self) -> int:
        """Abort every live child subtree (pre-commit cleanup)."""
        n = 0
        for k in self.children:
            if not k._resolved and k.alive:
                k.abort()
                n += 1
        return n

    def truncate(self, n_generated: int) -> None:
        """Keep only the first ``n_generated`` tokens generated here.

        The speculative-decode primitive: a draft keeps its verified
        prefix and commits that.
        """
        self.engine.truncate(self.seq, self.fork_len + n_generated)

    # -- context manager ------------------------------------------------
    def __enter__(self) -> "BranchContext":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        if not self._resolved and self.alive and self.parent is not None:
            self.abort()
        return False

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        st = self.status
        return (f"BranchContext(seq={self.seq}, depth={self.depth}, "
                f"status={st.value if st else 'reaped'})")


__all__ = ["BranchContext", "PolicyResult", "StateContext",
           "policy_result"]
