"""Speculative exploration — drafts verified against a target.

Two faces of the same fork/explore/commit pattern:

* :func:`speculative_decode` — the serving policy.  N sampled **draft**
  branches decode ``k`` tokens each; then ONE fused ``verify`` dispatch
  against the frozen origin (``ServeEngine.spec_verify``) teacher-forces
  every draft row through the target in a single pass, yielding the
  target's greedy token at every draft position — what previously took
  a dedicated verifier branch decoding ``k`` sequential steps.  The
  winning draft is truncated to its verified prefix and committed (KV
  pages + token tail shrink together); when nothing verified, a held
  fallback branch takes one true greedy step and commits, so the policy
  always makes progress.  In a deployment the drafts come from a
  cheaper model; here both share the engine, so the policy demonstrates
  the lifecycle + the one-dispatch verify, not an end-to-end speedup.
* :class:`SpeculativeTrainer` — the training port
  (``examples/speculative_train.py``).  Every step forks K candidate
  update branches *inside one jitted program* (stacked leading axis —
  there is no process to signal on a TPU), runs them in parallel, and
  first-commit-wins selects the update with the best validation loss.
"""

from __future__ import annotations

from typing import Any, Dict, Generator, Tuple

import jax
import jax.numpy as jnp

from repro.api.flags import BR_SPECULATIVE
from repro.core.errors import BranchError
from repro.core.explore import explore
from repro.explore_ctx.context import BranchContext, policy_result
from repro.explore_ctx.driver import Decode, Fork
from repro.explore_ctx.scoring import lcp_len


def speculative_decode(ctx: BranchContext, *, n_drafts: int = 3,
                       draft_tokens: int = 8,
                       temperature: float = 1.5) -> Generator:
    """Draft / fused-verify / commit-the-longest-verified-prefix.

    The fork declares its children ``BR_SPECULATIVE`` — the flag that
    licenses ``truncate`` (rewriting a draft down to its verified
    prefix); an undeclared branch attempting the same gets ``-EPERM``.

    The verify phase is ONE device dispatch: ``ctx.verify`` scores all
    draft rows against the frozen origin in a single fused pass
    (``ServeEngine.spec_verify``), instead of a verifier branch decoding
    ``draft_tokens`` sequential greedy steps.  Child 0 of the fork group
    is a parked **fallback** branch that only decodes (one true greedy
    step, then commits) when every draft diverges at its first token.
    """
    try:
        kids = yield Fork(ctx, n_drafts + 1, flags=BR_SPECULATIVE)
    except BranchError:   # includes AdmissionDenied
        # permanent page pressure (or a root resolved underneath us):
        # plain greedy decode, no speculation
        yield Decode([ctx], draft_tokens, greedy=True)
        return policy_result(ctx, committed=False,
                             policy="speculative_decode", degraded=True,
                             drafts=0, accepted=0)
    fallback_br, drafts = kids[0], list(kids[1:])
    # ONE wait, one continuous batch of sampled draft lanes — no greedy
    # verifier lane decodes alongside them anymore
    yield Decode(drafts, draft_tokens, greedy=False,
                 temperature=temperature)
    rows = [d.generated() for d in drafts]
    # a draft may stop short of draft_tokens (decode budget); the fused
    # verify wants equal-length rows, so score the common length
    t = min(len(r) for r in rows)
    if t > 0:
        target_rows = ctx.verify([r[:t] for r in rows])   # ONE dispatch
        verified = [lcp_len(r[:t], tr) for r, tr in zip(rows, target_rows)]
    else:
        verified = [0] * len(drafts)
    best = max(range(len(drafts)), key=lambda i: verified[i])
    accepted = verified[best]
    # acceptance telemetry on the engine's obs hub: proposed counts every
    # draft position scored by the fused verify, accepted only the
    # winning draft's verified prefix (a fallback round is an honest 0)
    m = ctx.session.obs.metrics
    prop = m.counter("spec.tokens_proposed")
    acc = m.counter("spec.tokens_accepted")
    m.counter("spec.rounds").inc()
    prop.inc(t * len(drafts))
    acc.inc(accepted)
    m.gauge("spec.acceptance_rate").set(
        round(acc.value / max(prop.value, 1), 4))
    fallback = accepted == 0
    if fallback:
        # every draft diverged at its first token: the parked fallback
        # branch takes one true greedy step so the commit makes progress
        yield Decode([fallback_br], 1, greedy=True)
        winner = fallback_br
    else:
        winner = drafts[best]
        if accepted < len(winner.generated()):
            winner.truncate(accepted)    # keep only the verified prefix
    winner.commit()
    # 'accepted' counts only draft tokens that verified — a fallback
    # commit is an honest 0% acceptance, not a perfect run
    return policy_result(
        ctx, score=float(accepted),
        policy="speculative_decode", drafts=n_drafts,
        draft_tokens=draft_tokens, accepted=accepted, fallback=fallback,
        verified_per_draft=verified, verify_dispatches=1 if t else 0,
        acceptance_rate=accepted / max(draft_tokens, 1))


class SpeculativeTrainer:
    """Fork-K-updates/commit-best training, packaged.

    ``step`` runs one fork/explore/commit round fully inside jit: each
    branch applies the gradient scaled by an independently sampled
    learning-rate multiplier, success is a finite validation loss, and
    the branch with the earliest commit-time (here: lowest val loss)
    wins.  If every branch diverges the frozen origin resumes unchanged
    — the paper's "if all branches abort, the parent resumes".
    """

    def __init__(self, model: Any, opt: Any, *, n_branches: int = 4,
                 lr_scale_base: float = 0.25, lr_scale_steps: int = 4):
        from repro.optim import apply_updates

        self.model = model
        self.opt = opt
        self.n_branches = n_branches

        def one_branch(state, key, batch, val_batch):
            lr_scale = lr_scale_base * (
                2.0 ** jax.random.randint(key, (), 0, lr_scale_steps)
                .astype(jnp.float32))

            def loss_fn(p):
                return model.loss(p, batch)[0]

            grads = jax.grad(loss_fn)(state["params"])
            grads = jax.tree_util.tree_map(lambda g: g * lr_scale, grads)
            updates, new_opt = opt.update(grads, state["opt"],
                                          state["params"])
            new_params = apply_updates(state["params"], updates)
            val = model.loss(new_params, val_batch)[0]
            return ({"params": new_params, "opt": new_opt},
                    jnp.isfinite(val), val)

        @jax.jit
        def spec_step(state, key, batch, val_batch):
            return explore(
                lambda s, k: one_branch(s, k, batch, val_batch),
                state, n_branches, key, commit_time_fn=lambda a: a)

        self._spec_step = spec_step

    def init(self, key: jax.Array) -> Dict[str, Any]:
        params = self.model.init(key)
        return {"params": params, "opt": self.opt.init(params)}

    def step(self, state: Dict[str, Any], key: jax.Array, batch: Any,
             val_batch: Any) -> Tuple[Dict[str, Any], Dict[str, Any]]:
        res = self._spec_step(state, key, batch, val_batch)
        info = {"winner": int(res.winner),
                "committed": bool(res.committed),
                "val_losses": [float(v) for v in res.aux]}
        return res.state, info


__all__ = ["SpeculativeTrainer", "speculative_decode"]
