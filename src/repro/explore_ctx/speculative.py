"""Speculative exploration — drafts verified against a target.

Two faces of the same fork/explore/commit pattern:

* :func:`speculative_decode` — the serving policy.  One fork group holds
  a greedy **verifier** branch (the target's own continuation) and N
  sampled **draft** branches.  After decoding, each draft is verified by
  longest-common-prefix against the verifier; the winning draft is
  truncated to its verified prefix and committed (KV pages + token tail
  shrink together), or the verifier commits when nothing verified.  In a
  deployment the drafts come from a cheaper model and the verifier pass
  is one batched forward; here both share the engine, so the policy
  demonstrates lifecycle + truncation semantics, not a speedup.
* :class:`SpeculativeTrainer` — the training port
  (``examples/speculative_train.py``).  Every step forks K candidate
  update branches *inside one jitted program* (stacked leading axis —
  there is no process to signal on a TPU), runs them in parallel, and
  first-commit-wins selects the update with the best validation loss.
"""

from __future__ import annotations

from typing import Any, Dict, Generator, Tuple

import jax
import jax.numpy as jnp

from repro.api.flags import BR_SPECULATIVE
from repro.core.errors import BranchError
from repro.core.explore import explore
from repro.explore_ctx.context import BranchContext, policy_result
from repro.explore_ctx.driver import Decode, Fork
from repro.explore_ctx.scoring import lcp_len


def speculative_decode(ctx: BranchContext, *, n_drafts: int = 3,
                       draft_tokens: int = 8,
                       temperature: float = 1.5) -> Generator:
    """Draft/verify/commit-the-longest-verified-prefix, as a policy.

    The fork declares its children ``BR_SPECULATIVE`` — the flag that
    licenses ``truncate`` (rewriting a draft down to its verified
    prefix); an undeclared branch attempting the same gets ``-EPERM``.
    """
    try:
        kids = yield Fork(ctx, n_drafts + 1, flags=BR_SPECULATIVE)
    except BranchError:   # includes AdmissionDenied
        # permanent page pressure (or a root resolved underneath us):
        # plain greedy decode, no speculation
        yield Decode([ctx], draft_tokens, greedy=True)
        return policy_result(ctx, committed=False,
                             policy="speculative_decode", degraded=True,
                             drafts=0, accepted=0)
    verifier, drafts = kids[0], list(kids[1:])
    # ONE wait, one continuous batch: the greedy verifier lane decodes
    # alongside the sampled drafts (per-sequence sampling rows)
    yield Decode(kids, draft_tokens,
                 greedy=[True] + [False] * len(drafts),
                 temperature=[1.0] + [temperature] * len(drafts))
    target = verifier.generated()
    verified = [lcp_len(d.generated(), target) for d in drafts]
    best = max(range(len(drafts)), key=lambda i: verified[i])
    accepted = verified[best]
    fallback = accepted == 0
    if fallback:
        winner = verifier                # every draft diverged at once:
    else:                                # the target's own tokens commit
        winner = drafts[best]
        if accepted < len(winner.generated()):
            winner.truncate(accepted)    # keep only the verified prefix
    winner.commit()
    # 'accepted' counts only draft tokens that verified — a verifier
    # fallback is an honest 0% acceptance, not a perfect run
    return policy_result(
        ctx, score=float(accepted),
        policy="speculative_decode", drafts=n_drafts,
        draft_tokens=draft_tokens, accepted=accepted, fallback=fallback,
        verified_per_draft=verified,
        acceptance_rate=accepted / max(draft_tokens, 1))


class SpeculativeTrainer:
    """Fork-K-updates/commit-best training, packaged.

    ``step`` runs one fork/explore/commit round fully inside jit: each
    branch applies the gradient scaled by an independently sampled
    learning-rate multiplier, success is a finite validation loss, and
    the branch with the earliest commit-time (here: lowest val loss)
    wins.  If every branch diverges the frozen origin resumes unchanged
    — the paper's "if all branches abort, the parent resumes".
    """

    def __init__(self, model: Any, opt: Any, *, n_branches: int = 4,
                 lr_scale_base: float = 0.25, lr_scale_steps: int = 4):
        from repro.optim import apply_updates

        self.model = model
        self.opt = opt
        self.n_branches = n_branches

        def one_branch(state, key, batch, val_batch):
            lr_scale = lr_scale_base * (
                2.0 ** jax.random.randint(key, (), 0, lr_scale_steps)
                .astype(jnp.float32))

            def loss_fn(p):
                return model.loss(p, batch)[0]

            grads = jax.grad(loss_fn)(state["params"])
            grads = jax.tree_util.tree_map(lambda g: g * lr_scale, grads)
            updates, new_opt = opt.update(grads, state["opt"],
                                          state["params"])
            new_params = apply_updates(state["params"], updates)
            val = model.loss(new_params, val_batch)[0]
            return ({"params": new_params, "opt": new_opt},
                    jnp.isfinite(val), val)

        @jax.jit
        def spec_step(state, key, batch, val_batch):
            return explore(
                lambda s, k: one_branch(s, k, batch, val_batch),
                state, n_branches, key, commit_time_fn=lambda a: a)

        self._spec_step = spec_step

    def init(self, key: jax.Array) -> Dict[str, Any]:
        params = self.model.init(key)
        return {"params": params, "opt": self.opt.init(params)}

    def step(self, state: Dict[str, Any], key: jax.Array, batch: Any,
             val_batch: Any) -> Tuple[Dict[str, Any], Dict[str, Any]]:
        res = self._spec_step(state, key, batch, val_batch)
        info = {"winner": int(res.winner),
                "committed": bool(res.committed),
                "val_losses": [float(v) for v in res.aux]}
        return res.state, info


__all__ = ["SpeculativeTrainer", "speculative_decode"]
