from repro.checkpoint.manager import CheckpointManager
from repro.checkpoint.serialization import (
    leaf_from_bytes,
    leaf_to_bytes,
    tree_paths,
)

__all__ = ["CheckpointManager", "leaf_from_bytes", "leaf_to_bytes",
           "tree_paths"]
