"""Pytree leaf <-> bytes with a tiny self-describing header.

Format: ``REPR0 | dtype-str-len | dtype-str | ndim | dims... | raw``;
optional zstd compression (magic flips to ``REPRZ``).  bfloat16 is
round-tripped through its uint16 bit pattern so numpy can carry it.
"""

from __future__ import annotations

import struct
from typing import Any, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

try:
    import zstandard as _zstd

    _ZC = _zstd.ZstdCompressor(level=3)
    _ZD = _zstd.ZstdDecompressor()
except Exception:  # pragma: no cover
    _zstd = None

_MAGIC_RAW = b"REPR0"
_MAGIC_ZST = b"REPRZ"


def _np_view(x: Any) -> Tuple[np.ndarray, str]:
    """numpy view + logical dtype string (handles bfloat16)."""
    arr = np.asarray(x)
    dt = str(arr.dtype)
    if dt == "bfloat16":
        arr = arr.view(np.uint16)
    return arr, dt


def leaf_to_bytes(x: Any, compress: bool = False) -> bytes:
    arr, dt = _np_view(x)
    raw = np.ascontiguousarray(arr).tobytes()
    if compress and _zstd is not None:
        raw = _ZC.compress(raw)
        magic = _MAGIC_ZST
    else:
        magic = _MAGIC_RAW
    dtb = dt.encode()
    head = magic + struct.pack("<H", len(dtb)) + dtb
    head += struct.pack("<H", arr.ndim)
    head += struct.pack(f"<{arr.ndim}q", *arr.shape)
    return head + raw


def leaf_from_bytes(data: bytes) -> np.ndarray:
    magic, off = data[:5], 5
    (dtl,) = struct.unpack_from("<H", data, off)
    off += 2
    dt = data[off:off + dtl].decode()
    off += dtl
    (ndim,) = struct.unpack_from("<H", data, off)
    off += 2
    shape = struct.unpack_from(f"<{ndim}q", data, off)
    off += 8 * ndim
    raw = data[off:]
    if magic == _MAGIC_ZST:
        if _zstd is None:  # pragma: no cover
            raise RuntimeError("zstd-compressed checkpoint, zstd missing")
        raw = _ZD.decompress(raw)
    elif magic != _MAGIC_RAW:
        raise ValueError("bad leaf header")
    if dt == "bfloat16":
        arr = np.frombuffer(raw, np.uint16).reshape(shape)
        return jnp.asarray(arr.view(jnp.bfloat16))
    return np.frombuffer(raw, dt).reshape(shape).copy()


def tree_paths(tree: Any) -> List[str]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [jax.tree_util.keystr(p) for p, _ in flat]
