"""Branch-aware delta checkpointing on BranchFS.

Every checkpoint is a BranchFS branch committed into ``base``:

* **delta economics** — leaves are content-addressed chunks, so a step-N
  checkpoint stores only leaves that changed since step N-1 (optimizer
  `step` scalar, updated weights...).  Unchanged leaves (frozen embeddings,
  data config) cost one manifest entry.  This is the paper's
  modification-proportional commit, measured in benchmarks/commit_abort.
* **fsync elision** — leaf writes go to an uncommitted branch (no fsync);
  the commit is the durability point, exactly BranchFS §6 semantics.
* **async** — ``save_async`` snapshots device arrays to host (blocking
  only for the device→host copy) and writes/commits on a background
  thread, overlapping serialization with the next train step.
* **mesh-free** — leaves are stored logically (full arrays), so restore
  can re-shard onto any mesh (elastic re-scale path, runtime/elastic.py).
* **speculative checkpoints** — an *uncommitted* branch per step enables
  cheap rollback: abort on NaN, commit on health check (runtime/fault.py).
"""

from __future__ import annotations

import json
import threading
from pathlib import Path
from typing import Any, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint.serialization import leaf_from_bytes, leaf_to_bytes
from repro.fs.branchfs import BASE, BranchFS


class CheckpointManager:
    def __init__(self, root: str | Path, compress: bool = False):
        self.fs = BranchFS(root)
        self.compress = compress
        self._worker: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------------
    def _write_tree(self, branch: str, step: int, tree: Any,
                    extra: Optional[Dict[str, Any]] = None) -> None:
        flat = jax.tree_util.tree_flatten_with_path(tree)[0]
        for path, leaf in flat:
            key = f"step{step:012d}/{jax.tree_util.keystr(path)}"
            self.fs.write(branch, key, leaf_to_bytes(leaf, self.compress))
        meta = {"step": step, "extra": extra or {}}
        self.fs.write(branch, f"step{step:012d}/__meta__",
                      json.dumps(meta).encode())
        self.fs.write(branch, "__latest__", str(step).encode())

    def _branch_name(self, step: int, tag: str) -> str:
        import uuid

        return f"ckpt-{step}-{tag}-{uuid.uuid4().hex[:8]}"

    def save(self, step: int, tree: Any,
             extra: Optional[Dict[str, Any]] = None) -> str:
        """Synchronous save: branch → write leaves → commit (durable)."""
        (branch,) = self.fs.create(name=self._branch_name(step, "s"))
        self._write_tree(branch, step, tree, extra)
        self.fs.commit(branch)
        return branch

    def save_async(self, step: int, tree: Any,
                   extra: Optional[Dict[str, Any]] = None) -> None:
        """Snapshot to host now; serialize + commit in the background."""
        self.wait()  # one in flight at a time; surfaces prior errors
        host_tree = jax.tree_util.tree_map(np.asarray, tree)

        def work():
            try:
                (branch,) = self.fs.create(name=self._branch_name(step,
                                                                  "a"))
                self._write_tree(branch, step, host_tree, extra)
                self.fs.commit(branch)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._worker = threading.Thread(target=work, daemon=True)
        self._worker.start()

    def wait(self) -> None:
        if self._worker is not None:
            self._worker.join()
            self._worker = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    # ------------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        self.wait()
        try:
            return int(self.fs.read(BASE, "__latest__").decode())
        except KeyError:
            return None

    def restore(self, like: Any, step: Optional[int] = None,
                branch: str = BASE) -> Any:
        """Rebuild a pytree shaped like ``like`` from a checkpoint."""
        self.wait()
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError("no checkpoint committed")
        flat = jax.tree_util.tree_flatten_with_path(like)
        leaves = []
        for path, _ in flat[0]:
            key = f"step{step:012d}/{jax.tree_util.keystr(path)}"
            leaves.append(leaf_from_bytes(self.fs.read(branch, key)))
        return jax.tree_util.tree_unflatten(flat[1], leaves)

    def restore_meta(self, step: Optional[int] = None,
                     branch: str = BASE) -> Dict[str, Any]:
        self.wait()
        if step is None:
            step = self.latest_step()
        raw = self.fs.read(branch, f"step{step:012d}/__meta__")
        return json.loads(raw.decode())

    def steps(self) -> List[int]:
        self.wait()
        out = set()
        for p in self.fs.listdir(BASE):
            if p.startswith("step") and p.endswith("/__meta__"):
                out.add(int(p[4:16]))
        return sorted(out)
