"""Quickstart: the paper's Listing 2 (fork / explore / commit) in branchx.

Three state domains, one abstraction:
  1. host pytree state (BranchStore)        — ≈ BranchFS
  2. on-disk workspace (BranchFS)           — ≈ BranchFS daemon
  3. in-program stacked state (explore())   — ≈ branch() + BR_MEMORY

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import tempfile

import jax
import jax.numpy as jnp

from repro.core import (
    BranchStore,
    StaleBranchError,
    explore,
    explore_threads,
)
from repro.fs import BranchFS


def demo_store():
    print("== 1. BranchStore: three candidate fixes, tests pick one ==")
    store = BranchStore({"main.py": "print('broken')", "README": "v1"})

    def make_fix(i):
        def fix(branch_id):
            store.write(branch_id, "main.py", f"print('fix {i}')")
            tests_pass = i == 1  # only fix 1 passes its tests
            return tests_pass

        return fix

    winner, statuses = explore_threads(
        store, BranchStore.ROOT, [make_fix(0), make_fix(1), make_fix(2)])
    print(f"   winner branch: {winner}, statuses: "
          f"{[s.value for s in statuses]}")
    print(f"   base now sees: {store.read(BranchStore.ROOT, 'main.py')}")


def demo_fs():
    print("== 2. BranchFS on disk: nested exploration ==")
    with tempfile.TemporaryDirectory() as td:
        fs = BranchFS(td)
        fs.write("base", "config.yaml", b"lr: 1e-4")
        (strategy,) = fs.create(name="strategy-a")
        v1, v2 = fs.create(parent=strategy, n=2)
        fs.write(v1, "config.yaml", b"lr: 3e-4")
        fs.write(v2, "config.yaml", b"lr: 1e-3")
        fs.commit(v2)               # sub-variant wins -> strategy-a
        try:
            fs.read(v1, "config.yaml")
        except StaleBranchError:
            print("   sibling v1 got -ESTALE (as the paper specifies)")
        fs.commit(strategy)         # strategy-a wins -> base
        print(f"   base config: {fs.read('base', 'config.yaml').decode()}")


def demo_device():
    print("== 3. Device-side explore(): 4 branches race inside one jit ==")
    origin = {"x": jnp.zeros((3,)), "loss": jnp.float32(1e9)}

    def step(state, key):
        cand = jax.random.normal(key, (3,))
        loss = jnp.sum(cand**2)
        return {"x": cand, "loss": loss}, loss < state["loss"], loss

    res = jax.jit(lambda o, k: explore(step, o, 4, k,
                                       commit_time_fn=lambda a: a))(
        origin, jax.random.PRNGKey(0))
    print(f"   committed branch {int(res.winner)} with loss "
          f"{float(res.state['loss']):.4f}")


if __name__ == "__main__":
    demo_store()
    demo_fs()
    demo_device()
    print("quickstart complete")
