"""Quickstart: the paper's Listing 2 (fork / explore / commit) in branchx.

Four faces of one abstraction:
  1. host pytree state (BranchStore)        — ≈ BranchFS
  2. on-disk workspace (BranchFS)           — ≈ BranchFS daemon
  3. in-program stacked state (explore())   — ≈ branch() + BR_MEMORY
  4. the branch() syscall surface itself    — repro.api.BranchSession
     (vectorized fork, flags word, errno discipline, epoll-style waits)

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import tempfile

import jax
import jax.numpy as jnp

from repro.core import (
    BranchStore,
    StaleBranchError,
    explore,
    explore_threads,
)
from repro.fs import BranchFS


def demo_store():
    print("== 1. BranchStore: three candidate fixes, tests pick one ==")
    store = BranchStore({"main.py": "print('broken')", "README": "v1"})

    def make_fix(i):
        def fix(branch_id):
            store.write(branch_id, "main.py", f"print('fix {i}')")
            tests_pass = i == 1  # only fix 1 passes its tests
            return tests_pass

        return fix

    winner, statuses = explore_threads(
        store, BranchStore.ROOT, [make_fix(0), make_fix(1), make_fix(2)])
    print(f"   winner branch: {winner}, statuses: "
          f"{[s.value for s in statuses]}")
    print(f"   base now sees: {store.read(BranchStore.ROOT, 'main.py')}")


def demo_fs():
    print("== 2. BranchFS on disk: nested exploration ==")
    with tempfile.TemporaryDirectory() as td:
        fs = BranchFS(td)
        fs.write("base", "config.yaml", b"lr: 1e-4")
        (strategy,) = fs.create(name="strategy-a")
        v1, v2 = fs.create(parent=strategy, n=2)
        fs.write(v1, "config.yaml", b"lr: 3e-4")
        fs.write(v2, "config.yaml", b"lr: 1e-3")
        fs.commit(v2)               # sub-variant wins -> strategy-a
        try:
            fs.read(v1, "config.yaml")
        except StaleBranchError:
            print("   sibling v1 got -ESTALE (as the paper specifies)")
        fs.commit(strategy)         # strategy-a wins -> base
        print(f"   base config: {fs.read('base', 'config.yaml').decode()}")


def demo_device():
    print("== 3. Device-side explore(): 4 branches race inside one jit ==")
    origin = {"x": jnp.zeros((3,)), "loss": jnp.float32(1e9)}

    def step(state, key):
        cand = jax.random.normal(key, (3,))
        loss = jnp.sum(cand**2)
        return {"x": cand, "loss": loss}, loss < state["loss"], loss

    res = jax.jit(lambda o, k: explore(step, o, 4, k,
                                       commit_time_fn=lambda a: a))(
        origin, jax.random.PRNGKey(0))
    print(f"   committed branch {int(res.winner)} with loss "
          f"{float(res.state['loss']):.4f}")


def demo_api():
    print("== 4. branch() over a serving engine: the repro.api surface ==")
    import dataclasses

    from repro.api import EV_FINISHED, BranchSession, Waiter
    from repro.configs import get_config
    from repro.models.model import Model
    from repro.runtime.serve_loop import ServeEngine

    cfg = dataclasses.replace(get_config("paper-agentic"), dtype="float32")
    model = Model(cfg, attn_chunk=8, remat=False)
    engine = ServeEngine(model, model.init(jax.random.PRNGKey(0)),
                         num_pages=64, page_size=4, max_pages_per_seq=16)
    session = BranchSession(engine, seed=0)

    root = session.open([7, 3, 9], max_new_tokens=10)
    kids = session.branch(root, n=3)   # one ledger txn, one fused CoW copy
    # epoll-style: wait until every sibling generated 4 tokens
    Waiter(session).add(kids[0], produced=4).add(kids[1], produced=4) \
                   .add(kids[2], produced=4).wait(require_all=True)
    best = max(kids, key=lambda h: sum(session.tokens(h)[3:]))
    session.commit(best)               # siblings -ESTALE, pages recycled
    losers = [h for h in kids if h != best]
    print(f"   poll ready-set after commit: "
          f"{ {h: session.stat(h)['events'] for h in losers} }")
    session.wait([root], events=EV_FINISHED)
    print(f"   committed continuation: {session.result(root)}")
    session.finish(root)
    pool = session.tree()["pool"]
    print(f"   pool drained: {pool['pages_free']}/{pool['pages_total']}")


if __name__ == "__main__":
    demo_store()
    demo_fs()
    demo_device()
    demo_api()
    print("quickstart complete")
