"""End-to-end training driver: ~100M-param decoder trained on the
synthetic pipeline with the full production stack — fault-tolerant
branch-context stepping, async delta checkpoints, restart, metrics.

Default config is a real ~100M model (qwen2 family: 12L, d=768, 12H,
kv=4, ff=2048, 32k vocab) for a few hundred steps.  ``--smoke`` shrinks
everything for CI (used by tests/test_examples.py).

Run:  PYTHONPATH=src python examples/train_100m.py [--steps 300]
      PYTHONPATH=src python examples/train_100m.py --smoke
"""

import argparse
import dataclasses
import tempfile

import jax

from repro.checkpoint import CheckpointManager
from repro.configs.base import ArchConfig
from repro.data import SyntheticLMPipeline
from repro.models.model import Model
from repro.optim import adamw, cosine_warmup
from repro.runtime.fault import FaultTolerantTrainer
from repro.runtime.train_loop import build_train_step, init_train_state


def config_100m() -> ArchConfig:
    return ArchConfig(
        name="train-100m", family="dense", num_layers=12, d_model=768,
        num_heads=12, num_kv_heads=4, d_ff=2048, vocab_size=32_000,
        mlp_activation="swiglu", dtype="float32",
    )


def config_smoke() -> ArchConfig:
    return dataclasses.replace(
        config_100m(), num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=2, d_ff=128, vocab_size=512)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args(argv)

    cfg = config_smoke() if args.smoke else config_100m()
    if args.smoke:
        args.steps, args.batch, args.seq = 20, 2, 32
    n_params = cfg.param_count()
    print(f"arch={cfg.name} params={n_params / 1e6:.1f}M "
          f"steps={args.steps} batch={args.batch} seq={args.seq}")

    model = Model(cfg, attn_chunk=min(256, args.seq),
                  loss_chunk=min(128, args.seq), remat=not args.smoke)
    opt = adamw(cosine_warmup(3e-4, args.steps // 10 + 1, args.steps))
    step = jax.jit(build_train_step(model, opt), donate_argnums=(0,))
    state = init_train_state(model, opt, jax.random.PRNGKey(0))
    data = SyntheticLMPipeline(cfg, batch=args.batch, seq=args.seq,
                               seed=17)

    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="branchx-100m-")
    trainer = FaultTolerantTrainer(
        step_fn=step, state=state, data=data,
        ckpt=CheckpointManager(ckpt_dir), ckpt_every=max(args.steps // 4,
                                                         5))
    log_every = max(args.steps // 20, 1)
    for start in range(0, args.steps, log_every):
        n = min(log_every, args.steps - start)
        trainer.run(n)
        m = trainer.metrics_log[-1]
        print(f"step {trainer.steps_done:4d} loss {m['loss']:.4f} "
              f"gnorm {m['grad_norm']:.3f}")
    first, last = trainer.metrics_log[0], trainer.metrics_log[-1]
    print(f"loss {first['loss']:.4f} -> {last['loss']:.4f} "
          f"({trainer.rollbacks} rollbacks, checkpoints in {ckpt_dir})")
    assert last["loss"] < first["loss"], "training did not improve"


if __name__ == "__main__":
    main()
