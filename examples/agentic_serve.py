"""Agentic exploration over generations — the paper's serving workload.

A Tree-of-Thoughts style search: fork N continuation branches from a
shared prompt (CoW KV pages), decode each, score them, commit the best
(first-commit-wins invalidates + recycles the siblings), then explore
nested sub-branches from the winner.

Run:  PYTHONPATH=src python examples/agentic_serve.py
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.model import Model
from repro.runtime.serve_loop import ServeEngine


def branch_score(engine: ServeEngine, seq: int, prompt_len: int) -> float:
    """Score a branch: mean of its generated token ids as a stand-in for
    a task reward (in production: a verifier / unit tests / reward
    model)."""
    gen = engine.tokens(seq)[prompt_len:]
    return float(np.mean(gen)) if gen else 0.0


def explore_level(engine, parent, n_branches, n_tokens, key, prompt_len):
    branches = engine.fork(parent, n_branches)
    for i in range(n_tokens):
        key, k = jax.random.split(key)
        engine.decode(branches, greedy=False, temperature=2.0, key=k)
    scores = [branch_score(engine, b, prompt_len) for b in branches]
    ranked = sorted(zip(scores, branches), reverse=True)
    best = ranked[0][1]
    print(f"  scores: {[f'{s:.1f}' for s, _ in ranked]} -> "
          f"committing branch {best}")
    for _, b in ranked[1:]:
        pass  # losers are invalidated by the winner's commit
    engine.commit(best)
    return key


def main():
    cfg = dataclasses.replace(get_config("paper-agentic"), dtype="float32")
    model = Model(cfg, attn_chunk=8, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, num_pages=512, page_size=8,
                         max_pages_per_seq=32)

    prompt = [7, 3, 9, 21, 14, 2]
    root = engine.add_request(prompt)
    key = jax.random.PRNGKey(42)

    print(f"prompt: {prompt}")
    print(f"pool before: {engine.stats()}")
    for level in range(3):
        print(f"level {level}: fork 3 branches, decode 4 tokens each")
        key = explore_level(engine, root, n_branches=3, n_tokens=4,
                            key=key, prompt_len=len(prompt))
        print(f"  committed length: {len(engine.tokens(root))}, "
              f"pool: {engine.stats()}")
    print(f"final sequence: {engine.tokens(root)}")


if __name__ == "__main__":
    main()
