"""Agentic exploration over generations — the paper's serving workload,
now through the one public ``repro.api`` surface.

Two Tree-of-Thoughts searches (``beam_search``: fork N continuation
branches per level, decode, score, commit the best) plus a nested
``tree_search`` run *concurrently* on one engine: every request enters
through a :class:`~repro.api.BranchSession` (worst-case page
reservations, so no mid-decode -ENOSPC; every fork a vectorized
``branch()`` with one fused CoW dispatch), and the exploration driver
multiplexes all policies' decode work into the same continuous batch
via the session's epoll-like ``Waiter``.

Run:  PYTHONPATH=src python examples/agentic_serve.py

``--trace trace.json`` records per-branch lifecycle spans, prints the
one-screen metrics summary, and writes a Chrome/Perfetto timeline —
open it at https://ui.perfetto.dev to see the fork/explore/commit story
as one row per branch.

``--client http://host:port`` drives the SAME workload over HTTP
against a running front door (``python -m repro.launch.serve --serve
host:port``) instead of building an in-process engine: each exploration
becomes a ``POST /v1/explore`` SSE stream, and the three searches still
share one engine's continuous batch — server-side.
"""

import argparse
import dataclasses

import jax

from repro.api import BranchSession
from repro.configs import get_config
from repro.explore_ctx import ExplorationDriver, beam_search, tree_search
from repro.models.model import Model
from repro.obs import Observability
from repro.runtime.serve_loop import ServeEngine


def run_client(url: str) -> None:
    """The same three concurrent searches, over the HTTP front door."""
    import asyncio

    from repro.server import ServeClient

    client = ServeClient(url)

    async def drive() -> None:
        health = await client.health()
        print(f"server: {health}")
        beam, beam2, tree = await asyncio.gather(
            client.explore([7, 3, 9, 21, 14, 2], policy="beam",
                           max_new_tokens=13,
                           params={"width": 3, "depth": 3,
                                   "tokens_per_level": 4,
                                   "temperature": 2.0}),
            client.explore([4, 8, 15, 16, 23, 42], policy="beam",
                           max_new_tokens=13,
                           params={"width": 3, "depth": 3,
                                   "tokens_per_level": 4,
                                   "temperature": 2.0}),
            client.explore([5, 10, 20], policy="tree", max_new_tokens=17,
                           params={"fan_out": 3, "max_nodes": 9,
                                   "tokens_per_node": 4, "max_depth": 3,
                                   "temperature": 2.0}),
        )
        for name, fin in (("beam", beam), ("beam2", beam2),
                          ("tree", tree)):
            if fin["event"] != "result":
                print(f"{name}: {fin['event']} — {fin}")
                continue
            print(f"{name}: final sequence {fin['tokens']}")
        metrics = await client.metrics()
        served = [ln for ln in metrics.splitlines() if "server." in ln]
        print("server metrics:\n  " + "\n  ".join(served))

    asyncio.run(drive())


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a Chrome/Perfetto trace.json on exit and "
                         "print the metrics summary")
    ap.add_argument("--client", default=None, metavar="URL",
                    help="drive a running front door over HTTP instead "
                         "of building an in-process engine")
    args = ap.parse_args(argv)

    if args.client:
        run_client(args.client)
        return

    cfg = dataclasses.replace(get_config("paper-agentic"), dtype="float32")
    model = Model(cfg, attn_chunk=8, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, num_pages=512, page_size=8,
                         max_pages_per_seq=32,
                         obs=Observability(trace=args.trace is not None))
    session = BranchSession(engine, max_batch=8, seed=42)
    driver = ExplorationDriver(session)

    prompt = [7, 3, 9, 21, 14, 2]
    print(f"prompt: {prompt}")
    print(f"pool before: {engine.stats()}")

    # three concurrent explorations, one page pool, one batching loop
    beam = driver.explore(prompt, max_new_tokens=13, policy=beam_search,
                          width=3, depth=3, tokens_per_level=4,
                          temperature=2.0, name="beam")
    beam2 = driver.explore([4, 8, 15, 16, 23, 42], max_new_tokens=13,
                           policy=beam_search, width=3, depth=3,
                           tokens_per_level=4, temperature=2.0,
                           name="beam2")
    tree = driver.explore([5, 10, 20], max_new_tokens=17,
                          policy=tree_search, fan_out=3, max_nodes=9,
                          tokens_per_node=4, max_depth=3,
                          temperature=2.0, name="tree")
    driver.run()

    for level in beam.result.stats["levels"]:
        if level.get("degraded"):
            print(f"  level {level['level']}: page pressure — "
                  "decoded unforked")
            continue
        scores = sorted(level["scores"], reverse=True)
        print(f"  level {level['level']}: scores "
              f"{[f'{s:.1f}' for s in scores]} -> "
              f"committing branch {level['winner_seq']}")
    tree_score = ("degraded" if tree.result.score is None
                  else f"{tree.result.score:.1f}")
    print(f"nested tree: created {tree.result.stats['branches_created']} "
          f"branches, winner depth {tree.result.stats.get('winner_depth')}"
          f", score {tree_score}")
    print(f"final sequence: {beam.result.tokens}")
    print(f"concurrent sequence: {beam2.result.tokens}")
    print(f"pool after (drained): {session.tree()['pool']}")
    if args.trace:
        print("metrics summary:")
        print(session.obs.metrics.format())
        session.trace(args.trace)
        print(f"wrote {args.trace} — open at https://ui.perfetto.dev")


if __name__ == "__main__":
    main()
