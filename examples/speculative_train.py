"""Speculative training: every step forks K candidate update branches
(different LR multipliers), runs them in parallel inside one jit, and
commits the one with the best validation loss — first-commit-wins as a
training-time primitive (paper §8: "system configuration tuning").

Run:  PYTHONPATH=src python examples/speculative_train.py
"""

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.core import explore
from repro.data import SyntheticLMPipeline
from repro.models.model import Model
from repro.optim import adamw, apply_updates


def main():
    cfg = dataclasses.replace(reduced(get_config("qwen2-1.5b")),
                              dtype="float32")
    model = Model(cfg, attn_chunk=8, loss_chunk=8, remat=False)
    opt = adamw(1e-3)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    state = {"params": params, "opt": opt.init(params)}
    data = SyntheticLMPipeline(cfg, batch=4, seq=32, seed=1)
    val_batch = data.peek(10_000)  # held-out

    def one_branch(state, key, batch):
        """Try this branch's LR scale; success = val loss improves."""
        lr_scale = 0.25 * (2.0 ** jax.random.randint(key, (), 0, 4)
                           .astype(jnp.float32))

        def loss_fn(p):
            return model.loss(p, batch)[0]

        grads = jax.grad(loss_fn)(state["params"])
        grads = jax.tree_util.tree_map(lambda g: g * lr_scale, grads)
        updates, new_opt = opt.update(grads, state["opt"],
                                      state["params"])
        new_params = apply_updates(state["params"], updates)
        val = model.loss(new_params, val_batch)[0]
        new_state = {"params": new_params, "opt": new_opt}
        return new_state, jnp.isfinite(val), val

    @jax.jit
    def spec_step(state, key, batch):
        return explore(lambda s, k: one_branch(s, k, batch),
                       state, 4, key, commit_time_fn=lambda a: a)

    for step in range(15):
        key, k = jax.random.split(key)
        batch = data.next()
        res = spec_step(state, k, batch)
        state = res.state
        vals = [f"{float(v):.3f}" for v in res.aux]
        print(f"step {step:02d} committed branch {int(res.winner)} "
              f"val-losses {vals}")
    print("speculative training complete")


if __name__ == "__main__":
    main()
