"""Speculative training through the BranchContext subsystem: every step
forks K candidate update branches (different LR multipliers), runs them
in parallel inside one jit, and commits the one with the best validation
loss — first-commit-wins as a training-time primitive (paper §8:
"system configuration tuning").  The fork/explore/commit mechanics live
in ``repro.explore_ctx.SpeculativeTrainer``; this example is the
three-line usage.

Run:  PYTHONPATH=src python examples/speculative_train.py
"""

import dataclasses

import jax

from repro.configs import get_config, reduced
from repro.data import SyntheticLMPipeline
from repro.explore_ctx import SpeculativeTrainer
from repro.models.model import Model
from repro.optim import adamw


def main():
    cfg = dataclasses.replace(reduced(get_config("qwen2-1.5b")),
                              dtype="float32")
    model = Model(cfg, attn_chunk=8, loss_chunk=8, remat=False)
    data = SyntheticLMPipeline(cfg, batch=4, seq=32, seed=1)
    val_batch = data.peek(10_000)  # held-out

    trainer = SpeculativeTrainer(model, adamw(1e-3), n_branches=4)
    key = jax.random.PRNGKey(0)
    state = trainer.init(key)

    for step in range(15):
        key, k = jax.random.split(key)
        state, info = trainer.step(state, k, data.next(), val_batch)
        vals = [f"{v:.3f}" for v in info["val_losses"]]
        print(f"step {step:02d} committed branch {info['winner']} "
              f"val-losses {vals}")
    print("speculative training complete")


if __name__ == "__main__":
    main()
