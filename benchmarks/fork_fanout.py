"""Vectorized vs sequential fork fan-out (the TClone hot path).

Agent fan-out is the branching hot path: a policy forks k siblings at
once, and BranchBench-style workloads live or die on that latency.  The
``repro.api`` surface makes ``branch(parent, n=k)`` a *vectorized* fork:
one handle-table transaction, one reservation-ledger admission, one
kernel fork (one exclusive commit group), and — the device-side win —
every child's shared-tail CoW hoisted into a **single** fused
``_copy_pages`` dispatch (``KVBranchManager.fork_batch``).  The
sequential baseline issues ``k`` ``branch(parent, n=1)`` calls: k ledger
transactions and k one-page CoW dispatches for the same end state.

Rows per fan-out k ∈ {2, 4, 8, 16}:

* ``vectorized_us``  — wall-clock of one ``branch(parent, n=k)``
* ``sequential_us``  — wall-clock of k × ``branch(parent, n=1)``
* ``us_per_fork``    — vectorized cost / k (the paper's <350 µs
  branch-creation bar, now including the eager CoW device work)
* ``branches_per_s`` — vectorized fan-out throughput
* ``speedup``        — sequential / vectorized wall-clock
* ``cow_dispatches`` — device dispatches per fan-out (must be 1
  vectorized, k sequential)
"""

from __future__ import annotations

import dataclasses
import statistics
import time
from typing import List, Tuple

import jax

from repro.api import BR_HOLD, BranchSession
from repro.configs import get_config
from repro.models.model import Model
from repro.runtime.serve_loop import ServeEngine

FAN_OUTS = (2, 4, 8, 16)


def _session() -> BranchSession:
    cfg = dataclasses.replace(get_config("paper-agentic"), dtype="float32")
    model = Model(cfg, attn_chunk=8, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    # 45-token prompt -> 44 cached tokens: a partially-filled tail page,
    # so every forked child carries exactly one tail CoW to service
    engine = ServeEngine(model, params, num_pages=512, page_size=16,
                         max_pages_per_seq=8)
    session = BranchSession(engine, max_batch=16)
    return session


def _reap(session: BranchSession, kids: List[int]) -> None:
    for hd in kids:
        session.abort(hd)
        session.close(hd)
    # one untimed scheduler round lets the ledger drop the aborted
    # children's reservations — otherwise they accumulate across trials
    # and later timed forks hit AdmissionDenied (and pay a scheduler
    # step inside the timed region)
    session.step()


def _median_us(session: BranchSession, fork_fn, trials: int = 10) -> float:
    """Median wall-clock of ``fork_fn`` alone; cleanup is untimed."""
    out = []
    for _ in range(trials):
        t0 = time.perf_counter()
        kids = fork_fn()
        out.append((time.perf_counter() - t0) * 1e6)
        _reap(session, kids)
    return statistics.median(out)


def run() -> List[Tuple[str, float, str]]:
    session = _session()
    engine = session.engine
    # BR_HOLD: the origin never decodes on its own, so the _reap
    # bookkeeping step between trials is pure host work
    root = session.open(list(range(2, 47)), max_new_tokens=16,
                        flags=BR_HOLD)
    assert session.admitted(root)

    rows: List[Tuple[str, float, str]] = []
    for k in FAN_OUTS:
        def vectorized() -> List[int]:
            return session.branch(root, n=k)

        def sequential() -> List[int]:
            return [session.branch(root, n=1)[0] for _ in range(k)]

        _reap(session, vectorized())       # warm the k-op CoW bucket
        _reap(session, sequential())       # warm the 1-op CoW bucket

        d0 = engine.cow_dispatches
        _reap(session, vectorized())
        vec_dispatches = engine.cow_dispatches - d0
        d0 = engine.cow_dispatches
        _reap(session, sequential())
        seq_dispatches = engine.cow_dispatches - d0

        vec_us = _median_us(session, vectorized)
        seq_us = _median_us(session, sequential)
        rows.append((f"fanout{k}_vectorized_us", vec_us,
                     f"{vec_dispatches}_cow_dispatch"))
        rows.append((f"fanout{k}_sequential_us", seq_us,
                     f"{seq_dispatches}_cow_dispatches"))
        rows.append((f"fanout{k}_us_per_fork", vec_us / k,
                     "paper_T4<350us"))
        rows.append((f"fanout{k}_branches_per_s", k / (vec_us / 1e6),
                     "vectorized"))
        rows.append((f"fanout{k}_speedup", seq_us / vec_us,
                     "sequential/vectorized"))

    session.finish(root)
    return rows


if __name__ == "__main__":
    for name, value, derived in run():
        print(f"{name},{value:.3f},{derived}")
