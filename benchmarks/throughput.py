"""Paper Table 6 — sequential I/O throughput: native vs chained vs
passthrough.

The paper measures a 50 MB file in 64 KB blocks through three paths:
native FS (8,800 MB/s), the FUSE daemon (1,655 MB/s = 19 %), and
FOPEN_PASSTHROUGH (7,236 MB/s = 82 %).  The branchx analogues:

* native      — direct dict reads of the flat state;
* chained     — reads through a depth-k branch chain (the FUSE-roundtrip
                analogue: indirection cost per block);
* passthrough — reads from a consolidated view (chain walked once).

Writes: branch writes are buffered without durability (fsync elision) —
compared against base writes with durability at commit.
"""

from __future__ import annotations

import time
from typing import List, Tuple

from repro.core import BranchStore

BLOCK = 64 * 1024
TOTAL = 50 * 1024 * 1024
N_BLOCKS = TOTAL // BLOCK


def _mbps(seconds: float) -> float:
    return TOTAL / seconds / 1e6


def run() -> List[Tuple[str, float, str]]:
    payload = b"z" * BLOCK
    base = {f"blk{i}": payload for i in range(N_BLOCKS)}
    store = BranchStore(base)

    # native: flat dict reads
    flat = dict(base)
    t0 = time.perf_counter()
    for i in range(N_BLOCKS):
        _ = flat[f"blk{i}"]
    native = time.perf_counter() - t0

    # chained: depth-8 branch chain, all reads resolve to base
    b = BranchStore.ROOT
    for _ in range(8):
        (b,) = store.fork(b)
        store.write(b, "touch", b"t")  # keep deltas non-empty
    t0 = time.perf_counter()
    for i in range(N_BLOCKS):
        _ = store.read(b, f"blk{i}")
    chained = time.perf_counter() - t0

    # passthrough: consolidated view (chain walked once)
    view = store.consolidated_view(b)
    t0 = time.perf_counter()
    for i in range(N_BLOCKS):
        _ = view[f"blk{i}"]
    passthrough = time.perf_counter() - t0

    # writes into a branch delta (ephemeral, no durability)
    (w,) = store.fork(BranchStore.ROOT)
    t0 = time.perf_counter()
    for i in range(N_BLOCKS):
        store.write(w, f"blk{i}", payload)
    branch_write = time.perf_counter() - t0

    rows = [
        ("read_native_MBps", _mbps(native), "paper_T6_native"),
        ("read_chained_depth8_MBps", _mbps(chained), "paper_T6_fuse"),
        ("read_passthrough_MBps", _mbps(passthrough),
         "paper_T6_passthrough"),
        ("write_branch_MBps", _mbps(branch_write),
         "paper_T6_fsync_elision"),
        ("chained_over_native", _mbps(chained) / _mbps(native),
         "paper=0.19"),
        ("passthrough_over_native", _mbps(passthrough) / _mbps(native),
         "paper=0.82"),
    ]
    return rows
