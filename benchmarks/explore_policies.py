"""Exploration-policy throughput: the BranchContext subsystem under load.

Per policy × fan-out: wall-clock branches/s (forks actually created and
resolved through scheduler admission), end-to-end exploration latency,
and peak pool utilization — plus kernel-level commit latency and the
aggregate throughput of 8 explorations multiplexed on one engine.
BranchBench's point (PAPERS.md) is that agentic workloads are defined by
their branching patterns; these rows are the repo's trajectory for them.
"""

from __future__ import annotations

import dataclasses
import time
from typing import List, Tuple

import jax

from repro.configs import get_config
from repro.explore_ctx import (
    ExplorationDriver,
    beam_search,
    best_of_n,
    tree_search,
)
from repro.models.model import Model
from repro.runtime.scheduler import Scheduler, SchedulerConfig
from repro.runtime.serve_loop import ServeEngine


def _build_engine():
    cfg = dataclasses.replace(get_config("paper-agentic"), dtype="float32")
    model = Model(cfg, attn_chunk=8, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    return ServeEngine(model, params, num_pages=512, page_size=8,
                       max_pages_per_seq=32)


def _branches_of(res) -> int:
    st = res.stats
    if "branches" in st:
        return st["branches"]
    if "branches_created" in st:
        return st["branches_created"]
    return sum(len(lv.get("scores", [])) for lv in st.get("levels", []))


def _drive(engine, launches) -> Tuple[float, int, int, int]:
    """Run explorations to completion.

    Returns (seconds, branches_created, tokens, peak_pages_used).
    """
    from repro.api import BranchSession

    driver = ExplorationDriver(BranchSession(engine, max_batch=16, seed=7))
    exps = [launch(driver) for launch in launches]
    peak = 0
    t0 = time.perf_counter()
    for _ in range(2000):
        if all(e.done for e in exps):
            break
        driver.step()
        st = engine.stats()
        peak = max(peak, st["pages_total"] - st["pages_free"])
    else:
        raise RuntimeError("benchmark explorations exceeded the step "
                           "bound (fork-blocked with no stall kick?)")
    dt = time.perf_counter() - t0
    for e in exps:
        if e.error is not None:
            raise e.error
    branches = sum(_branches_of(e.result) for e in exps)
    tokens = sum(len(e.result.generated) for e in exps)
    return dt, branches, tokens, peak


def _launch(policy, prompt, budget, **kw):
    return lambda drv: drv.explore(prompt, budget, policy, **kw)


def _timed(eng, launches) -> Tuple[float, int, int, int]:
    """Warm, then time: decode batch widths are unpadded, so each
    configuration's first run pays its jit compiles — running the same
    shape twice keeps branches/s comparable across fan-outs."""
    _drive(eng, launches)
    return _drive(eng, launches)


def run() -> List[Tuple[str, float, str]]:
    rows: List[Tuple[str, float, str]] = []
    eng = _build_engine()

    for fan in (2, 4, 8):
        dt, br, toks, peak = _timed(eng, [_launch(
            best_of_n, [3, 1, 4, 1], 10, n=fan, tokens=4)])
        rows.append((f"best_of_{fan}_branches_per_s", br / dt,
                     f"peak_pages={peak}"))

        dt, br, toks, peak = _timed(eng, [_launch(
            beam_search, [3, 1, 4, 1], 2 * 4 + 1, width=fan, depth=2,
            tokens_per_level=4)])
        rows.append((f"beam_w{fan}_d2_branches_per_s", br / dt,
                     f"peak_pages={peak}"))

        dt, br, toks, peak = _timed(eng, [_launch(
            tree_search, [3, 1, 4, 1], 3 * 3 + 1, fan_out=fan,
            max_nodes=3 * fan, tokens_per_node=3, max_depth=3)])
        rows.append((f"tree_f{fan}_n{3 * fan}_branches_per_s", br / dt,
                     f"peak_pages={peak}"))

    # 8 interleaved explorations multiplexed into one continuous batch
    launches = [_launch(best_of_n, [i + 1, i + 2, i + 3], 10, n=4,
                        tokens=4) for i in range(8)]
    dt, br, toks, peak = _timed(eng, launches)
    rows.append(("concurrent8_branches_per_s", br / dt,
                 f"tokens={toks},peak_pages={peak}"))
    rows.append(("concurrent8_latency_us", dt * 1e6, "8x_best_of_4"))

    # kernel-level commit latency (host work: table promote + sibling
    # invalidation + scheduler reap), isolated from decode time
    sched = Scheduler(eng, SchedulerConfig(max_batch=16))
    reps, total = 10, 0.0
    for r in range(reps):
        rid = sched.submit([5, 6, 7, 8], max_new_tokens=6)
        sched.admit()
        seq = sched.seq_of(rid)
        kids = sched.fork(seq, 4)
        eng.decode(kids)
        t0 = time.perf_counter()
        eng.commit(kids[0])
        total += time.perf_counter() - t0
        sched.finish(rid)
    rows.append(("commit_latency_us", total / reps * 1e6,
                 "4way_group_first_commit_wins"))
    return rows
