"""Paper Table 5 — commit/abort latency vs modification size.

Claim: commit cost ∝ modified data volume (317 µs @ 1 KB → 2.1 ms @ 1 MB
on the paper's hardware); abort is cheap and ~size-independent.
"""

from __future__ import annotations

import statistics
import tempfile
import time
from typing import List, Tuple

from repro.fs import BranchFS


def _bench(fs: BranchFS, size: int, mode: str, trials: int = 10) -> float:
    times = []
    payload = b"y" * size
    for t in range(trials):
        (b,) = fs.create()
        fs.write(b, f"mod_{t}", payload)
        t0 = time.perf_counter()
        if mode == "commit":
            fs.commit(b)
        else:
            fs.abort(b)
        times.append((time.perf_counter() - t0) * 1e6)
    return statistics.median(times)


def _bench_write_commit(fs: BranchFS, size: int, trials: int = 10
                        ) -> float:
    """End-to-end modification cost: write the delta AND commit it.

    branchx's commit alone is O(#modified files), not O(bytes) (content-
    addressed chunks land on disk at write() time — a beyond-paper
    improvement); the paper's Table-5 proportionality therefore shows up
    in write+commit."""
    times = []
    payload = b"y" * size
    for t in range(trials):
        (b,) = fs.create()
        t0 = time.perf_counter()
        fs.write(b, f"wm_{t}", payload)
        fs.commit(b)
        times.append((time.perf_counter() - t0) * 1e6)
    return statistics.median(times)


def run() -> List[Tuple[str, float, str]]:
    rows = []
    for size, label in ((1024, "1KB"), (100 * 1024, "100KB"),
                        (1024 * 1024, "1MB")):
        with tempfile.TemporaryDirectory() as td:
            fs = BranchFS(td)
            fs.write("base", "seed", b"s")
            rows.append((f"commit_{label}", _bench(fs, size, "commit"),
                         "O(#files)_beyond_paper"))
        with tempfile.TemporaryDirectory() as td:
            fs = BranchFS(td)
            fs.write("base", "seed", b"s")
            rows.append((f"write_commit_{label}",
                         _bench_write_commit(fs, size),
                         "paper_T5_prop_to_delta"))
        with tempfile.TemporaryDirectory() as td:
            fs = BranchFS(td)
            fs.write("base", "seed", b"s")
            rows.append((f"abort_{label}", _bench(fs, size, "abort"),
                         "paper_T5_cheap"))
    return rows
