"""Device-side exploration benchmark: N-branch fork/explore/commit cost
inside one jitted program (speculative-training primitive).

Measures the per-round overhead of fork_stacked + vmap(step) +
first_commit_wins vs. running the same step once — the cost of
parallelism when branches map onto spare accelerator capacity.
"""

from __future__ import annotations

import statistics
import time
from typing import List, Tuple

import jax
import jax.numpy as jnp

from repro.core import explore


def run() -> List[Tuple[str, float, str]]:
    dim = 256
    origin = {"w": jnp.zeros((dim, dim)), "loss": jnp.float32(1e9)}
    target = jax.random.normal(jax.random.PRNGKey(0), (dim, dim))

    def loss(w):
        return jnp.mean((w - target) ** 2)

    def step(state, key):
        g = jax.grad(loss)(state["w"])
        lr = 0.05 + 0.1 * jax.random.uniform(key)
        w = state["w"] - lr * g
        l = loss(w)
        return {"w": w, "loss": l}, l < state["loss"], l

    rows = []

    def timed(jitted, reps=50):
        out = jitted(origin, jnp.int32(0))  # compile
        jax.block_until_ready(out["w"])
        t0 = time.perf_counter()
        for i in range(reps):
            out = jitted(origin, jnp.int32(i))
        jax.block_until_ready(out["w"])
        return (time.perf_counter() - t0) / reps * 1e6

    base = jax.jit(lambda s, i: step(
        s, jax.random.fold_in(jax.random.PRNGKey(1), i))[0])
    t_single = timed(base)
    rows.append(("single_step_us", t_single, "no-branching"))

    for n in (2, 4, 8):
        run_explore = jax.jit(
            lambda o, i, n=n: explore(
                step, o, n, jax.random.fold_in(jax.random.PRNGKey(2), i),
                commit_time_fn=lambda a: a).state)
        us = timed(run_explore)
        rows.append((f"explore_{n}branch_us", us,
                     f"overhead={us / t_single:.2f}x"))
    return rows
