"""Benchmark harness — one module per paper table.

Prints ``name,us_per_call,derived`` CSV rows and writes a
machine-readable ``BENCH_<timestamp>.json`` (override with ``--out``)
so the perf trajectory is tracked across PRs.  Tables:
  T4 (creation O(1))      -> branch_create
  T5 (commit ∝ Δ)        -> commit_abort
  T6 (throughput)         -> throughput
  serving-scale branching -> kvbranch_bench
  vectorized fork fan-out -> fork_fanout
  serve throughput        -> serve_throughput
  sharded (tp) serving    -> shard_serve
  in-program exploration  -> explore_bench
  exploration policies    -> explore_policies
  decode fast path        -> decode_step
  fused spec verify       -> spec_verify
  HTTP/SSE front door     -> front_door
  branchlint self-host    -> lint_selfhost

  tiered KV + prefix hits -> kv_tier

``--compare <baseline.json>`` checks the run against a committed
baseline and fails on a >20% drop of any throughput-like row
(``*_per_s``, ``*speedup*``, ``*gain*``); latency rows only warn —
shared CI machines make microsecond medians too noisy to gate on.

Rows whose ``derived`` label embeds a paper target (``...<350us``) are
checked against it: violations warn by default and fail the run under
``--strict-derived`` (same noise rationale as the latency compare).
"""

from __future__ import annotations

import argparse
import json
import platform
import re
import subprocess
import sys
import time
import traceback
from pathlib import Path

_DERIVED_TARGET = re.compile(r"<\s*(\d+(?:\.\d+)?)\s*us\b")


def check_derived(records: list) -> list:
    """Rows claiming a paper latency bar in their derived label
    (``paper_T4<350us``) are held to it.  Returns violation strings."""
    out = []
    for r in records:
        m = _DERIVED_TARGET.search(r.get("derived", "") or "")
        if m and r["value"] > float(m.group(1)):
            out.append(f"derived target missed {r['module']}.{r['name']}: "
                       f"{r['value']:.1f}us > {m.group(1)}us "
                       f"({r['derived']})")
    return out


def compare(baseline_path: Path, records: list) -> list:
    """Regression check vs a committed baseline JSON.

    Throughput-like rows (``*_per_s``, ``*speedup*``, ``*gain*``) fail
    on a >20% drop; ``*_us*`` latency rows print a warning only (CI
    wall-clock noise); everything else is informational.  Returns the
    list of failure strings.
    """
    base = json.loads(Path(baseline_path).read_text())
    base_rows = {(r["module"], r["name"]): r["value"]
                 for r in base.get("rows", [])}
    failures = []
    for r in records:
        key = (r["module"], r["name"])
        name = r["name"]
        if name.startswith("_") or key not in base_rows:
            continue
        old, new = base_rows[key], r["value"]
        if old <= 0:
            continue
        label = f"{key[0]}.{name}: {old:.3f} -> {new:.3f}"
        # suffix match: "us_per_step" latency rows contain "per_s"
        if (name.endswith("per_s") or "speedup" in name
                or "gain" in name):
            if new < 0.8 * old:
                failures.append(f"throughput regression {label} "
                                f"({new / old - 1:+.0%})")
        elif "_us" in name and new > 1.5 * old:
            print(f"warning: latency grew {label}", file=sys.stderr)
    return failures


def _git_rev() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], capture_output=True,
            text=True, timeout=10).stdout.strip() or "unknown"
    except Exception:
        return "unknown"


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None,
                    help="path for the JSON record (default: "
                         "BENCH_<timestamp>.json in the cwd)")
    ap.add_argument("--only", default=None,
                    help="comma-separated module names to run")
    ap.add_argument("--compare", default=None,
                    help="baseline BENCH_*.json to regression-check "
                         "against (fail on >20%% throughput drop)")
    ap.add_argument("--strict-derived", action="store_true",
                    help="fail (not just warn) when a row misses the "
                         "paper target embedded in its derived label "
                         "(e.g. paper_T4<350us)")
    args = ap.parse_args(argv)

    from benchmarks import (
        branch_create,
        commit_abort,
        decode_step,
        explore_bench,
        explore_policies,
        fork_fanout,
        front_door,
        kv_tier,
        kvbranch_bench,
        lint_selfhost,
        serve_throughput,
        shard_serve,
        spec_verify,
        throughput,
    )

    modules = [
        ("branch_create", branch_create),
        ("commit_abort", commit_abort),
        ("throughput", throughput),
        ("kvbranch_bench", kvbranch_bench),
        ("fork_fanout", fork_fanout),
        ("serve_throughput", serve_throughput),
        ("shard_serve", shard_serve),
        ("explore_bench", explore_bench),
        ("explore_policies", explore_policies),
        ("decode_step", decode_step),
        ("spec_verify", spec_verify),
        ("front_door", front_door),
        ("lint_selfhost", lint_selfhost),
        ("kv_tier", kv_tier),
    ]
    if args.only:
        keep = set(args.only.split(","))
        unknown = keep - {n for n, _ in modules}
        if unknown:
            ap.error(f"unknown benchmark module(s): {sorted(unknown)}")
        modules = [(n, m) for n, m in modules if n in keep]

    print("name,us_per_call,derived")
    records = []
    failed = []
    for name, mod in modules:
        t0 = time.time()
        try:
            for row, value, derived in mod.run():
                print(f"{name}.{row},{value:.3f},{derived}")
                records.append({"module": name, "name": row,
                                "value": value, "derived": derived})
        except Exception:
            traceback.print_exc()
            failed.append(name)
        records.append({"module": name, "name": "_wall_s",
                        "value": round(time.time() - t0, 3),
                        "derived": "harness"})

    # the obs-registry view across every engine/manager the benchmark
    # modules created (live + already-GC'd hubs), so the trajectory
    # carries dispatch counts and latency percentiles, not just
    # wall-clock rows
    try:
        from repro.obs import merged_snapshot
        metrics = merged_snapshot()
    except Exception:
        metrics = {}

    stamp = time.strftime("%Y%m%d_%H%M%S")
    out = Path(args.out) if args.out else Path(f"BENCH_{stamp}.json")
    out.write_text(json.dumps({
        "schema": 2,
        "created": stamp,
        "git_rev": _git_rev(),
        "python": platform.python_version(),
        "platform": platform.platform(),
        "failed": failed,
        "rows": records,
        "metrics": metrics,
    }, indent=2))
    print(f"wrote {out}")
    misses = check_derived(records)
    for line in misses:
        print(("" if args.strict_derived else "warning: ") + line,
              file=sys.stderr)
    if misses and args.strict_derived:
        failed.append("derived-targets")
    if args.compare:
        regressions = compare(Path(args.compare), records)
        for line in regressions:
            print(line, file=sys.stderr)
        if regressions:
            failed.append(f"compare:{args.compare}")
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
