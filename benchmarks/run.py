"""Benchmark harness — one module per paper table.

Prints ``name,us_per_call,derived`` CSV rows and writes a
machine-readable ``BENCH_<timestamp>.json`` (override with ``--out``)
so the perf trajectory is tracked across PRs.  Tables:
  T4 (creation O(1))      -> branch_create
  T5 (commit ∝ Δ)        -> commit_abort
  T6 (throughput)         -> throughput
  serving-scale branching -> kvbranch_bench
  vectorized fork fan-out -> fork_fanout
  serve throughput        -> serve_throughput
  sharded (tp) serving    -> shard_serve
  in-program exploration  -> explore_bench
  exploration policies    -> explore_policies
"""

from __future__ import annotations

import argparse
import json
import platform
import subprocess
import sys
import time
import traceback
from pathlib import Path


def _git_rev() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], capture_output=True,
            text=True, timeout=10).stdout.strip() or "unknown"
    except Exception:
        return "unknown"


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None,
                    help="path for the JSON record (default: "
                         "BENCH_<timestamp>.json in the cwd)")
    ap.add_argument("--only", default=None,
                    help="comma-separated module names to run")
    args = ap.parse_args(argv)

    from benchmarks import (
        branch_create,
        commit_abort,
        explore_bench,
        explore_policies,
        fork_fanout,
        kvbranch_bench,
        serve_throughput,
        shard_serve,
        throughput,
    )

    modules = [
        ("branch_create", branch_create),
        ("commit_abort", commit_abort),
        ("throughput", throughput),
        ("kvbranch_bench", kvbranch_bench),
        ("fork_fanout", fork_fanout),
        ("serve_throughput", serve_throughput),
        ("shard_serve", shard_serve),
        ("explore_bench", explore_bench),
        ("explore_policies", explore_policies),
    ]
    if args.only:
        keep = set(args.only.split(","))
        unknown = keep - {n for n, _ in modules}
        if unknown:
            ap.error(f"unknown benchmark module(s): {sorted(unknown)}")
        modules = [(n, m) for n, m in modules if n in keep]

    print("name,us_per_call,derived")
    records = []
    failed = []
    for name, mod in modules:
        t0 = time.time()
        try:
            for row, value, derived in mod.run():
                print(f"{name}.{row},{value:.3f},{derived}")
                records.append({"module": name, "name": row,
                                "value": value, "derived": derived})
        except Exception:
            traceback.print_exc()
            failed.append(name)
        records.append({"module": name, "name": "_wall_s",
                        "value": round(time.time() - t0, 3),
                        "derived": "harness"})

    stamp = time.strftime("%Y%m%d_%H%M%S")
    out = Path(args.out) if args.out else Path(f"BENCH_{stamp}.json")
    out.write_text(json.dumps({
        "schema": 1,
        "created": stamp,
        "git_rev": _git_rev(),
        "python": platform.python_version(),
        "platform": platform.platform(),
        "failed": failed,
        "rows": records,
    }, indent=2))
    print(f"wrote {out}")
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
