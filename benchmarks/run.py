"""Benchmark harness — one module per paper table.

Prints ``name,us_per_call,derived`` CSV rows.  Tables:
  T4 (creation O(1))      -> branch_create
  T5 (commit ∝ Δ)        -> commit_abort
  T6 (throughput)         -> throughput
  serving-scale branching -> kvbranch_bench
  serve throughput        -> serve_throughput
  in-program exploration  -> explore_bench
"""

from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (
        branch_create,
        commit_abort,
        explore_bench,
        kvbranch_bench,
        serve_throughput,
        throughput,
    )

    modules = [
        ("branch_create", branch_create),
        ("commit_abort", commit_abort),
        ("throughput", throughput),
        ("kvbranch_bench", kvbranch_bench),
        ("serve_throughput", serve_throughput),
        ("explore_bench", explore_bench),
    ]
    print("name,us_per_call,derived")
    failed = []
    for name, mod in modules:
        try:
            for row, value, derived in mod.run():
                print(f"{name}.{row},{value:.3f},{derived}")
        except Exception:
            traceback.print_exc()
            failed.append(name)
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
