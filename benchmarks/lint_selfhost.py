"""branchlint self-hosting cost — the analyzer's own wall-clock.

The lint-smoke CI job runs ``python -m repro.analysis src tests`` on
every push; this module keeps that cost on the BENCH trajectory so a
rule whose path simulation goes super-linear (BL002/BL004 ride the
``cfg`` simulator, whose state sets are capped but not free) shows up
as a throughput regression, not as mysteriously slower CI.

Rows:
* ``selfhost_wall_us`` — one full ``analyze_paths(["src"])`` pass;
* ``files_per_s`` — analysis throughput (the ``--compare`` gate row);
* ``cfg_rules_wall_us`` — the two path-sensitive rules alone, the
  part that could plausibly blow up.
"""

from __future__ import annotations

import statistics
import time
from typing import List, Tuple


def _wall_us(fn, trials: int = 3) -> float:
    times = []
    for _ in range(trials):
        t0 = time.perf_counter()
        fn()
        times.append((time.perf_counter() - t0) * 1e6)
    return statistics.median(times)


def run() -> List[Tuple[str, float, str]]:
    from repro.analysis import analyze_paths

    result = analyze_paths(["src"])     # warm (imports, pyc)
    files = max(result.files_checked, 1)

    full_us = _wall_us(lambda: analyze_paths(["src"]))
    cfg_us = _wall_us(
        lambda: analyze_paths(["src"], rules=["BL002", "BL004"]))

    return [
        ("selfhost_wall_us", full_us,
         f"{files} files, {len(result.findings)} findings"),
        ("files_per_s", files / (full_us / 1e6), "analysis throughput"),
        ("cfg_rules_wall_us", cfg_us, "BL002+BL004 path simulation"),
    ]
