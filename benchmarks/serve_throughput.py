"""Serve-throughput figure: scheduler-driven continuous batching.

Measures end-to-end serving throughput (generated tokens per second)
through the :class:`Scheduler` — admission, continuous batching across
requests, retirement — in two regimes:

* plain: N requests decode to completion as one continuously batched
  stream;
* branched: each request forks into exploration branches (page-budget
  checked) that decode batched together, then first-commit-wins; this
  exercises the fused CoW fault service on the shared decode path.
"""

from __future__ import annotations

import dataclasses
import time
from typing import List, Tuple

import jax

from repro.configs import get_config
from repro.models.model import Model
from repro.runtime.scheduler import Scheduler, SchedulerConfig
from repro.runtime.serve_loop import ServeEngine


def _build_engine():
    cfg = dataclasses.replace(get_config("paper-agentic"), dtype="float32")
    model = Model(cfg, attn_chunk=8, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    return ServeEngine(model, params, num_pages=512, page_size=16,
                       max_pages_per_seq=24)


def run() -> List[Tuple[str, float, str]]:
    rows: List[Tuple[str, float, str]] = []

    # ------------------------------------------------------------------
    # plain continuous batching: 6 requests, 8 new tokens each
    # ------------------------------------------------------------------
    eng = _build_engine()
    sched = Scheduler(eng, SchedulerConfig(max_batch=8))
    for r in range(6):
        sched.submit(list(range(2 + r, 10 + r)), max_new_tokens=8)
    sched.step()   # untimed: admits all 6, compiles prefill + b=6 decode
    t0 = time.perf_counter()
    n_tokens = sched.run(max_steps=64)
    dt = time.perf_counter() - t0
    rows.append(("serve_tokens_per_s", n_tokens / dt,
                 "continuous-batching"))
    rows.append(("serve_steps", float(sched.steps), f"{n_tokens}tok"))

    # ------------------------------------------------------------------
    # branched serving: fork 4 branches per request, decode, commit best
    # ------------------------------------------------------------------
    eng2 = _build_engine()
    sched2 = Scheduler(eng2, SchedulerConfig(max_batch=8))
    rids = [sched2.submit(list(range(3 + r, 11 + r)), max_new_tokens=32)
            for r in range(2)]
    sched2.admit()
    all_branches = []
    for rid in rids:
        all_branches.extend(sched2.fork(sched2.seq_of(rid), 4))
    eng2.decode(all_branches)  # compile + fused CoW service
    t0 = time.perf_counter()
    steps = 6
    for _ in range(steps):
        eng2.decode(all_branches)
    dt = time.perf_counter() - t0
    rows.append(("serve_branched_tokens_per_s",
                 len(all_branches) * steps / dt, "8way_batched"))
    rows.append(("serve_cow_dispatches", float(eng2.cow_dispatches),
                 f"{eng2.cow_faults}faults_fused"))
    # first-commit-wins per request (branch 0 of each 4-way group)
    for i, rid in enumerate(rids):
        eng2.commit(all_branches[i * 4])
    rows.append(("pages_free_after_commits",
                 float(eng2.stats()["pages_free"]), "losers-recycled"))
    return rows
