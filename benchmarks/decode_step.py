"""Decode fast path: dispatches/step, step latency, int8 fan-out.

The PR-6 tentpole measured end to end:

* ``*_us_per_step``     — one batched decode step, legacy two-dispatch
  ``ref`` vs the fused one-dispatch path (``fused_ref`` on CPU — same
  routing the Pallas kernel uses on TPU), under a fork-heavy workload
  where every step carries CoW faults.
* ``*_dispatches_per_step`` — device dispatches a CoW-carrying step
  costs (the 2 -> 1 headline: the fused step needs no ``_copy_pages``).
* ``fanout_*``          — branches a fixed-byte pool can hold: int8
  pages store 4x the pages of the fp32 test dtype (2x vs bf16) at equal
  bytes, so the same HBM admits a deeper agentic fan-out.
* ``qwen2_parity``      — greedy tokens on a reduced qwen2 config
  (qkv_bias, GQA 4:1) identical across ref / fused / int8.
"""

from __future__ import annotations

import dataclasses
import statistics
import time
from typing import List, Tuple

import jax

from repro.configs import get_config
from repro.configs.base import reduced
from repro.models.model import Model
from repro.runtime.serve_loop import ServeEngine

_SETUP = {}


def _model(name="paper-agentic"):
    if name not in _SETUP:
        cfg = dataclasses.replace(get_config(name), dtype="float32")
        if name != "paper-agentic":
            cfg = dataclasses.replace(reduced(cfg), dtype="float32")
        model = Model(cfg, attn_chunk=8, remat=False)
        params = model.init(jax.random.PRNGKey(0))
        _SETUP[name] = (model, params)
    return _SETUP[name]


def _engine(name="paper-agentic", **kw):
    model, params = _model(name)
    kw.setdefault("num_pages", 512)
    kw.setdefault("page_size", 4)
    kw.setdefault("max_pages_per_seq", 32)
    return ServeEngine(model, params, **kw)


def _cow_workload(eng, steps=12):
    """Fork-heavy decode: every step opens with fresh CoW faults.

    Returns (median us/step, device dispatches per step) where
    dispatches = 1 (the jitted step) + any separate _copy_pages calls.
    """
    root = eng.add_request(list(range(2, 15)))   # partial tail page
    eng.decode([root])
    samples = []
    d0, steps_run = eng.cow_dispatches, 0
    kids: List[int] = []
    for _ in range(steps):
        kids = eng.fork(root, 2)     # shared partial tail -> CoW faults
        t0 = time.perf_counter()
        eng.decode(kids)             # the measured step (faults + token)
        samples.append((time.perf_counter() - t0) * 1e6)
        steps_run += 1
        for k in kids:
            eng.abort(k)
            eng.kv.tree.reap(k)
    copy_dispatches = (eng.cow_dispatches - d0) / steps_run
    assert eng.cow_faults > 0, "workload produced no CoW faults"
    return statistics.median(samples[2:]), 1 + copy_dispatches


def _max_fanout(eng) -> int:
    """Branches a pool admits: fork 1 child at a time, decode it one
    step (forcing its tail CoW page allocation), until -ENOSPC."""
    root = eng.add_request(list(range(2, 15)))
    eng.decode([root])
    n = 0
    origin = root
    try:
        while True:
            (kid,) = eng.fork(origin, 1)
            eng.decode([kid])        # materialize the CoW'd tail page
            n += 1
    except MemoryError:
        return n


def run() -> List[Tuple[str, float, str]]:
    rows: List[Tuple[str, float, str]] = []

    ref_us, ref_disp = _cow_workload(_engine(attn_impl="ref"))
    fus_us, fus_disp = _cow_workload(_engine(attn_impl="fused_ref"))
    rows.append(("ref_us_per_step", ref_us, "legacy_two_dispatch"))
    rows.append(("fused_us_per_step", fus_us, "cow_rides_the_step"))
    rows.append(("ref_dispatches_per_step", ref_disp, "step+_copy_pages"))
    rows.append(("fused_dispatches_per_step", fus_disp, "target_1"))
    rows.append(("fused_step_speedup", ref_us / fus_us, "ref/fused"))

    # tokens/s of plain batched decode (no forking), both paths
    for impl in ("ref", "fused_ref"):
        eng = _engine(attn_impl=impl)
        seqs = [eng.add_request(list(range(2, 12))) for _ in range(8)]
        for _ in range(2):
            eng.decode(seqs)         # warm the compile cache
        t0 = time.perf_counter()
        n_steps = 16
        for _ in range(n_steps):
            eng.decode(seqs)
        dt = time.perf_counter() - t0
        rows.append((f"{impl}_decode_tokens_per_s",
                     len(seqs) * n_steps / dt, "batch8_greedy"))

    # fan-out at equal pool bytes: fp32 pages vs int8 pages (+scales).
    # fp32 -> int8 is 4 bytes -> 1 byte per element, so the same byte
    # budget holds 4x the pages (2x for a bf16 deployment dtype).
    base_pages = 48
    fp = _engine(num_pages=base_pages, max_pages_per_seq=8)
    q8 = _engine(num_pages=base_pages * 4, max_pages_per_seq=8,
                 kv_dtype="int8")
    fan_fp = _max_fanout(fp)
    fan_q8 = _max_fanout(q8)
    rows.append(("fanout_fp32_pool", float(fan_fp),
                 f"{base_pages}pages"))
    rows.append(("fanout_int8_equal_bytes", float(fan_q8),
                 f"{base_pages * 4}pages_same_bytes"))
    rows.append(("fanout_int8_gain", fan_q8 / max(fan_fp, 1),
                 "target>=2x_vs_bf16"))

    # greedy parity on a reduced qwen2 (qkv_bias=True, GQA) config
    toks = {}
    for label, kw in (("ref", dict(attn_impl="ref")),
                      ("fused", dict(attn_impl="fused_ref")),
                      ("int8", dict(kv_dtype="int8"))):
        eng = _engine("qwen2-1.5b", **kw)
        sid = eng.add_request(list(range(3, 16)))
        out = [eng.decode([sid])[0] for _ in range(8)]
        kids = eng.fork(sid, 2)
        out += eng.decode(kids)
        toks[label] = out
    parity = (toks["ref"] == toks["fused"] == toks["int8"])
    rows.append(("qwen2_parity", float(parity),
                 "greedy_ref==fused==int8"))
    assert parity, f"greedy divergence on qwen2: {toks}"
    return rows


if __name__ == "__main__":
    for name, value, derived in run():
        print(f"{name},{value:.3f},{derived}")
