"""Tiered KV pool + cross-request prefix sharing.

Three claims from the tiered-pool design (DESIGN §16):

* **Prefix sharing** — N requests with an identical prompt cost ONE
  prefill dispatch total; repeats adopt the cached CoW pages.
  Rows: ``prefill_dispatches_Nreq`` (target 1), ``prefix_hit_rate``,
  ``prefix_adopt_speedup`` (cold prefill vs cached adoption).
* **Checkpoint/restore latency vs branch size** — demoting a branch to
  the host tier and re-seating it scales with its page count, and a
  restore stays far below a cold prefill of the same context.
  Rows: ``checkpoint_ctx{n}_us``, ``restore_ctx{n}_us``,
  ``restore_vs_prefill_gain``.
* **Demote-before-deny** — a scheduler facing page pressure checkpoints
  held branches instead of denying admission: the deficit clears with
  zero evictions.  Rows: ``pressure_demotions``, ``pressure_admitted``.
"""

from __future__ import annotations

import dataclasses
import statistics
import time
from typing import List, Tuple

import jax

from repro.configs import get_config
from repro.models.model import Model
from repro.runtime.scheduler import Scheduler, SchedulerConfig
from repro.runtime.serve_loop import ServeEngine

N_REPEATS = 8


def _engine(**kw):
    cfg = dataclasses.replace(get_config("paper-agentic"), dtype="float32")
    model = Model(cfg, attn_chunk=8, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    kw.setdefault("num_pages", 256)
    kw.setdefault("page_size", 4)
    kw.setdefault("max_pages_per_seq", 80)
    return ServeEngine(model, params, **kw)


def bench_prefix_sharing() -> List[Tuple[str, float, str]]:
    rows: List[Tuple[str, float, str]] = []
    eng = _engine(prefix_cache=True)
    prompt = list(range(2, 19))          # 16 cached tokens = 4 full pages

    t0 = time.perf_counter()
    eng.add_request(prompt)              # the one real prefill
    cold_us = (time.perf_counter() - t0) * 1e6
    d0 = eng.prefill_dispatches

    warm_us = []
    for _ in range(N_REPEATS - 1):
        t0 = time.perf_counter()
        eng.add_request(prompt)
        warm_us.append((time.perf_counter() - t0) * 1e6)

    st = eng.kv.stats()
    m = eng.kv.obs.metrics
    hits = m.counter("kv.prefix_hits").value
    rate = hits / max(1, hits + m.counter("kv.prefix_misses").value)
    rows.append((f"prefill_dispatches_{N_REPEATS}req",
                 float(1 + (eng.prefill_dispatches - d0)), "target_1"))
    rows.append(("prefix_hit_rate", rate, f"{N_REPEATS - 1}_repeats"))
    rows.append(("prefix_cold_us", cold_us, "dense_prefill"))
    rows.append(("prefix_adopt_us", statistics.median(warm_us),
                 "cached_pages"))
    rows.append(("prefix_adopt_speedup",
                 cold_us / statistics.median(warm_us), "cold/cached"))
    rows.append(("prefix_pages_shared",
                 float(st["prefix_pages_cached"]), "cow_read_only"))
    return rows


def bench_checkpoint_restore() -> List[Tuple[str, float, str]]:
    rows: List[Tuple[str, float, str]] = []
    eng = _engine()
    for ctx in (64, 256):
        prompt = [(5 * i) % eng.cfg.vocab_size + 1 for i in range(ctx)]
        t0 = time.perf_counter()
        sid = eng.add_request(prompt)
        prefill_us = (time.perf_counter() - t0) * 1e6

        # one warm cycle, then timed cycles (each checkpoint frees the
        # device pages the paired restore re-allocates)
        eng.checkpoint(sid)
        eng.restore(sid)
        ck_samples, rs_samples = [], []
        for _ in range(5):
            t0 = time.perf_counter()
            eng.checkpoint(sid)
            ck_samples.append((time.perf_counter() - t0) * 1e6)
            t0 = time.perf_counter()
            eng.restore(sid)
            rs_samples.append((time.perf_counter() - t0) * 1e6)
        ck_us = statistics.median(ck_samples)
        rs_us = statistics.median(rs_samples)
        rows.append((f"checkpoint_ctx{ctx}_us", ck_us,
                     f"{-(-ctx // eng.page_size)}_pages_to_host"))
        rows.append((f"restore_ctx{ctx}_us", rs_us,
                     f"{-(-ctx // eng.page_size)}_pages_from_host"))
        rows.append((f"restore_vs_prefill_gain_ctx{ctx}",
                     prefill_us / rs_us, "prefill/restore"))
        eng.release(sid)
    return rows


def bench_demote_pressure() -> List[Tuple[str, float, str]]:
    eng = _engine(num_pages=64, page_size=4, max_pages_per_seq=16)
    sched = Scheduler(eng, SchedulerConfig(max_batch=8))
    # park held work covering most of the pool
    held = []
    for i in range(4):
        rid = sched.submit([i + 1, i + 2, i + 3, i + 4],
                           max_new_tokens=48)   # worst 13 pages each
        sched.admit()
        seq = sched.seq_of(rid)
        sched.hold(seq)
        held.append(seq)
    d0 = sched.stats().get("checkpointed", 0)
    # head request cannot fit without demotions
    rid = sched.submit(list(range(10, 26)), max_new_tokens=44)
    admitted = sched.admit()
    st = sched.stats()
    return [
        ("pressure_demotions", float(st.get("checkpointed", 0) - d0),
         "held_to_tier"),
        ("pressure_admitted", float(len(admitted)), "target_1"),
    ]


def run() -> List[Tuple[str, float, str]]:
    return (bench_prefix_sharing() + bench_checkpoint_restore()
            + bench_demote_pressure())


if __name__ == "__main__":
    for name, value, derived in run():
        print(f"{name},{value:.3f},{derived}")
