"""Serving-layer branch benchmarks: KV fork/CoW/commit at engine scale,
plus decode-step overhead with vs without active branches.

This is the paper's evaluation transplanted to the domain that matters
for agents on accelerators: forking a *generation* must be O(1) in
context length, CoW must cost one page copy, and first-commit-wins must
recycle loser pages.
"""

from __future__ import annotations

import dataclasses
import statistics
import time
from typing import List, Tuple

import jax

from repro.configs import get_config
from repro.models.model import Model
from repro.runtime.serve_loop import ServeEngine


def _median_us(fn, trials=8, inner=1) -> float:
    out = []
    for _ in range(trials):
        t0 = time.perf_counter()
        for _ in range(inner):
            fn()
        out.append((time.perf_counter() - t0) / inner * 1e6)
    return statistics.median(out)


def run() -> List[Tuple[str, float, str]]:
    cfg = dataclasses.replace(get_config("paper-agentic"), dtype="float32")
    model = Model(cfg, attn_chunk=8, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, num_pages=512, page_size=16,
                      max_pages_per_seq=24)
    root = eng.add_request(list(range(2, 50)))  # 48-token prompt

    rows: List[Tuple[str, float, str]] = []

    # fork/abort latency (host metadata only — zero-copy)
    def fork_abort():
        (c,) = eng.fork(root, 1)
        eng.abort(c)

    rows.append(("engine_fork_abort_us", _median_us(fork_abort, inner=10),
                 "zero-copy"))

    # decode with no branching (baseline) vs 4 live branches (batched)
    warm = eng.add_request([1, 2, 3])
    eng.decode([warm])  # compile
    t_plain = _median_us(lambda: eng.decode([warm]), trials=5)
    rows.append(("decode_1seq_us", t_plain, "baseline"))

    branches = eng.fork(root, 4)
    dispatches0, faults0 = eng.cow_dispatches, eng.cow_faults
    eng.decode(branches)  # triggers the CoW copies + compile for b=4
    # all sibling tail-page faults are serviced by ONE fused device
    # dispatch (the old path issued 2 jit calls per faulting page)
    rows.append(("cow_faults_first_branched_step",
                 float(eng.cow_faults - faults0), "shared_tail"))
    rows.append(("cow_dispatches_first_branched_step",
                 float(eng.cow_dispatches - dispatches0), "fused"))
    t_branched = _median_us(lambda: eng.decode(branches), trials=5)
    rows.append(("decode_4branches_us", t_branched,
                 "batched_siblings"))
    rows.append(("branch_decode_overhead_per_seq",
                 (t_branched / 4) / t_plain, "≈amortized"))

    # commit recycles losers
    t0 = time.perf_counter()
    eng.commit(branches[0])
    rows.append(("engine_commit_us", (time.perf_counter() - t0) * 1e6,
                 "first-commit-wins"))

    st = eng.stats()
    rows.append(("pages_shared_after_commit", float(st["pages_shared"]),
                 "prefix-sharing"))
    return rows
