"""Fused speculative verify vs k sequential verifier decode steps.

The verify phase of ``speculative_decode`` used to be a greedy verifier
branch decoding ``k`` tokens — ``k`` device dispatches plus a fork and
a branch's page footprint.  ``ServeEngine.spec_verify`` teacher-forces
every draft row through the target in ONE read-only pass over the
shared block table.  Rows:

* ``sequential_us``   — fork a verifier + k greedy decode steps (+ abort)
* ``fused_us``        — one ``spec_verify`` call, same drafts
* ``speedup``         — sequential / fused wall-clock
* ``fused_dispatches``— device dispatches the fused verify costs (1)
* ``policy_*``        — end-to-end ``speculative_decode`` acceptance
  stats through the driver, confirming the rewritten policy verifies
  with one dispatch per round.
"""

from __future__ import annotations

import dataclasses
import statistics
import time
from typing import List, Tuple

import jax

from repro.configs import get_config
from repro.models.model import Model
from repro.runtime.serve_loop import ServeEngine

DRAFT_TOKENS = (4, 8)
N_DRAFTS = 3


def _engine(**kw):
    cfg = dataclasses.replace(get_config("paper-agentic"), dtype="float32")
    model = Model(cfg, attn_chunk=8, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    kw.setdefault("num_pages", 256)
    kw.setdefault("page_size", 4)
    kw.setdefault("max_pages_per_seq", 32)
    return ServeEngine(model, params, **kw)


def run() -> List[Tuple[str, float, str]]:
    rows: List[Tuple[str, float, str]] = []
    eng = _engine(attn_impl="fused_ref")
    root = eng.add_request(list(range(2, 15)))
    eng.decode([root])
    key = jax.random.PRNGKey(1)

    for k in DRAFT_TOKENS:
        # drafts: what the policy would have sampled (content does not
        # matter for timing; teacher-forcing cost is draft-independent)
        drafts = [[(7 * i + j) % eng.cfg.vocab_size for j in range(k)]
                  for i in range(N_DRAFTS)]

        def sequential() -> List[int]:
            (ver,) = eng.fork(root, 1)
            out = [eng.decode([ver])[0] for _ in range(k)]
            eng.abort(ver)
            eng.kv.tree.reap(ver)
            return out

        def fused() -> List[List[int]]:
            return eng.spec_verify(root, drafts)

        sequential(); fused()        # warm both compile caches
        seq_us = []
        for _ in range(5):
            t0 = time.perf_counter()
            sequential()
            seq_us.append((time.perf_counter() - t0) * 1e6)
        d0 = eng.verify_dispatches
        fus_us = []
        for _ in range(5):
            t0 = time.perf_counter()
            fused()
            fus_us.append((time.perf_counter() - t0) * 1e6)
        per_call = (eng.verify_dispatches - d0) / 5
        seq_m, fus_m = statistics.median(seq_us), statistics.median(fus_us)
        rows.append((f"k{k}_sequential_us", seq_m, f"{k}_decode_steps"))
        rows.append((f"k{k}_fused_us", fus_m, "one_spec_verify"))
        rows.append((f"k{k}_speedup", seq_m / fus_m, "sequential/fused"))
        rows.append((f"k{k}_fused_dispatches", per_call, "target_1"))

    # end-to-end policy: acceptance through the exploration driver
    from repro.explore_ctx.driver import ExplorationDriver
    from repro.explore_ctx.speculative import speculative_decode

    eng2 = _engine(attn_impl="fused_ref")
    drv = ExplorationDriver(eng2)
    res = drv.explore([9, 8, 7], 12, speculative_decode, n_drafts=3,
                      draft_tokens=6, temperature=1.5).run()
    rows.append(("policy_accepted", float(res.stats["accepted"]),
                 "of_6_draft_tokens"))
    rows.append(("policy_verify_dispatches",
                 float(eng2.verify_dispatches), "one_per_round"))
    return rows


if __name__ == "__main__":
    for name, value, derived in run():
        print(f"{name},{value:.3f},{derived}")
