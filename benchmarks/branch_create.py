"""Paper Table 4 — branch creation latency vs base size (O(1) claim).

Three state domains:
* BranchStore (in-memory pytree store) fork vs number of leaves;
* BranchFS (on-disk) create vs number of files in base;
* KVBranchManager fork vs context length (pages in the block table).

Paper claim: creation stays < 350 µs and is independent of base size.
"""

from __future__ import annotations

import statistics
import tempfile
import time
from typing import Callable, Dict, List, Tuple

from repro.core import BranchStore, KVBranchManager
from repro.fs import BranchFS


def _median_us(fn: Callable[[], None], trials: int = 10,
               inner: int = 1) -> float:
    times = []
    for _ in range(trials):
        t0 = time.perf_counter()
        for _ in range(inner):
            fn()
        times.append((time.perf_counter() - t0) / inner * 1e6)
    return statistics.median(times)


def bench_store_fork() -> List[Tuple[str, float, str]]:
    rows = []
    for n in (100, 1_000, 10_000):
        store = BranchStore({f"f{i}": i for i in range(n)})
        us = _median_us(lambda: store.abort(store.fork()[0]), trials=10,
                        inner=20)
        rows.append((f"store_fork_base{n}", us, "O(1)-in-base"))
    return rows


def bench_fs_create() -> List[Tuple[str, float, str]]:
    rows = []
    for n in (100, 1_000, 10_000):
        with tempfile.TemporaryDirectory() as td:
            fs = BranchFS(td)
            for i in range(n):
                fs.write("base", f"f{i}", b"x" * 64)

            def one():
                (b,) = fs.create()
                fs.abort(b)

            us = _median_us(one, trials=10, inner=3)
            rows.append((f"branchfs_create_base{n}", us,
                         "paper_T4<350us"))
    return rows


def bench_kv_fork() -> List[Tuple[str, float, str]]:
    rows = []
    for ctx in (1_024, 8_192, 32_768):
        kv = KVBranchManager(num_pages=ctx // 16 + 64, page_size=16)
        sid = kv.new_seq(length=ctx)

        def one():
            (c,) = kv.fork(sid)
            kv.abort(c)

        us = _median_us(one, trials=10, inner=10)
        rows.append((f"kv_fork_ctx{ctx}", us, "zero-copy"))
    return rows


def run() -> List[Tuple[str, float, str]]:
    return bench_store_fork() + bench_fs_create() + bench_kv_fork()
