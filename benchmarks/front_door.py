"""Front-door load: the HTTP/SSE serving stack under mixed tenancy.

A closed-loop generator drives a REAL socket server (the same code path
``python -m repro.launch.serve --serve`` boots) with ≥64 concurrent
client streams across 4 tenant classes:

* ``vip`` (priority 3)  — chat, tight TTFT expectations;
* ``pro`` (priority 2)  — best-of-N explorations;
* ``batch`` (priority 1) — speculative decodes plus parked
  reservation-holders (the preemption victims);
* ``free`` (priority 1) — chat behind a 2-deep concurrency quota, so
  the 429 path is exercised under load, not just in unit tests.

Reported per tenant: p50/p99 time-to-first-token and tokens streamed;
aggregate: client-observed tokens/s and requests/s.  The run asserts
the serving invariants while measuring them — every stream terminates
in ``finished``/``result``/``evicted`` (never an engine error, never a
mid-decode ``-ENOSPC``), preemption only ever evicts parked or
speculative work, and shutdown drains to an empty registry.
"""

from __future__ import annotations

import asyncio
import dataclasses
from typing import Dict, List, Tuple

import jax

STREAMS = 64          # concurrent client coroutines
REQUESTS_EACH = 2     # closed-loop requests per stream
MAX_NEW = 12


def _build_front_door():
    from repro.api import BranchSession
    from repro.configs import get_config
    from repro.models.model import Model
    from repro.runtime.serve_loop import ServeEngine
    from repro.server import FrontDoor, TenantConfig

    cfg = dataclasses.replace(get_config("paper-agentic"), dtype="float32")
    model = Model(cfg, attn_chunk=8, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, num_pages=96, page_size=8,
                         max_pages_per_seq=16)
    session = BranchSession(engine, max_batch=16, seed=3)
    return FrontDoor(session, [
        TenantConfig("vip", max_concurrent=32, priority=3),
        TenantConfig("pro", max_concurrent=32, priority=2),
        TenantConfig("batch", max_concurrent=32, priority=1),
        TenantConfig("free", max_concurrent=2, priority=1),
    ])


async def _one_request(client, tenant: str, kind: str, seed: int,
                       out: Dict[str, List]) -> None:
    """One closed-loop request; records TTFT and terminal event."""
    import time

    from repro.server import ServeError

    prompt = [1 + (seed * 7 + i) % 400 for i in range(4)]
    body = {"tenant": tenant, "prompt": prompt,
            "max_new_tokens": MAX_NEW, "stream": True}
    if kind == "chat":
        path = "/v1/generate"
    else:
        path = "/v1/explore"
        body["policy"] = kind
        body["params"] = ({"n": 3, "tokens": 6} if kind == "best_of_n"
                          else {"n_drafts": 2, "draft_tokens": 4})
    for _attempt in range(1200):   # closed loop: retry 429s patiently
        t0 = time.perf_counter()
        ttft = None
        terminal = None
        tokens = 0
        try:
            async for event, data in client.stream("POST", path, body):
                if event == "token":
                    if ttft is None:
                        ttft = time.perf_counter() - t0
                    tokens += len(data.get("tokens", ()))
                elif event == "response":   # non-SSE reply: an error doc
                    status = data.get("status", 500)
                    raise ServeError(status, data)
                elif event in ("finished", "result", "evicted", "error"):
                    terminal = event
        except ServeError as err:
            if err.status == 429:           # closed loop: retry later
                out["quota_429"].append(tenant)
                await asyncio.sleep(0.1)
                continue
            raise
        out["terminal"].append((tenant, kind, terminal))
        out["tokens"].append((tenant, tokens))
        if ttft is not None:
            out["ttft"].append((tenant, ttft))
        return
    out["terminal"].append((tenant, kind, "starved"))


async def _load(fd) -> Tuple[Dict[str, List], float, int]:
    import time

    from repro.server import ServeClient

    server = await fd.serve("127.0.0.1", 0)
    port = server.sockets[0].getsockname()[1]
    client = ServeClient(f"127.0.0.1:{port}")

    # parked reservation-holders: what preemption will reclaim
    held = []
    for i in range(4):
        r = await client.hold([2, 3, 5, 7], tenant="batch",
                              max_new_tokens=MAX_NEW)
        held.append(r["id"])

    plan: List[Tuple[str, str]] = []
    for i in range(STREAMS):
        if i % 4 == 0:
            plan.append(("vip", "chat"))
        elif i % 4 == 1:
            plan.append(("pro", "best_of_n"))
        elif i % 4 == 2:
            plan.append(("batch", "speculative"))
        else:
            plan.append(("free", "chat"))

    out: Dict[str, List] = {"ttft": [], "tokens": [], "terminal": [],
                            "quota_429": []}

    async def stream_worker(idx: int, tenant: str, kind: str) -> None:
        for r in range(REQUESTS_EACH):
            await _one_request(client, tenant, kind, idx * 31 + r, out)

    t0 = time.perf_counter()
    await asyncio.gather(*(stream_worker(i, t, k)
                           for i, (t, k) in enumerate(plan)))
    elapsed = time.perf_counter() - t0

    # the held reservations may have been preempted; whatever survived
    # is evicted by the graceful drain — registry must end empty
    stats = await fd.shutdown(drain=True, timeout=120)
    leftover = len(fd.registry.live)
    if leftover:
        raise AssertionError(
            f"drain left {leftover} live records ({stats})")
    return out, elapsed, len(held)


def _pct(xs: List[float], q: float) -> float:
    if not xs:
        return 0.0
    xs = sorted(xs)
    return xs[min(len(xs) - 1, int(q * len(xs)))]


def run():
    fd = _build_front_door()
    out, elapsed, n_held = asyncio.run(_load(fd))

    bad = [t for t in out["terminal"] if t[2] in ("error", "starved", None)]
    if bad:
        raise AssertionError(f"streams did not finish cleanly: {bad[:5]}")
    # preemption victims must be held/speculative only: chat and
    # best_of_n streams may never see an eviction
    evicted_kinds = {kind for _, kind, term in out["terminal"]
                     if term == "evicted"}
    if evicted_kinds - {"speculative"}:
        raise AssertionError(
            f"non-preemptible work was evicted: {evicted_kinds}")

    snap = fd.session.obs.metrics.snapshot()
    counters = snap.get("counters", {})

    total_tokens = sum(n for _, n in out["tokens"])
    yield ("streams", float(STREAMS), f"{REQUESTS_EACH} req each")
    yield ("tokens_per_s", total_tokens / max(elapsed, 1e-9),
           f"{total_tokens} tokens over {elapsed:.1f}s, one engine")
    yield ("requests_per_s", len(out["terminal"]) / max(elapsed, 1e-9),
           f"{len(out['terminal'])} streams completed")
    tenants = sorted({t for t, _ in out["ttft"]})
    for tenant in tenants:
        ts = [x * 1e6 for t, x in out["ttft"] if t == tenant]
        toks = sum(n for t, n in out["tokens"] if t == tenant)
        yield (f"{tenant}_ttft_p50_us", _pct(ts, 0.50),
               f"n={len(ts)} first-token latencies")
        yield (f"{tenant}_ttft_p99_us", _pct(ts, 0.99),
               f"{toks} tokens streamed")
    yield ("quota_429", float(len(out["quota_429"])),
           "closed-loop retries (free tenant, quota 2)")
    yield ("preemptions", float(counters.get("server.preemptions", 0)),
           f"victims among {n_held} parked + speculative drafts")
    yield ("clean_drain", 1.0, "registry empty after graceful shutdown")
