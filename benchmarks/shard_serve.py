"""Sharded-serving throughput: the decode hot loop on a tp mesh.

Proves the DESIGN §11 scaling claims on forced-host-device CPU meshes
(the same harness the distributed tests use):

* per-step decode latency and tokens/s for tp=1 vs tp=2 through a
  branched continuous batch;
* the fork/commit cost model is mesh-invariant — one vectorized
  ``branch()`` fan-out still services its CoW plan in exactly ONE fused
  ``_copy_pages`` dispatch under ``shard_map`` (asserted, then
  reported);
* tp=2 tokens are bit-identical to tp=1 (asserted in the subprocess).

Each tp width runs in a subprocess because
``--xla_force_host_platform_device_count`` must be set before JAX
initializes — the parent process (and every other benchmark in the
``run.py`` sweep) keeps seeing the normal device set.  CPU "shards" of
one physical core measure dispatch/partitioning overhead, not speedup;
the derived column carries the dispatch counts that must stay flat.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path
from typing import List, Tuple

_WORKER = r"""
import os, sys, json
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%(tp)d"
import dataclasses, time
import jax
from repro.configs import get_config
from repro.models.model import Model
from repro.runtime.scheduler import Scheduler, SchedulerConfig
from repro.runtime.serve_loop import ServeEngine

tp = %(tp)d
cfg = dataclasses.replace(get_config("paper-agentic"), dtype="float32")
model = Model(cfg, attn_chunk=8, remat=False)
params = model.init(jax.random.PRNGKey(0))
eng = ServeEngine(model, params, num_pages=512, page_size=16,
                  max_pages_per_seq=24, tp=tp)
sched = Scheduler(eng, SchedulerConfig(max_batch=8))
rids = [sched.submit(list(range(3 + r, 11 + r)), max_new_tokens=32)
        for r in range(2)]
sched.admit()

# vectorized fan-out: 4 branches per request, ONE fused CoW dispatch each
cow0 = eng.cow_dispatches
branches = []
for rid in rids:
    branches.extend(sched.fork(sched.seq_of(rid), 4, eager_cow=True))
fork_dispatches = eng.cow_dispatches - cow0
assert fork_dispatches == len(rids), (fork_dispatches, len(rids))

tokens = [eng.decode(branches)]          # untimed: compile
cow_before = eng.cow_dispatches
t0 = time.perf_counter()
steps = 8
for _ in range(steps):
    tokens.append(eng.decode(branches))
dt = time.perf_counter() - t0
json.dump({
    "tp": tp,
    "devices": len(jax.devices()),
    "us_per_step": dt / steps * 1e6,
    "tokens_per_s": len(branches) * steps / dt,
    "fork_cow_dispatches_per_fanout": fork_dispatches / len(rids),
    "decode_cow_dispatches": eng.cow_dispatches - cow_before,
    "tokens": tokens,
}, sys.stdout)
"""


def _run_tp(tp: int) -> dict:
    repo = Path(__file__).resolve().parent.parent
    env = dict(os.environ)
    env["PYTHONPATH"] = str(repo / "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    r = subprocess.run(
        [sys.executable, "-c", _WORKER % {"tp": tp}],
        capture_output=True, text=True, env=env, timeout=900)
    if r.returncode != 0:
        raise RuntimeError(f"tp={tp} worker failed:\n{r.stderr[-4000:]}")
    return json.loads(r.stdout)


def run() -> List[Tuple[str, float, str]]:
    rows: List[Tuple[str, float, str]] = []
    results = {tp: _run_tp(tp) for tp in (1, 2)}
    # the acceptance property: same seed => same tokens across meshes
    assert results[1]["tokens"] == results[2]["tokens"], \
        "tp=2 tokens diverged from tp=1"
    for tp, res in results.items():
        rows.append((f"tp{tp}_us_per_step", res["us_per_step"],
                     f"{res['devices']}dev"))
        rows.append((f"tp{tp}_tokens_per_s", res["tokens_per_s"],
                     "8way_branched"))
        rows.append((f"tp{tp}_fork_cow_dispatches",
                     res["fork_cow_dispatches_per_fanout"],
                     "per_4way_fanout_fused"))
    rows.append(("tp_token_identical", 1.0, "tp1_vs_tp2"))
    return rows
