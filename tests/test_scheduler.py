"""Scheduler + cross-domain lifecycle at the serving layer.

Covers the engine/scheduler split (admission, continuous batching,
page-budget-aware fork admission), the fused CoW fault service (one
device dispatch per decode step), and cross-domain atomicity: a raced
``BranchRuntime.commit`` where the KV domain loses must strand no token
tails and leak no page refcounts.
"""

import dataclasses

import jax
import pytest

from repro.configs import get_config
from repro.core import BranchRuntime, BranchStore, BR_KV, BR_STATE
from repro.core.branch import root_context
from repro.core.errors import StaleBranchError
from repro.models.model import Model
from repro.runtime.scheduler import AdmissionDenied, Scheduler, SchedulerConfig
from repro.runtime.serve_loop import ServeEngine


@pytest.fixture(scope="module")
def engine_setup():
    cfg = dataclasses.replace(get_config("paper-agentic"), dtype="float32")
    model = Model(cfg, attn_chunk=8, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def fresh_engine(engine_setup, **kw):
    cfg, model, params = engine_setup
    kw.setdefault("num_pages", 128)
    kw.setdefault("page_size", 4)
    kw.setdefault("max_pages_per_seq", 16)
    return ServeEngine(model, params, **kw)


def pages_for(eng, n_tokens):
    return -(-n_tokens // eng.page_size)


# ---------------------------------------------------------------------------
# fused CoW fault service
# ---------------------------------------------------------------------------

def test_cow_faults_serviced_in_one_dispatch(engine_setup):
    eng = fresh_engine(engine_setup)
    root = eng.add_request([7, 8, 9])     # 2 cached tokens: mid-page tail
    branches = eng.fork(root, 3)
    d0, f0 = eng.cow_dispatches, eng.cow_faults
    eng.decode(branches)
    # every sibling CoW-faults the shared tail page, all in ONE dispatch
    assert eng.cow_faults == f0 + 3
    assert eng.cow_dispatches == d0 + 1
    # after the fault the tails are private: no further dispatches
    eng.decode(branches)
    assert eng.cow_dispatches == d0 + 1


def test_cow_batched_equals_unbatched_decode(engine_setup):
    prompt = [11, 22, 33]
    ctrl = fresh_engine(engine_setup)
    c = ctrl.add_request(prompt)
    want = [ctrl.decode([c])[0] for _ in range(3)]

    eng = fresh_engine(engine_setup)
    root = eng.add_request(prompt)
    b1, b2, b3 = eng.fork(root, 3)
    for _ in range(3):
        eng.decode([b1, b2, b3])          # fused CoW on the first step
    assert eng.tokens(b1)[3:] == eng.tokens(b2)[3:] == want


# ---------------------------------------------------------------------------
# scheduler: admission + continuous batching + retirement
# ---------------------------------------------------------------------------

def test_continuous_batching_matches_unscheduled_decode(engine_setup):
    ctrl = fresh_engine(engine_setup)
    c = ctrl.add_request([1, 2, 3])
    want = [ctrl.decode([c])[0] for _ in range(3)]

    eng = fresh_engine(engine_setup)
    sched = Scheduler(eng, SchedulerConfig(max_batch=4))
    r1 = sched.submit([1, 2, 3], max_new_tokens=3)
    r2 = sched.submit([9, 8, 7, 6], max_new_tokens=5)
    produced = sched.run(max_steps=20)
    assert produced == 3 + 5
    assert sched.result(r1) == [1, 2, 3] + want
    assert len(sched.result(r2)) == 4 + 5
    # retirement released every page and token tail
    st = sched.stats()
    assert st["sequences_live"] == 0
    assert st["token_tails"] == 0
    assert st["pages_free"] == st["pages_total"]


def test_admission_waits_for_page_budget(engine_setup):
    eng = fresh_engine(engine_setup, num_pages=5)
    sched = Scheduler(eng, SchedulerConfig(max_batch=4, decode_reserve=2))
    r1 = sched.submit(list(range(1, 9)), max_new_tokens=2)   # 2 pages
    r2 = sched.submit(list(range(11, 19)), max_new_tokens=2)
    st = sched.step()
    assert st["admitted"] == 1                # r2 must wait: 3 < 2+2 free
    assert st["waiting"] == 1
    sched.run(max_steps=20)
    assert len(sched.result(r1)) == 10
    assert len(sched.result(r2)) == 10        # admitted after r1 retired


def test_fork_admission_page_budget(engine_setup):
    eng = fresh_engine(engine_setup, num_pages=8)
    sched = Scheduler(eng, SchedulerConfig(decode_reserve=1))
    rid = sched.submit(list(range(1, 9)), max_new_tokens=64)
    sched.admit()
    seq = sched.seq_of(rid)
    with pytest.raises(AdmissionDenied):
        sched.fork(seq, 20)                   # would overrun the pool
    children = sched.fork(seq, 2)
    # frozen origin waits; children join the running batch
    assert set(sched.runnable()) == set(children)


def test_scheduler_observes_kernel_commit(engine_setup):
    eng = fresh_engine(engine_setup)
    sched = Scheduler(eng, SchedulerConfig(max_batch=8))
    rid = sched.submit([2, 4, 6, 8], max_new_tokens=64)
    sched.admit()
    seq = sched.seq_of(rid)
    b1, b2 = sched.fork(seq, 2)
    sched.step()
    eng.commit(b1)        # kernel-level first-commit-wins
    # next round: loser + winner dropped, parent resumed and runnable
    assert sched.runnable() == [seq]
    sched.step()
    assert len(eng.tokens(seq)) == 6  # prompt + forked step + parent step


# ---------------------------------------------------------------------------
# cross-domain atomicity (store + KV + token tails)
# ---------------------------------------------------------------------------

def test_raced_runtime_commit_kv_loser_strands_nothing(engine_setup):
    """If the KV domain already lost a kernel-level race, the composite
    commit must lose atomically: no stranded token tails, no leaked page
    refcounts."""
    eng = fresh_engine(engine_setup)
    store = BranchStore({"plan": b"root"})
    runtime = BranchRuntime(store, eng.kv)
    root_ctx = root_context(store)

    seq = eng.add_request([5, 6, 7, 8, 9])
    eng.decode([seq])
    h1, h2 = runtime.create(root_ctx, 2, flags=BR_STATE | BR_KV,
                            kv_seqs=[seq])
    c1, c2 = h1.kv_seqs[seq], h2.kv_seqs[seq]
    eng.decode([c1, c2])

    eng.commit(c2)                      # sibling wins at the kernel level
    winner_tokens = eng.tokens(seq)
    with pytest.raises(StaleBranchError):
        runtime.commit(h1)              # composite commit loses everywhere

    st = eng.stats()
    assert st["token_tails"] == 1       # only the promoted root tail
    assert st["sequences_live"] == 1
    used = st["pages_total"] - st["pages_free"]
    assert used == pages_for(eng, eng.kv.length(seq))  # no leaked refs
    assert eng.tokens(seq) == winner_tokens
    assert h1._resolved                 # loser fully unwound
    assert not h1.state.is_active


def test_impossible_request_rejected_at_submit(engine_setup):
    eng = fresh_engine(engine_setup, num_pages=4)
    sched = Scheduler(eng, SchedulerConfig(decode_reserve=2))
    with pytest.raises(AdmissionDenied):
        sched.submit(list(range(100)))   # can never fit the pool
    # the FIFO head is not blocked: a feasible request still flows
    rid = sched.submit([1, 2, 3], max_new_tokens=1)
    sched.run(max_steps=4)
    assert len(sched.result(rid)) == 4


def test_frozen_kv_child_refused_before_state_commit():
    """A composite commit whose KV branch has nested live children must
    refuse up front — not half-commit the state domain."""
    from repro.core import KVBranchManager
    from repro.core.errors import BranchStateError

    store = BranchStore({"plan": b"root"})
    kv = KVBranchManager(num_pages=16, page_size=4)
    runtime = BranchRuntime(store, kv)
    root_ctx = root_context(store)
    seq = kv.new_seq(length=4)
    (h,) = runtime.create(root_ctx, 1, flags=BR_STATE | BR_KV,
                          kv_seqs=[seq])
    kv.fork(h.kv_seqs[seq], 2)           # nested children freeze the branch
    with pytest.raises(BranchStateError):
        runtime.commit(h)
    # nothing half-committed: state branch still live, store unchanged
    assert h.state.is_active
    assert not h._resolved
    assert root_ctx.read("plan") == b"root"


def test_state_cas_loss_unwinds_kv_domain():
    """If the *store* domain loses the epoch CAS, the composite commit
    must also lose the KV domain: no live forked sequences survive."""
    from repro.core import KVBranchManager

    store = BranchStore({"plan": b"root"})
    kv = KVBranchManager(num_pages=16, page_size=4)
    runtime = BranchRuntime(store, kv)
    root_ctx = root_context(store)
    seq = kv.new_seq(length=4)

    (h_kv,) = runtime.create(root_ctx, 1, flags=BR_STATE | BR_KV,
                             kv_seqs=[seq])
    kv.prepare_append(h_kv.kv_seqs[seq], 3)
    (h_state,) = runtime.create(root_ctx, 1)   # state-only sibling
    runtime.commit(h_state)                    # bumps the store epoch
    with pytest.raises(StaleBranchError):
        runtime.commit(h_kv)
    assert h_kv._resolved
    assert not kv.is_live(h_kv.kv_seqs[seq])   # pages unwound, not stranded
    st = kv.stats()
    assert st["sequences_live"] == 1           # only the original root seq
    assert st["pages_total"] - st["pages_free"] == 1  # ceil(4/4) pages


def test_raced_runtime_commits_store_decides_once(engine_setup):
    """Two handles race through the runtime itself: the loser raises
    -ESTALE and every domain (store delta, pages, tokens) is reclaimed."""
    eng = fresh_engine(engine_setup)
    store = BranchStore({"plan": b"root"})
    runtime = BranchRuntime(store, eng.kv)
    root_ctx = root_context(store)

    seq = eng.add_request([1, 3, 5, 7])
    h1, h2 = runtime.create(root_ctx, 2, flags=BR_STATE | BR_KV,
                            kv_seqs=[seq])
    eng.decode([h1.kv_seqs[seq], h2.kv_seqs[seq]])
    h2.state.write("plan", b"h2-wins")
    runtime.commit(h2)
    with pytest.raises(StaleBranchError):
        runtime.commit(h1)

    assert root_ctx.read("plan") == b"h2-wins"
    st = eng.stats()
    assert st["token_tails"] == 1
    assert st["sequences_live"] == 1
    used = st["pages_total"] - st["pages_free"]
    assert used == pages_for(eng, eng.kv.length(seq))
