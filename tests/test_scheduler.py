"""Scheduler + cross-domain lifecycle at the serving layer.

Covers the engine/scheduler split (admission, continuous batching,
page-budget-aware fork admission), the fused CoW fault service (one
device dispatch per decode step), and cross-domain atomicity: a raced
``BranchRuntime.commit`` where the KV domain loses must strand no token
tails and leak no page refcounts.
"""

import dataclasses

import jax
import pytest

from repro.configs import get_config
from repro.core import BranchRuntime, BranchStore, BR_KV, BR_STATE
from repro.core.branch import root_context
from repro.core.errors import StaleBranchError
from repro.models.model import Model
from repro.runtime.scheduler import AdmissionDenied, Scheduler, SchedulerConfig
from repro.runtime.serve_loop import ServeEngine


@pytest.fixture(scope="module")
def engine_setup():
    cfg = dataclasses.replace(get_config("paper-agentic"), dtype="float32")
    model = Model(cfg, attn_chunk=8, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def fresh_engine(engine_setup, **kw):
    cfg, model, params = engine_setup
    kw.setdefault("num_pages", 128)
    kw.setdefault("page_size", 4)
    kw.setdefault("max_pages_per_seq", 16)
    return ServeEngine(model, params, **kw)


def pages_for(eng, n_tokens):
    return -(-n_tokens // eng.page_size)


# ---------------------------------------------------------------------------
# fused CoW fault service
# ---------------------------------------------------------------------------

def test_cow_faults_serviced_in_one_dispatch(engine_setup):
    eng = fresh_engine(engine_setup)
    root = eng.add_request([7, 8, 9])     # 2 cached tokens: mid-page tail
    branches = eng.fork(root, 3)
    d0, f0 = eng.cow_dispatches, eng.cow_faults
    eng.decode(branches)
    # every sibling CoW-faults the shared tail page, all in ONE dispatch
    assert eng.cow_faults == f0 + 3
    assert eng.cow_dispatches == d0 + 1
    # after the fault the tails are private: no further dispatches
    eng.decode(branches)
    assert eng.cow_dispatches == d0 + 1


def test_cow_batched_equals_unbatched_decode(engine_setup):
    prompt = [11, 22, 33]
    ctrl = fresh_engine(engine_setup)
    c = ctrl.add_request(prompt)
    want = [ctrl.decode([c])[0] for _ in range(3)]

    eng = fresh_engine(engine_setup)
    root = eng.add_request(prompt)
    b1, b2, b3 = eng.fork(root, 3)
    for _ in range(3):
        eng.decode([b1, b2, b3])          # fused CoW on the first step
    assert eng.tokens(b1)[3:] == eng.tokens(b2)[3:] == want


# ---------------------------------------------------------------------------
# scheduler: admission + continuous batching + retirement
# ---------------------------------------------------------------------------

def test_continuous_batching_matches_unscheduled_decode(engine_setup):
    ctrl = fresh_engine(engine_setup)
    c = ctrl.add_request([1, 2, 3])
    want = [ctrl.decode([c])[0] for _ in range(3)]

    eng = fresh_engine(engine_setup)
    sched = Scheduler(eng, SchedulerConfig(max_batch=4))
    r1 = sched.submit([1, 2, 3], max_new_tokens=3)
    r2 = sched.submit([9, 8, 7, 6], max_new_tokens=5)
    produced = sched.run(max_steps=20)
    assert produced == 3 + 5
    assert sched.result(r1) == [1, 2, 3] + want
    assert len(sched.result(r2)) == 4 + 5
    # retirement released every page and token tail
    st = sched.stats()
    assert st["sequences_live"] == 0
    assert st["token_tails"] == 0
    assert st["pages_free"] == st["pages_total"]


def test_admission_waits_for_page_budget(engine_setup):
    eng = fresh_engine(engine_setup, num_pages=5)
    sched = Scheduler(eng, SchedulerConfig(max_batch=4))
    # each request reserves its worst case: ceil((8+2)/4) = 3 of 5 pages
    r1 = sched.submit(list(range(1, 9)), max_new_tokens=2)
    r2 = sched.submit(list(range(11, 19)), max_new_tokens=2)
    st = sched.step()
    assert st["admitted"] == 1                # r2 must wait: 3 + 3 > 5
    assert st["waiting"] == 1
    sched.run(max_steps=20)
    assert len(sched.result(r1)) == 10
    assert len(sched.result(r2)) == 10        # admitted after r1 retired


def test_admission_accounts_for_decode_budget(engine_setup):
    # a generation longer than the pool can ever hold must be -EAGAIN'd
    # at submit, not -ENOSPC'd (and state-corrupted) mid-decode
    eng = fresh_engine(engine_setup, num_pages=8)
    sched = Scheduler(eng)
    with pytest.raises(AdmissionDenied):
        sched.submit(list(range(1, 9)), max_new_tokens=40)  # 12 > 8 pages


def test_oversize_decode_budget_rejected_at_submit(engine_setup):
    # worst case exceeding the per-sequence block table can never decode
    # to completion (dense_block_tables would blow up) -> reject up front
    eng = fresh_engine(engine_setup, num_pages=128, max_pages_per_seq=4)
    sched = Scheduler(eng)
    with pytest.raises(AdmissionDenied):
        sched.submit([1, 2, 3, 4], max_new_tokens=16)       # 5 > 4 pages


def test_admitted_requests_always_complete(engine_setup):
    # the pool only fits one worst-case request at a time; the ledger
    # serializes them and every one decodes to its full budget
    eng = fresh_engine(engine_setup, num_pages=4)
    sched = Scheduler(eng)
    rids = [sched.submit([r + 1, r + 2], max_new_tokens=10)
            for r in range(3)]                # worst: 3 of 4 pages each
    sched.run(max_steps=60)
    for rid in rids:
        assert len(sched.result(rid)) == 12
    st = sched.stats()
    assert st["pages_free"] == st["pages_total"]
    assert st["pages_reserved"] == 0


def test_fork_admission_page_budget(engine_setup):
    eng = fresh_engine(engine_setup, num_pages=32)
    sched = Scheduler(eng)
    rid = sched.submit(list(range(1, 9)), max_new_tokens=8)  # worst 4
    sched.admit()
    seq = sched.seq_of(rid)
    with pytest.raises(AdmissionDenied):
        sched.fork(seq, 20)                   # 20*(4-2+1) > 32-4 budget
    children = sched.fork(seq, 2)
    # frozen origin waits; children join the running batch
    assert set(sched.runnable()) == set(children)


def test_scheduler_observes_kernel_commit(engine_setup):
    eng = fresh_engine(engine_setup)
    sched = Scheduler(eng, SchedulerConfig(max_batch=8))
    rid = sched.submit([2, 4, 6, 8], max_new_tokens=32)
    sched.admit()
    seq = sched.seq_of(rid)
    b1, b2 = sched.fork(seq, 2)
    sched.step()
    eng.commit(b1)        # kernel-level first-commit-wins
    # next round: loser + winner dropped, parent resumed and runnable
    assert sched.runnable() == [seq]
    sched.step()
    assert len(eng.tokens(seq)) == 6  # prompt + forked step + parent step


# ---------------------------------------------------------------------------
# cross-domain atomicity (store + KV + token tails)
# ---------------------------------------------------------------------------

def test_raced_runtime_commit_kv_loser_strands_nothing(engine_setup):
    """If the KV domain already lost a kernel-level race, the composite
    commit must lose atomically: no stranded token tails, no leaked page
    refcounts."""
    eng = fresh_engine(engine_setup)
    store = BranchStore({"plan": b"root"})
    runtime = BranchRuntime(store, eng.kv)
    root_ctx = root_context(store)

    seq = eng.add_request([5, 6, 7, 8, 9])
    eng.decode([seq])
    h1, h2 = runtime.create(root_ctx, 2, flags=BR_STATE | BR_KV,
                            kv_seqs=[seq])
    c1, c2 = h1.kv_seqs[seq], h2.kv_seqs[seq]
    eng.decode([c1, c2])

    eng.commit(c2)                      # sibling wins at the kernel level
    winner_tokens = eng.tokens(seq)
    with pytest.raises(StaleBranchError):
        runtime.commit(h1)              # composite commit loses everywhere

    st = eng.stats()
    assert st["token_tails"] == 1       # only the promoted root tail
    assert st["sequences_live"] == 1
    used = st["pages_total"] - st["pages_free"]
    assert used == pages_for(eng, eng.kv.length(seq))  # no leaked refs
    assert eng.tokens(seq) == winner_tokens
    assert h1._resolved                 # loser fully unwound
    assert not h1.state.is_active


def test_impossible_request_rejected_at_submit(engine_setup):
    eng = fresh_engine(engine_setup, num_pages=4)
    sched = Scheduler(eng)
    with pytest.raises(AdmissionDenied):
        sched.submit(list(range(100)))   # can never fit the pool
    # the FIFO head is not blocked: a feasible request still flows
    rid = sched.submit([1, 2, 3], max_new_tokens=1)
    sched.run(max_steps=4)
    assert len(sched.result(rid)) == 4


def test_frozen_kv_child_refused_before_state_commit():
    """A composite commit whose KV branch has nested live children must
    refuse up front — not half-commit the state domain."""
    from repro.core import KVBranchManager
    from repro.core.errors import BranchStateError

    store = BranchStore({"plan": b"root"})
    kv = KVBranchManager(num_pages=16, page_size=4)
    runtime = BranchRuntime(store, kv)
    root_ctx = root_context(store)
    seq = kv.new_seq(length=4)
    (h,) = runtime.create(root_ctx, 1, flags=BR_STATE | BR_KV,
                          kv_seqs=[seq])
    kv.fork(h.kv_seqs[seq], 2)           # nested children freeze the branch
    with pytest.raises(BranchStateError):
        runtime.commit(h)
    # nothing half-committed: state branch still live, store unchanged
    assert h.state.is_active
    assert not h._resolved
    assert root_ctx.read("plan") == b"root"


def test_state_cas_loss_unwinds_kv_domain():
    """If the *store* domain loses the epoch CAS, the composite commit
    must also lose the KV domain: no live forked sequences survive."""
    from repro.core import KVBranchManager

    store = BranchStore({"plan": b"root"})
    kv = KVBranchManager(num_pages=16, page_size=4)
    runtime = BranchRuntime(store, kv)
    root_ctx = root_context(store)
    seq = kv.new_seq(length=4)

    (h_kv,) = runtime.create(root_ctx, 1, flags=BR_STATE | BR_KV,
                             kv_seqs=[seq])
    kv.prepare_append(h_kv.kv_seqs[seq], 3)
    (h_state,) = runtime.create(root_ctx, 1)   # state-only sibling
    runtime.commit(h_state)                    # bumps the store epoch
    with pytest.raises(StaleBranchError):
        runtime.commit(h_kv)
    assert h_kv._resolved
    assert not kv.is_live(h_kv.kv_seqs[seq])   # pages unwound, not stranded
    st = kv.stats()
    assert st["sequences_live"] == 1           # only the original root seq
    assert st["pages_total"] - st["pages_free"] == 1  # ceil(4/4) pages


def test_raced_runtime_commits_store_decides_once(engine_setup):
    """Two handles race through the runtime itself: the loser raises
    -ESTALE and every domain (store delta, pages, tokens) is reclaimed."""
    eng = fresh_engine(engine_setup)
    store = BranchStore({"plan": b"root"})
    runtime = BranchRuntime(store, eng.kv)
    root_ctx = root_context(store)

    seq = eng.add_request([1, 3, 5, 7])
    h1, h2 = runtime.create(root_ctx, 2, flags=BR_STATE | BR_KV,
                            kv_seqs=[seq])
    eng.decode([h1.kv_seqs[seq], h2.kv_seqs[seq]])
    h2.state.write("plan", b"h2-wins")
    runtime.commit(h2)
    with pytest.raises(StaleBranchError):
        runtime.commit(h1)

    assert root_ctx.read("plan") == b"h2-wins"
    st = eng.stats()
    assert st["token_tails"] == 1
    assert st["sequences_live"] == 1
    used = st["pages_total"] - st["pages_free"]
    assert used == pages_for(eng, eng.kv.length(seq))


# ---------------------------------------------------------------------------
# transactional decode: -ENOSPC mutates nothing
# ---------------------------------------------------------------------------

def test_decode_enospc_mutates_nothing(engine_setup):
    """A pool exhausted on a *later* batch member must roll back the
    earlier members' slot reservations: lengths, tables, free list and
    token tails all stay exactly as before the failed step."""
    eng = fresh_engine(engine_setup, num_pages=3)
    a = eng.add_request([1, 2, 3, 4, 5])      # 1 full page, length 4
    b = eng.add_request([6, 7, 8, 9, 10])
    toks_a, toks_b = eng.tokens(a), eng.tokens(b)
    with pytest.raises(MemoryError):
        eng.decode([a, b])                    # both need a fresh page, 1 free
    assert eng.kv.length(a) == 4 and eng.kv.length(b) == 4
    assert len(eng.kv.block_table(a)) == 1
    assert eng.kv.free_pages == 1
    assert eng.tokens(a) == toks_a and eng.tokens(b) == toks_b
    # the length == tokens - 1 invariant survived: a alone still decodes
    eng.decode([a])
    assert eng.kv.length(a) == 5


def test_decode_cow_rollback_on_enospc(engine_setup):
    """A speculative CoW tail swap whose device copy never ran must be
    undone when a later batch member exhausts the pool."""
    eng = fresh_engine(engine_setup, num_pages=2)
    root = eng.add_request([1, 2, 3])         # mid-page shared tail
    b1, b2 = eng.fork(root, 2)
    tail = eng.kv.block_table(root)[-1]
    d0 = eng.cow_dispatches
    with pytest.raises(MemoryError):
        eng.decode([b1, b2])                  # two CoW faults, one free page
    # b1's CoW was rolled back: tail shared 3 ways again, page refunded
    assert eng.kv.refcount(tail) == 3
    assert eng.kv.block_table(b1) == eng.kv.block_table(root)
    assert eng.kv.free_pages == 1
    assert eng.kv.length(b1) == eng.kv.length(b2) == 2
    assert eng.cow_dispatches == d0           # no device copy was issued


def test_decode_refuses_table_overflow_without_mutation(engine_setup):
    """Outgrowing the per-sequence block table is refused before any
    metadata mutates — not discovered by dense_block_tables after the
    batch's slots were already reserved."""
    eng = fresh_engine(engine_setup, max_pages_per_seq=1)
    seq = eng.add_request([1, 2, 3, 4])
    eng.decode([seq])                         # fills the single page
    toks = eng.tokens(seq)
    with pytest.raises(ValueError):
        eng.decode([seq])                     # would need a second page
    assert eng.kv.length(seq) == 4
    assert len(eng.kv.block_table(seq)) == 1
    assert eng.tokens(seq) == toks


# ---------------------------------------------------------------------------
# kernel GC: resolved subtrees are reaped, host memory stays bounded
# ---------------------------------------------------------------------------

def test_resolved_branches_reaped_from_kernel(engine_setup):
    eng = fresh_engine(engine_setup)
    sched = Scheduler(eng)
    rid = sched.submit([2, 4, 6, 8], max_new_tokens=4)
    sched.admit()
    seq = sched.seq_of(rid)
    b1, b2 = sched.fork(seq, 2)
    sched.step()
    eng.commit(b1)
    sched.run(max_steps=10)
    assert sched.result(rid)                  # captured before release
    # retired + resolved work leaves no lifecycle nodes, payload entries
    # or request records — a long-running loop cannot grow without bound
    assert len(eng.kv.tree) == 0
    assert eng.kv._tables == {} and eng.kv._lengths == {}
    assert len(eng.token_domain) == 0
    assert sched._requests == {} and sched._results == {}
    with pytest.raises(Exception):
        sched.result(rid)                     # results are claimed once


def test_abort_of_tracked_subtree_observed_not_crashed(engine_setup):
    """An agent aborting an interior branch whose children the scheduler
    also tracks must be *observed*: the whole reaped subtree leaves
    tracking and the origin resumes decoding — no BranchStateError."""
    eng = fresh_engine(engine_setup)
    sched = Scheduler(eng)
    rid = sched.submit([1, 2, 3, 4], max_new_tokens=8)
    sched.admit()
    root = sched.seq_of(rid)
    (b,) = sched.fork(root, 1)
    sched.fork(b, 2)                          # nested exploration
    sched.step()
    eng.abort(b)                              # kills b and its children
    sched.step()                              # observes, must not crash
    assert sched.runnable() == [root]
    sched.run(max_steps=20)
    assert len(sched.result(rid)) == 12


def test_external_release_of_scheduled_request(engine_setup):
    """Evicting a scheduled request's root out from under the scheduler
    (serving-slot eviction) drops its tracking and request record."""
    eng = fresh_engine(engine_setup)
    sched = Scheduler(eng)
    rid = sched.submit([1, 2, 3, 4], max_new_tokens=8)
    r2 = sched.submit([5, 6, 7], max_new_tokens=2)
    sched.admit()
    eng.release(sched.seq_of(rid))            # evicted before finishing
    sched.run(max_steps=10)
    assert len(sched.result(r2)) == 5         # the other request finishes
    assert sched._requests == {} and sched._seq_owner == {}
    with pytest.raises(Exception):
        sched.result(rid)                     # evicted: no result to claim


def test_release_reaps_whole_subtree(engine_setup):
    eng = fresh_engine(engine_setup)
    root = eng.add_request([1, 2, 3, 4, 5])
    b1, b2 = eng.fork(root, 2)
    eng.decode([b1, b2])
    eng.release(root)                         # evict root + live children
    assert eng.stats()["pages_free"] == eng.stats()["pages_total"]
    assert len(eng.kv.tree) == 0
    assert eng.kv._tables == {} and eng.kv._lengths == {}


# ---------------------------------------------------------------------------
# BR_ISOLATE: sibling handles are not addressable
# ---------------------------------------------------------------------------

def test_br_isolate_blocks_sibling_handles():
    from repro.core.errors import BranchError
    from repro.core.runtime_api import BR_ISOLATE

    store = BranchStore({"plan": b"root"})
    runtime = BranchRuntime(store)
    root_ctx = root_context(store)
    h1, h2 = runtime.create(root_ctx, 2)
    assert len(h1.group) == 2                 # default: siblings visible
    i1, i2 = runtime.create(root_ctx, 2, flags=BR_STATE | BR_ISOLATE)
    with pytest.raises(BranchError):
        _ = i1.group                          # isolation enforced here
    (solo,) = runtime.create(root_ctx, 1, flags=BR_STATE | BR_ISOLATE)
    assert solo.group == (solo,)              # self is always addressable
