"""BranchContext subsystem: policies, driver multiplexing, nesting.

The acceptance bar for the exploration layer:

* every policy (best-of-N, beam, tree, speculative) runs through
  scheduler admission end-to-end and leaves a drained pool;
* >= 8 interleaved explorations race one scheduler without stranded
  reservations (the pool returns to empty after all resolve);
* aborting a parent context invalidates grandchildren across every
  domain (KV pages, token tails, and the composite store);
* permanent page pressure degrades policies instead of crashing them.
"""

import dataclasses

import jax
import pytest

from repro.configs import get_config
from repro.core import BranchStore
from repro.core.lifecycle import BranchStatus
from repro.models.model import Model
from repro.runtime.scheduler import AdmissionDenied, Scheduler, SchedulerConfig
from repro.runtime.serve_loop import ServeEngine
from repro.explore_ctx import (
    BranchContext,
    Decode,
    ExplorationDriver,
    Fork,
    Submit,
    beam_search,
    best_of_n,
    lcp_len,
    speculative_decode,
    tree_search,
)


@pytest.fixture(scope="module")
def engine_setup():
    cfg = dataclasses.replace(get_config("paper-agentic"), dtype="float32")
    model = Model(cfg, attn_chunk=8, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def fresh_engine(engine_setup, **kw):
    cfg, model, params = engine_setup
    kw.setdefault("num_pages", 128)
    kw.setdefault("page_size", 4)
    kw.setdefault("max_pages_per_seq", 16)
    return ServeEngine(model, params, **kw)


def fresh_driver(engine_setup, *, store=None, **kw):
    eng = fresh_engine(engine_setup, **kw)
    sched = Scheduler(eng, SchedulerConfig(max_batch=8, seed=3))
    return eng, sched, ExplorationDriver(sched, store=store)


def assert_drained(sched):
    st = sched.stats()
    assert st["pages_free"] == st["pages_total"]
    assert st["pages_reserved"] == 0
    assert st["running"] == 0 and st["held"] == 0
    assert st["token_tails"] == 0
    assert len(sched.engine.kv.tree) == 0


# ---------------------------------------------------------------------------
# policies end-to-end through admission
# ---------------------------------------------------------------------------

def test_best_of_n_end_to_end(engine_setup):
    eng, sched, drv = fresh_driver(engine_setup)
    exp = drv.explore([7, 3, 9], 8, best_of_n, n=3, tokens=4)
    res = exp.run()
    assert res.committed
    assert len(res.generated) == 4
    assert res.stats["branches"] == 3
    assert res.score == max(res.stats["scores"])
    assert exp.final_tokens == res.tokens   # finish() captured the same
    assert_drained(sched)


def test_beam_search_commits_per_level(engine_setup):
    eng, sched, drv = fresh_driver(engine_setup)
    res = drv.explore([5, 5, 5], 9, beam_search, width=2, depth=2,
                      tokens_per_level=4).run()
    assert len(res.generated) == 8          # depth * tokens_per_level
    assert len(res.stats["levels"]) == 2
    assert all(len(lv["scores"]) == 2 for lv in res.stats["levels"])
    assert_drained(sched)


def test_tree_search_nested_expansion(engine_setup):
    eng, sched, drv = fresh_driver(engine_setup)
    res = drv.explore([2, 4, 6], 13, tree_search, fan_out=2, max_nodes=6,
                      tokens_per_node=3, max_depth=3).run()
    assert res.committed
    assert res.stats["branches_created"] == 6
    depth = res.stats["winner_depth"]
    assert 1 <= depth <= 3
    assert len(res.generated) == 3 * depth  # the whole winning lineage
    assert_drained(sched)


def test_tree_search_early_abort_prunes(engine_setup):
    eng, sched, drv = fresh_driver(engine_setup)
    res = drv.explore([2, 4, 6], 13, tree_search, fan_out=3, max_nodes=6,
                      tokens_per_node=3, prune_below=1e9).run()
    # impossible bar: every branch pruned on the spot, origin kept
    assert not res.committed
    assert res.stats["pruned"] == res.stats["branches_created"]
    assert res.generated == []
    assert_drained(sched)


def test_speculative_decode_verified_prefix(engine_setup):
    eng, sched, drv = fresh_driver(engine_setup)
    res = drv.explore([9, 8, 7], 10, speculative_decode, n_drafts=2,
                      draft_tokens=5, temperature=2.0).run()
    accepted = res.stats["accepted"]
    assert 0 <= accepted <= 5
    if res.stats["fallback"]:
        # honest 0% acceptance: the parked fallback branch took one true
        # greedy step so the commit still made progress
        assert accepted == 0 and len(res.generated) == 1
    else:
        assert len(res.generated) == accepted   # the verified prefix
        # the verify phase was ONE fused dispatch, not k decode steps
        assert res.stats["verify_dispatches"] == 1
        assert eng.verify_dispatches == 1
    assert res.stats["acceptance_rate"] == accepted / 5
    assert_drained(sched)


# ---------------------------------------------------------------------------
# concurrency: interleaved explorations racing one scheduler
# ---------------------------------------------------------------------------

def test_interleaved_exploration_stress(engine_setup):
    """>= 8 concurrent BranchContext explorations on one engine: all
    resolve, no stranded reservations, pool drains to zero."""
    eng, sched, drv = fresh_driver(engine_setup, num_pages=96)
    exps = []
    for i in range(9):
        if i % 3 == 0:
            exps.append(drv.explore([i + 1, i + 2], 8, best_of_n,
                                    n=3, tokens=4))
        elif i % 3 == 1:
            exps.append(drv.explore([i + 1, i + 2], 9, beam_search,
                                    width=2, depth=2, tokens_per_level=4))
        else:
            exps.append(drv.explore([i + 1, i + 2], 10, tree_search,
                                    fan_out=2, max_nodes=4,
                                    tokens_per_node=3))
    drv.run()
    assert all(e.done and e.error is None for e in exps)
    assert all(e.result.generated for e in exps)
    # the searches really interleaved: far fewer driver rounds than the
    # serial sum of each exploration's own decode schedule
    assert drv.steps < 40
    assert_drained(sched)


def test_backpressure_degrades_not_crashes(engine_setup):
    """A pool too small for everyone's fan-out: forks see backpressure,
    some policies degrade to unforked decoding, everything completes."""
    eng, sched, drv = fresh_driver(engine_setup, num_pages=40)
    exps = [drv.explore([i + 1, i + 2, i + 3], 12,
                        best_of_n, n=3, tokens=4) for i in range(8)]
    drv.run()
    assert all(e.done and e.error is None for e in exps)
    degraded = [e for e in exps if e.result.stats.get("degraded")]
    committed = [e for e in exps if e.result.committed]
    assert len(degraded) + len(committed) == 8
    assert committed                       # pressure didn't kill everyone
    assert_drained(sched)


def test_root_decode_to_exact_budget(engine_setup):
    """A policy that decodes the root to exactly its request budget: the
    scheduler retires the request naturally mid-exploration, and the
    context still reads the captured result."""
    eng, sched, drv = fresh_driver(engine_setup)

    def to_the_brim(ctx):
        yield Decode([ctx], 6, greedy=True)   # == max_new_tokens
        return ctx.tokens()

    exp = drv.explore([3, 1, 4], 6, to_the_brim)
    toks = exp.run()
    assert len(toks) == 3 + 6
    assert exp.final_tokens == toks
    assert_drained(sched)


def test_error_scoped_to_awaited_exploration(engine_setup):
    """Awaiting one exploration must not raise another's error, and a
    reported error is not re-raised by later run() calls."""
    eng, sched, drv = fresh_driver(engine_setup)

    def buggy(ctx):
        raise ValueError("boom")
        yield  # pragma: no cover

    def fine(ctx):
        kids = yield Fork(ctx, 2)
        yield Decode(kids, 2)
        kids[0].commit()
        return "ok"

    bad = drv.explore([1, 2, 3], 8, buggy)
    good = drv.explore([4, 5, 6], 8, fine)
    assert good.run() == "ok"          # not poisoned by bad's failure
    with pytest.raises(ValueError, match="boom"):
        bad.run()
    drv.run()                          # stale errors surface only once
    assert_drained(sched)


def test_no_stray_root_token_before_policy(engine_setup):
    """The admitted root is held in the admission transaction itself:
    the policy sees exactly the prompt, never a scheduler-paced token."""
    eng, sched, drv = fresh_driver(engine_setup)
    seen = {}

    def probe(ctx):
        seen["fork_len"] = ctx.fork_len
        seen["tokens"] = ctx.tokens()
        return True
        yield  # pragma: no cover - makes this a generator

    drv.explore([7, 3, 9], 8, probe).run()
    assert seen["fork_len"] == 3
    assert seen["tokens"] == [7, 3, 9]


def test_beam_survives_budget_exhausted_degraded_root(engine_setup):
    """A degraded beam level that exhausts the request budget retires
    the root; the next level's fork fails with BranchError, which must
    degrade the policy — not crash the whole driver run."""
    eng, sched, drv = fresh_driver(engine_setup, num_pages=6)
    # worst case fills the pool: every fork is permanently denied
    exp = drv.explore([1, 2, 3], 8, beam_search, width=2, depth=3,
                      tokens_per_level=4)
    res = exp.run()
    assert any(lv.get("degraded") for lv in res.stats["levels"])
    assert len(res.stats["levels"]) == 3    # all levels accounted for
    assert len(res.generated) == 8          # capped at the budget
    assert_drained(sched)


def test_tick_wait_is_not_a_stall(engine_setup):
    eng, sched, drv = fresh_driver(engine_setup)
    from repro.explore_ctx import Tick

    def patient(ctx):
        yield Tick(4)
        return "waited"

    exp = drv.explore([1, 2, 3], 8, patient)
    assert exp.run() == "waited"


def test_missized_sampling_rows_mutate_nothing(engine_setup):
    eng = fresh_engine(engine_setup)
    a = eng.add_request([1, 2, 3])
    b = eng.add_request([4, 5, 6])
    with pytest.raises(ValueError, match="sampling rows"):
        eng.decode([a, b], greedy=[True])   # wrong row length
    # refused before any metadata moved: the invariant survives
    assert eng.kv.length(a) == 2 and eng.kv.length(b) == 2
    assert len(eng.tokens(a)) == 3 and len(eng.tokens(b)) == 3
    eng.decode([a, b])                      # still decodes cleanly


def test_driver_stall_is_detected(engine_setup):
    """A policy decoding its own frozen origin can never make progress;
    the driver must prove the stall and raise, not spin forever."""
    eng, sched, drv = fresh_driver(engine_setup)

    def bad_policy(ctx):
        yield Fork(ctx, 2)
        yield Decode([ctx], 4)             # ctx is FROZEN: never decodes

    drv.explore([1, 2, 3], 8, bad_policy)
    with pytest.raises(RuntimeError, match="stalled"):
        drv.run()


# ---------------------------------------------------------------------------
# nesting: recursive invalidation across domains
# ---------------------------------------------------------------------------

def test_nested_context_abort_invalidates_grandchildren(engine_setup):
    """Aborting a parent context kills grandchildren in the KV domain,
    token domain and scheduler tracking — one kernel cascade."""
    eng, sched, drv = fresh_driver(engine_setup)
    holder = {}

    def nested(ctx):
        (child,) = yield Fork(ctx, 1)
        grandkids = yield Fork(child, 2)
        yield Decode(grandkids, 2)
        holder["child"], holder["grandkids"] = child, grandkids
        child.abort()                       # invalidates the whole subtree
        return ctx.generated()

    exp = drv.explore([4, 5, 6], 8, nested)
    exp.run()
    child, (g1, g2) = holder["child"], holder["grandkids"]
    for c in (child, g1, g2):
        assert not c.alive
    assert_drained(sched)


def test_nested_composite_abort_spans_store_domain(engine_setup):
    """With composite contexts the same parent abort also invalidates
    the grandchildren's *store* branches — cross-domain recursion."""
    store = BranchStore({"plan": b"root"})
    eng, sched, drv = fresh_driver(engine_setup, store=store)
    holder = {}

    def nested(ctx):
        (child,) = yield Fork(ctx, 1)
        grandkids = yield Fork(child, 2)
        yield Decode(grandkids, 2)
        for i, g in enumerate(grandkids):
            g.state.write("plan", f"g{i}".encode())
        child.abort()                       # invalidates the whole subtree
        holder["kv_dead"] = [not c.alive for c in [child] + grandkids]
        holder["state_status"] = [c.state.status
                                  for c in [child] + grandkids]
        return True

    drv.explore([4, 5, 6], 8, nested).run()
    assert holder["kv_dead"] == [True, True, True]  # KV domain dead
    assert holder["state_status"][0] is BranchStatus.ABORTED
    assert all(s in (BranchStatus.ABORTED, BranchStatus.STALE)
               for s in holder["state_status"])     # store domain dead too
    assert store.read(BranchStore.ROOT, "plan") == b"root"
    # the exploration's whole store subtree was reaped on completion:
    # a long-running driver's store stays bounded
    assert len(store._tree) == 1                    # only the store root
    assert_drained(sched)


def test_composite_commit_promotes_both_domains(engine_setup):
    store = BranchStore({"plan": b"root"})
    eng, sched, drv = fresh_driver(engine_setup, store=store)

    def pick_one(ctx):
        kids = yield Fork(ctx, 3)
        yield Decode(kids, 3)
        for i, k in enumerate(kids):
            k.state.write("plan", f"branch-{i}".encode())
        kids[2].commit()
        return ctx.state.read("plan")

    res = drv.explore([1, 2, 3], 8, pick_one).run()
    assert res == b"branch-2"
    assert_drained(sched)


def test_composite_fork_backpressure_does_not_churn_store(engine_setup):
    """A denied composite fork must be refused by the cheap KV ledger
    check BEFORE the store domain forks — retry rounds while parked
    must not grow the store tree."""
    store = BranchStore({"plan": b"root"})
    eng, sched, drv = fresh_driver(engine_setup, store=store, num_pages=4)
    rid = sched.submit([1, 2, 3], max_new_tokens=4, hold=True)
    sched.admit()
    ctx = drv._bind_root(rid, sched.seq_of(rid))
    nodes_before = len(store._tree)
    for _ in range(5):
        with pytest.raises(AdmissionDenied):
            ctx.fork(8)                     # can never fit 8 children
    assert len(store._tree) == nodes_before  # no fork/unwind churn


def test_decode_per_context_sampling_rows(engine_setup):
    """One Decode wait mixes a greedy verifier lane with sampled drafts
    (speculative decoding's shape) and a bad row length fails into the
    policy, not the driver."""
    eng, sched, drv = fresh_driver(engine_setup)
    seen = {}

    def mixed(ctx):
        kids = yield Fork(ctx, 3)
        yield Decode(kids, 3, greedy=[True, False, False],
                     temperature=[1.0, 3.0, 3.0])
        seen["gen"] = [k.generated() for k in kids]
        with pytest.raises(ValueError, match="sampling rows"):
            yield Decode(kids, 1, greedy=[True])
        kids[0].commit()
        return True

    assert drv.explore([11, 12, 13], 8, mixed).run() is True
    assert all(len(g) == 3 for g in seen["gen"])
    assert_drained(sched)


def test_admission_error_reaches_policy(engine_setup):
    """A request that can never fit raises AdmissionDenied *inside* the
    policy generator (not backpressure — a programming error)."""
    eng, sched, drv = fresh_driver(engine_setup, num_pages=4)

    def wants_too_much(ctx_unused):
        with pytest.raises(AdmissionDenied):
            yield Submit(list(range(100)), 100)
        return "handled"

    exp = drv.launch(wants_too_much(None))
    drv.run()
    assert exp.result == "handled"


# ---------------------------------------------------------------------------
# truncation (the speculative-decode primitive)
# ---------------------------------------------------------------------------

def test_truncate_then_commit_keeps_prefix(engine_setup):
    eng = fresh_engine(engine_setup)
    root = eng.add_request([1, 2, 3, 4, 5])
    b1, b2 = eng.fork(root, 2)
    for _ in range(6):
        eng.decode([b1, b2])               # greedy: identical branches
    assert lcp_len(eng.tokens(b1)[5:], eng.tokens(b2)[5:]) == 6
    free_before = eng.kv.free_pages
    eng.truncate(b1, 5 + 2)                # keep 2 "verified" tokens
    kept = eng.tokens(b1)
    assert kept == eng.tokens(b2)[:7]
    assert eng.kv.length(b1) == 6          # tokens - 1 invariant holds
    assert eng.kv.free_pages > free_before  # surplus tail page recycled
    eng.commit(b1)
    assert eng.tokens(root) == kept
    # the truncated branch keeps decoding correctly after commit
    eng.decode([root])
    assert len(eng.tokens(root)) == 8
    eng.release(root)
    assert eng.kv.free_pages == eng.kv.num_pages


def test_truncate_guards(engine_setup):
    from repro.core.errors import FrozenOriginError

    eng = fresh_engine(engine_setup)
    root = eng.add_request([1, 2, 3, 4, 5, 6])
    with pytest.raises(ValueError):
        eng.truncate(root, 9)              # cannot grow
    eng.fork(root, 1)
    with pytest.raises(FrozenOriginError):
        eng.truncate(root, 3)              # frozen origin: appends denied


# ---------------------------------------------------------------------------
# per-sequence sampling in one batch
# ---------------------------------------------------------------------------

def test_mixed_sampling_single_batch(engine_setup):
    """Greedy and sampled sequences share one decode dispatch; the
    greedy lane must match an all-greedy control."""
    ctrl = fresh_engine(engine_setup)
    c = ctrl.add_request([11, 12, 13])
    want = [ctrl.decode([c])[0] for _ in range(2)]

    eng = fresh_engine(engine_setup)
    a = eng.add_request([11, 12, 13])
    b = eng.add_request([11, 12, 13])
    key = jax.random.PRNGKey(0)
    for _ in range(2):
        key, k = jax.random.split(key)
        eng.decode([a, b], greedy=[True, False],
                   temperature=[1.0, 3.0], key=k)
    assert eng.tokens(a)[3:] == want


def test_scheduler_per_seq_sampling_inherited_on_fork(engine_setup):
    eng = fresh_engine(engine_setup)
    sched = Scheduler(eng, SchedulerConfig(seed=5))
    rid = sched.submit([1, 2, 3], max_new_tokens=6)
    sched.admit()
    seq = sched.seq_of(rid)
    sched.set_sampling(seq, greedy=False, temperature=2.0)
    kids = sched.fork(seq, 2)
    assert all(sched._sampling[k] == (False, 2.0) for k in kids)
    sched.step()                            # sampled decode, internal key
    assert all(sched.produced(k) == 1 for k in kids)


# ---------------------------------------------------------------------------
# scheduler completion primitives
# ---------------------------------------------------------------------------

def test_finish_retires_early_and_frees(engine_setup):
    eng = fresh_engine(engine_setup)
    sched = Scheduler(eng)
    rid = sched.submit([1, 2, 3], max_new_tokens=12)
    sched.admit()
    sched.step()
    assert not sched.finished(rid)
    sched.finish(rid)                       # long before the budget
    assert sched.finished(rid)
    assert len(sched.result(rid)) == 4
    st = sched.stats()
    assert st["pages_free"] == st["pages_total"]
    assert st["pages_reserved"] == 0


def test_finish_cancels_waiting_request(engine_setup):
    eng = fresh_engine(engine_setup, num_pages=4)
    sched = Scheduler(eng)
    r1 = sched.submit([1, 2, 3, 4], max_new_tokens=6)
    r2 = sched.submit([5, 6, 7, 8], max_new_tokens=6)   # FIFO-blocked
    sched.admit()
    sched.finish(r2)
    assert sched.result(r2) == []
    assert sched.wait(r1, max_steps=20)     # head request unaffected


def test_hold_blocks_decode_and_retire(engine_setup):
    eng = fresh_engine(engine_setup)
    sched = Scheduler(eng)
    rid = sched.submit([1, 2, 3], max_new_tokens=2)
    sched.admit()
    seq = sched.seq_of(rid)
    sched.hold(seq)
    for _ in range(3):
        st = sched.step()
        assert st["decoded"] == 0 and st["retired"] == 0
    sched.unhold(seq)
    assert len(sched.wait(rid, max_steps=10)) == 5  # prompt + budget
