"""repro.api conformance: errno discipline, flags word, handle table,
vectorized fork, unified eventing.

The acceptance bar for the syscall-faithful surface:

* stale/closed handles fail with ``-EBADF`` (generation counters), never
  silently address a recycled slot;
* ``BR_NONBLOCK`` turns page-budget denial into an immediate ``-EAGAIN``
  instead of blocking;
* ``BR_ISOLATE`` sibling access is rejected at the handle table;
* first-commit-wins invalidation is observable through ``poll()``;
* ``branch(parent, n=k)`` admits all k siblings in one ledger
  transaction and services their tail CoW in ONE fused device dispatch.
"""

import dataclasses

import jax
import pytest

from repro.api import (
    BR_HOLD,
    BR_ISOLATE,
    BR_NESTED,
    BR_NONBLOCK,
    BR_SPECULATIVE,
    EV_ADMITTED,
    EV_COMMITTED,
    EV_FINISHED,
    EV_INVALIDATED,
    AdmissionDenied,
    BadHandleError,
    BranchError,
    BranchSession,
    BranchStateError,
    Errno,
    PoolExhausted,
    StaleBranchError,
    Waiter,
)
from repro.configs import get_config
from repro.core import BranchStore
from repro.models.model import Model
from repro.runtime.serve_loop import ServeEngine


@pytest.fixture(scope="module")
def engine_setup():
    cfg = dataclasses.replace(get_config("paper-agentic"), dtype="float32")
    model = Model(cfg, attn_chunk=8, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def fresh_session(engine_setup, *, store=None, **kw):
    cfg, model, params = engine_setup
    kw.setdefault("num_pages", 128)
    kw.setdefault("page_size", 4)
    kw.setdefault("max_pages_per_seq", 16)
    engine = ServeEngine(model, params, **kw)
    return BranchSession(engine, store=store, max_batch=8, seed=11)


def opened_root(session, prompt=(1, 2, 3), max_new_tokens=12, flags=0):
    hd = session.open(list(prompt), max_new_tokens, flags)
    assert session.admitted(hd)
    return hd


# ---------------------------------------------------------------------------
# errno discipline
# ---------------------------------------------------------------------------

def test_every_branch_error_carries_shared_errno():
    assert AdmissionDenied("x").errno is Errno.EAGAIN
    assert AdmissionDenied("x", errno=Errno.ENOSPC).errno is Errno.ENOSPC
    assert StaleBranchError("x").errno is Errno.ESTALE
    assert BadHandleError("x").errno is Errno.EBADF
    assert BranchStateError("x").errno is Errno.EINVAL
    assert PoolExhausted("x").errno is Errno.ENOSPC
    # pre-unification compatibility: the pool error is still a MemoryError
    assert isinstance(PoolExhausted("x"), MemoryError)
    assert isinstance(PoolExhausted("x"), BranchError)


def test_never_fitting_request_is_enospc_not_eagain(engine_setup):
    s = fresh_session(engine_setup, num_pages=4)
    with pytest.raises(AdmissionDenied) as exc:
        s.open(list(range(100)), max_new_tokens=100)
    assert exc.value.errno is Errno.ENOSPC


# ---------------------------------------------------------------------------
# handle table: -EBADF via generation counters
# ---------------------------------------------------------------------------

def test_closed_handle_is_ebadf(engine_setup):
    s = fresh_session(engine_setup)
    root = opened_root(s)
    s.close(root)
    for op in (s.stat, s.events, s.tokens, s.abort, s.siblings):
        with pytest.raises(BadHandleError) as exc:
            op(root)
        assert exc.value.errno is Errno.EBADF


def test_recycled_slot_does_not_alias_old_handle(engine_setup):
    s = fresh_session(engine_setup)
    a = opened_root(s, prompt=(1, 2, 3))
    s.finish(a)                    # closes + frees the slot
    b = opened_root(s, prompt=(4, 5, 6))
    # the new root reuses slot 0 with a bumped generation: the old
    # handle must NOT resolve to it
    assert (a >> 16) == (b >> 16) and a != b
    with pytest.raises(BadHandleError):
        s.stat(a)
    assert s.stat(b)["seq"] is not None


def test_finish_closes_the_whole_subtree(engine_setup):
    s = fresh_session(engine_setup)
    root = opened_root(s, flags=BR_HOLD)
    kids = s.branch(root, BR_HOLD, 2)
    s.finish(root)
    for hd in [root] + kids:
        with pytest.raises(BadHandleError):
            s.events(hd)
    pool = s.tree()["pool"]
    assert pool["pages_free"] == pool["pages_total"]
    assert s.tree()["handles"]["open"] == 0


# ---------------------------------------------------------------------------
# flags word
# ---------------------------------------------------------------------------

def test_nonblock_fork_returns_eagain_instead_of_blocking(engine_setup):
    s = fresh_session(engine_setup, num_pages=8)
    root = opened_root(s, prompt=(1, 2, 3), max_new_tokens=8, flags=BR_HOLD)
    steps_before = s.steps
    with pytest.raises(AdmissionDenied) as exc:
        s.branch(root, BR_NONBLOCK, 8)   # can never fit 8 children
    assert exc.value.errno is Errno.EAGAIN
    assert s.steps == steps_before       # truly non-blocking: no stepping


def test_blocking_fork_raises_eagain_only_after_proven_stall(engine_setup):
    s = fresh_session(engine_setup, num_pages=8)
    root = opened_root(s, prompt=(1, 2, 3), max_new_tokens=8, flags=BR_HOLD)
    steps_before = s.steps
    with pytest.raises(AdmissionDenied):
        s.branch(root, 0, 8)
    assert s.steps > steps_before        # it tried to let work drain first


def test_isolate_rejects_sibling_access_at_handle_table(engine_setup):
    s = fresh_session(engine_setup)
    root = opened_root(s, flags=BR_HOLD)
    iso = s.branch(root, BR_ISOLATE | BR_HOLD, 2)
    with pytest.raises(BranchError) as exc:
        s.siblings(iso[0])
    assert exc.value.errno is Errno.EPERM
    open_kids = s.branch(iso[0], BR_HOLD | BR_NESTED, 2)
    assert set(s.siblings(open_kids[0])) == set(open_kids)


def test_nested_fork_requires_br_nested(engine_setup):
    s = fresh_session(engine_setup)
    root = opened_root(s, flags=BR_HOLD)
    (kid,) = s.branch(root, BR_HOLD, 1)
    with pytest.raises(BranchError) as exc:
        s.branch(kid, BR_HOLD, 2)        # fork-of-fork without BR_NESTED
    assert exc.value.errno is Errno.EINVAL
    grandkids = s.branch(kid, BR_HOLD | BR_NESTED, 2)
    assert len(grandkids) == 2


def test_truncate_requires_br_speculative(engine_setup):
    s = fresh_session(engine_setup)
    root = opened_root(s, flags=BR_HOLD)
    (plain,) = s.branch(root, 0, 1)
    (draft,) = s.branch(root, BR_SPECULATIVE, 1)
    s.wait([plain, draft], produced=3, require_all=True)
    with pytest.raises(BranchError) as exc:
        s.truncate(plain, 1)
    assert exc.value.errno is Errno.EPERM
    s.truncate(draft, 1)                 # declared draft: allowed
    assert len(s.tokens(draft)) == len(s.tokens(root)) + 1


# ---------------------------------------------------------------------------
# unified eventing
# ---------------------------------------------------------------------------

def test_first_commit_wins_invalidation_observed_through_poll(engine_setup):
    s = fresh_session(engine_setup)
    root = opened_root(s, flags=BR_HOLD)
    kids = s.branch(root, 0, 3)
    s.wait(kids, produced=2, require_all=True)
    assert s.poll(kids) == {}            # nothing resolved yet
    s.commit(kids[1])
    ready = s.poll(kids)
    assert ready[kids[1]] & EV_COMMITTED
    assert ready[kids[0]] & EV_INVALIDATED
    assert ready[kids[2]] & EV_INVALIDATED
    # the losers' scheduler/kernel state is gone too, not just flagged
    assert not s.alive(kids[0]) and not s.alive(kids[2])
    with pytest.raises(StaleBranchError):
        s.commit(kids[2])


def test_commit_loser_raises_estale_with_errno(engine_setup):
    s = fresh_session(engine_setup)
    root = opened_root(s, flags=BR_HOLD)
    kids = s.branch(root, BR_HOLD, 2)
    s.commit(kids[0])
    with pytest.raises(StaleBranchError) as exc:
        s.commit(kids[1])
    assert exc.value.errno is Errno.ESTALE


def test_waiter_finished_event_and_result(engine_setup):
    s = fresh_session(engine_setup)
    root = s.open([5, 6, 7], max_new_tokens=4)
    ready = Waiter(s).add(root, EV_FINISHED).wait(timeout_steps=50)
    assert ready[root] & EV_FINISHED
    toks = s.result(root)
    assert len(toks) == 3 + 4
    assert s.finish(root) == toks        # finish returns the same claim
    assert s.finish(root) is None        # ...and is idempotent after close


def test_admission_event_fires_when_fifo_drains(engine_setup):
    s = fresh_session(engine_setup, num_pages=8)
    first = s.open([1, 2, 3], max_new_tokens=17)     # 5 of 8 pool pages
    second = s.open([4, 5, 6], max_new_tokens=17)    # FIFO-blocked
    assert not s.events(second) & EV_ADMITTED
    ready = s.wait([second], events=EV_ADMITTED, timeout_steps=100)
    assert ready[second] & EV_ADMITTED
    s.finish(first), s.finish(second)


def test_branch_sees_admission_that_happened_during_steps(engine_setup):
    """A root admitted from the FIFO while the caller was stepping must
    be forkable without an explicit events()/admitted() call first."""
    s = fresh_session(engine_setup, num_pages=8)
    first = s.open([1, 2, 3], max_new_tokens=17)     # 5 of 8 pool pages
    second = s.open([4, 5, 6], max_new_tokens=5, flags=BR_HOLD)
    while not s.sched.finished(s.req_id_of(first)):
        s.step()                                     # admits second inside
    kids = s.branch(second, BR_HOLD, 2)              # no refresh needed
    assert len(kids) == 2
    s.finish(second)


def test_branch_after_request_finished_is_clean_einval(engine_setup):
    s = fresh_session(engine_setup)
    root = s.open([1, 2, 3], max_new_tokens=3)
    s.wait([root], events=EV_FINISHED, timeout_steps=50)
    with pytest.raises(BranchStateError) as exc:
        s.branch(root, BR_HOLD, 2)
    assert "finished" in str(exc.value)              # not a raw internal
    assert exc.value.errno is Errno.EINVAL


def test_finish_through_child_handle_claims_result(engine_setup):
    """finish() via a non-root handle must still claim the one-shot
    scheduler result (no stranded _results records) and return it."""
    s = fresh_session(engine_setup)
    root = opened_root(s, flags=BR_HOLD)
    (kid,) = s.branch(root, 0, 1)
    s.wait([kid], produced=2, require_all=True)
    s.commit(kid)
    toks = s.finish(kid)
    assert toks is not None and toks[:3] == [1, 2, 3]
    assert s.sched._results == {}                    # nothing stranded


# ---------------------------------------------------------------------------
# vectorized fork
# ---------------------------------------------------------------------------

def test_vectorized_fork_single_fused_cow_dispatch(engine_setup):
    s = fresh_session(engine_setup)
    root = opened_root(s, prompt=(1, 2, 3), max_new_tokens=12,
                       flags=BR_HOLD)   # 2 cached tokens: mid-page tail
    d0, f0 = s.engine.cow_dispatches, s.engine.cow_faults
    kids = s.branch(root, 0, 4)
    assert s.engine.cow_dispatches == d0 + 1   # ONE fused dispatch
    assert s.engine.cow_faults == f0 + 4       # ...covering all 4 tails
    # the eager CoW really privatized the tails: decoding the siblings
    # afterwards faults nothing
    s.wait(kids, produced=2, require_all=True)
    assert s.engine.cow_dispatches == d0 + 1


def test_sequential_forks_pay_one_dispatch_each(engine_setup):
    s = fresh_session(engine_setup)
    root = opened_root(s, prompt=(1, 2, 3), max_new_tokens=12,
                       flags=BR_HOLD)
    d0 = s.engine.cow_dispatches
    for _ in range(3):
        s.branch(root, BR_HOLD, 1)
    assert s.engine.cow_dispatches == d0 + 3


def test_vectorized_fork_one_ledger_group(engine_setup):
    s = fresh_session(engine_setup)
    root = opened_root(s, flags=BR_HOLD)
    kids = s.branch(root, BR_HOLD, 3)
    groups = {s.engine.kv.tree.node(s.seq_of(hd)).group for hd in kids}
    assert len(groups) == 1              # one exclusive commit group
    seq_kids = [s.branch(root, BR_HOLD, 1)[0] for _ in range(2)]
    seq_groups = {s.engine.kv.tree.node(s.seq_of(hd)).group
                  for hd in seq_kids}
    assert len(seq_groups) == 2          # sequential: separate groups


def test_vectorized_fork_midvector_error_leaves_no_orphans(engine_setup):
    """A BranchError raised mid-vector inside branch(n=k) must unwind:
    no orphaned handles in the table, no stranded page reservations —
    the dynamic face of branchlint's BL002 handle-lifecycle rule."""
    s = fresh_session(engine_setup)
    root = opened_root(s, flags=BR_HOLD)
    before_handles = set(s.open_handles())
    before_free = s.engine.kv.free_pages
    calls = {"n": 0}
    real_unhold = s.sched.unhold

    def flaky_unhold(seq):
        calls["n"] += 1
        if calls["n"] == 2:              # fail wiring the SECOND kid
            raise BranchError("injected mid-vector failure",
                              errno=Errno.EBUSY)
        real_unhold(seq)

    s.sched.unhold = flaky_unhold
    try:
        with pytest.raises(BranchError) as exc:
            s.branch(root, 0, 3)
        assert "mid-vector" in str(exc.value)
    finally:
        s.sched.unhold = real_unhold
    assert calls["n"] == 2               # it really was mid-vector
    # the half-created sibling group is fully gone: handle table back
    # to its pre-call population, every forked page freed again
    assert set(s.open_handles()) == before_handles
    assert s.engine.kv.free_pages == before_free
    # the parent is unharmed: a fresh full-width vector still works
    kids = s.branch(root, BR_HOLD, 3)
    assert len(kids) == 3
    s.commit(kids[0])
    s.finish(root)


# ---------------------------------------------------------------------------
# composite sessions (store domain rides the same verbs)
# ---------------------------------------------------------------------------

def test_composite_branch_commit_promotes_store_domain(engine_setup):
    store = BranchStore({"plan": b"root"})
    s = fresh_session(engine_setup, store=store)
    root = opened_root(s, flags=BR_HOLD)
    kids = s.branch(root, BR_HOLD, 2)
    for i, hd in enumerate(kids):
        s.state_of(hd).write("plan", f"branch-{i}".encode())
    s.commit(kids[1])
    assert s.state_of(root).read("plan") == b"branch-1"
    s.finish(root)
    assert len(store._tree) == 1         # exploration subtree reaped


def test_introspection_stat_and_tree(engine_setup):
    s = fresh_session(engine_setup)
    root = opened_root(s, flags=BR_HOLD)
    kids = s.branch(root, BR_HOLD | BR_SPECULATIVE, 2)
    st = s.stat(kids[0])
    assert st["depth"] == 1 and st["parent"] == root
    assert "BR_SPECULATIVE" in st["flags"] and "BR_HOLD" in st["flags"]
    assert st["status"] == "active" and st["held"]
    view = s.tree()
    assert view["handles"]["open"] == 3
    assert view["pool"]["pages_reserved"] > 0
    (root_node,) = view["branches"]
    assert len(root_node["children"]) == 2
    assert "frozen" == root_node["status"]
    assert s.format_tree()               # renders without crashing


# ---------------------------------------------------------------------------
# session close: the graceful-shutdown wake path
# ---------------------------------------------------------------------------

def test_session_close_wakes_blocked_waiter(engine_setup):
    import threading
    import time

    s = fresh_session(engine_setup)
    root = opened_root(s, flags=BR_HOLD)   # held: it will never decode
    out = {}

    def blocked():
        w = Waiter(s).add(root, EV_FINISHED)
        t0 = time.perf_counter()
        out["ready"] = w.wait(timeout_steps=10_000_000)
        out["elapsed"] = time.perf_counter() - t0

    t = threading.Thread(target=blocked)
    t.start()
    time.sleep(0.2)                        # let it block in wait()
    s.close()                              # no handle: close the SESSION
    t.join(timeout=30)
    assert not t.is_alive(), "close() must wake a blocked Waiter.wait"
    assert out["ready"] == {}              # nothing fired; woken by close
    assert out["elapsed"] < 30

    # a closed session refuses new work but keeps handles readable
    assert s.closed
    with pytest.raises(BranchStateError):
        s.open([1, 2], 4)
    assert s.tokens(root)[:3] == [1, 2, 3]
    assert s.step()["closed"] is True      # stepping is a no-op record


def test_session_wait_sugar_wakes_on_close(engine_setup):
    import threading
    import time

    s = fresh_session(engine_setup)
    root = opened_root(s, flags=BR_HOLD)

    def close_soon():
        time.sleep(0.2)
        s.close()

    t = threading.Thread(target=close_soon)
    t.start()
    ready = s.wait([root], events=EV_FINISHED,
                   timeout_steps=10_000_000)
    t.join(timeout=30)
    assert ready == {}                     # returned early, not by timeout
